"""Plan-cached fused einsumsvd engine vs the seed path (ISSUE 1 tentpole).

A/B on ``contract_twolayer`` (two-layer IBMPS, the library's hottest path):

* **seed**  — ``planner.disabled()`` + ``RandomizedSVD(fused=False)``: every
  matvec of every power iteration re-derives an "optimal" einsum path, and
  no compiled code is shared across the structurally-identical sites of the
  zip-up sweep (the behavior the seed repo shipped).
* **fused** — plan-cached paths + one jit-compiled randomized-SVD per
  network signature, replayed across sites/rows/sweeps.

Steady-state wall-clock is what the ITE/VQE evolution loops pay per energy
evaluation, so both variants get a warmup call before timing.  Cache
hit-rate counters are printed alongside.

Run: ``PYTHONPATH=src python benchmarks/bench_planner.py`` (or
``make bench-planner``).
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax

from benchmarks.common import SCALE, emit, emit_info, save_rows, timeit
from repro.core import planner
from repro.core.bmps import BMPS, contract_twolayer
from repro.core.einsumsvd import RandomizedSVD
from repro.core.peps import random_peps


def main():
    grid = 6 if SCALE == "small" else 8
    bond = 2
    chis = (32,) if SCALE == "small" else (32, 64)
    key = jax.random.PRNGKey(0)
    state = random_peps(grid, grid, bond, key)
    ckey = jax.random.PRNGKey(1)

    for chi in chis:
        seed_opt = BMPS(chi, RandomizedSVD(niter=2, oversample=4,
                                           fused=False))
        fused_opt = BMPS.randomized(chi, niter=2, oversample=4)

        def run_seed():
            with planner.disabled():
                return contract_twolayer(state.sites, state.sites, seed_opt,
                                         ckey)

        def run_fused():
            return contract_twolayer(state.sites, state.sites, fused_opt,
                                     ckey)

        # consistency first: the two engines agree on the same key
        planner.clear()
        v_seed = complex(run_seed())
        v_fused = complex(run_fused())
        rel = abs(v_seed - v_fused) / max(abs(v_seed), 1e-300)
        assert rel < 1e-5, (v_seed, v_fused)

        t_seed = timeit(run_seed, repeats=3, warmup=1)
        planner.reset_stats()
        t_fused = timeit(run_fused, repeats=3, warmup=1)
        s = planner.stats()
        total = s["fused_hits"] + s["fused_misses"]
        hit_rate = s["fused_hits"] / max(total, 1)

        emit(f"planner/{grid}x{grid}/chi{chi}/seed", t_seed,
             f"bond={bond}")
        emit(f"planner/{grid}x{grid}/chi{chi}/fused", t_fused,
             f"bond={bond},fused_hit_rate={hit_rate:.3f},"
             f"path_hits={s['path_hits']},path_misses={s['path_misses']}")
        speedup = t_seed / t_fused
        emit_info(f"planner/{grid}x{grid}/chi{chi}/speedup",
                  f"x{speedup:.2f}")
        print(f"# contract_twolayer {grid}x{grid} chi={chi}: "
              f"seed {t_seed*1e3:.1f} ms -> fused {t_fused*1e3:.1f} ms "
              f"({speedup:.2f}x, fused hit rate {hit_rate:.1%})")
        if speedup <= 1.0:
            print(f"# WARNING: fused engine did not beat seed at chi={chi}")

    save_rows("bench_planner.json")


if __name__ == "__main__":
    main()
