"""Paper Fig. 7: PEPS evolution (one TEBD layer) time vs bond dimension.

Compares the QR-SVD update with Gram orthogonalization (Alg. 5,
'local-gram-qr') against matricize+LAPACK QR and the direct theta update —
the same algorithm variants as the paper's Fig. 7, on the jnp backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SCALE, emit, timeit
from repro.core import gates as G
from repro.core.peps import (DirectUpdate, QRUpdate, random_peps,
                             _apply_two_site_adjacent)
from repro.core.einsumsvd import DirectSVD


def tebd_layer(state, update, key):
    g = jnp.asarray(G.ISWAP, dtype=state.dtype)
    for i in range(state.nrow):
        for j in range(0, state.ncol - 1, 2):
            key, sub = jax.random.split(key)
            state = _apply_two_site_adjacent(state, g, (i, j), (i, j + 1),
                                             update, sub)
    for j in range(state.ncol):
        for i in range(0, state.nrow - 1, 2):
            key, sub = jax.random.split(key)
            state = _apply_two_site_adjacent(state, g, (i, j), (i + 1, j),
                                             update, sub)
    return state


def main():
    grid = 4 if SCALE == "small" else 8
    bonds = (2, 4, 8) if SCALE == "small" else (2, 4, 8, 16)
    for r in bonds:
        state = random_peps(grid, grid, r, jax.random.PRNGKey(0))
        variants = {
            "gram-qr": QRUpdate(rank=r, gram=True),
            "reshape-qr": QRUpdate(rank=r, gram=False),
            "direct": DirectUpdate(rank=r, svd=DirectSVD()),
        }
        for name, upd in variants.items():
            fn = jax.jit(lambda s, k, u=upd: tebd_layer(s, u, k))
            t = timeit(fn, state, jax.random.PRNGKey(1), repeats=2)
            emit(f"evolution/{grid}x{grid}/r{r}/{name}", t,
                 f"bond={r};grid={grid}")


if __name__ == "__main__":
    main()
