"""Shared benchmark utilities: timing, CSV emission, result collection."""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, List

RESULTS_DIR = Path(os.environ.get("REPRO_RESULTS", "results"))
SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")  # small | paper

_ROWS: List[Dict] = []


def block(x):
    import jax
    return jax.block_until_ready(x)


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        block(fn(*args, **kw))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        block(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "", engine: str = None,
         precision: str = None):
    """Print the assignment-mandated CSV row: name,us_per_call,derived.

    ``engine`` tags the row with the boundary engine that produced it
    (``"zipup"`` / ``"variational"``); engine-dimensioned suites
    (bench_engines) set it so baseline JSONs can be compared per engine.
    ``precision`` tags precision-dimensioned rows (``"exact"`` /
    ``"mixed"``, bench_kernels) the same way."""
    us = seconds * 1e6
    print(f"{name},{us:.1f},{derived}")
    row = {"name": name, "us_per_call": us, "derived": derived}
    if engine is not None:
        row["engine"] = engine
    if precision is not None:
        row["precision"] = precision
    _ROWS.append(row)


def emit_info(name: str, derived: str, engine: str = None,
              precision: str = None):
    print(f"{name},,{derived}")
    row = {"name": name, "us_per_call": None, "derived": derived}
    if engine is not None:
        row["engine"] = engine
    if precision is not None:
        row["precision"] = precision
    _ROWS.append(row)


def save_rows(fname: str):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / fname
    path.write_text(json.dumps(_ROWS, indent=1))
    return path
