"""Paper Fig. 14: VQE on the ferromagnetic TFI model (Jz=-1, hx=-3.5).

Three suites:

1. ``vqe/*/bond*`` — lowest energy reached vs maximum PEPS bond dimension,
   with the statevector backend as reference (the paper's Fig. 14 sweep,
   SLSQP over the Ry+CNOT ansatz).
2. ``vqe/opt/*`` — optimizer convergence: SLSQP (paper, gradient-free)
   vs adam (exact JAX gradient through the PEPS contraction) vs a vmapped
   SPSA ensemble.  The figure of merit is *sequential* optimizer steps to
   reach the SLSQP reference energy + 1e-3: SLSQP's evaluations are
   inherently sequential (one point at a time), adam takes one
   value-and-grad evaluation per step, and every SPSA ensemble member
   advances in the same compiled program so a step costs one batched
   evaluation regardless of ensemble size.
3. ``vqe/batch/*`` — batched-ensemble throughput: ensemble=8 adam sharded
   over 8 virtual devices via ``peps_mesh`` vs ensemble=1, measured on a
   warm fused-step cache (circuits advanced per second).  Skipped with an
   info row when fewer than 8 devices are available.
"""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit, emit_info, save_rows
from repro.core.observable import tfi_hamiltonian
from repro.core.vqe import run_vqe


def _steps_to_target(history, target):
    """Index of the first history entry at or below ``target`` (or None)."""
    for k, e in enumerate(history):
        if e <= target:
            return k
    return None


def bond_sweep(n: int, iters: int, layers: int, obs) -> None:
    ref = run_vqe(n, n, obs, n_layers=layers, max_bond=4, maxiter=iters,
                  backend="statevector")
    emit_info(f"vqe/{n}x{n}/statevector",
              f"energy={ref.energy:.5f};evals={ref.n_evals}")
    bonds = (1, 2) if SCALE == "small" else (1, 2, 3, 4)
    for r in bonds:
        res = run_vqe(n, n, obs, n_layers=layers, max_bond=r,
                      contract_bond=max(2 * r, 4), maxiter=iters)
        emit_info(f"vqe/{n}x{n}/bond{r}",
                  f"energy={res.energy:.5f};evals={res.n_evals}")


def optimizer_convergence(n: int, layers: int, obs) -> None:
    """SLSQP vs adam vs SPSA-ensemble: sequential steps to the SLSQP target."""
    bond, chi = 2, 4
    slsqp = run_vqe(n, n, obs, n_layers=layers, max_bond=bond,
                    contract_bond=chi, maxiter=40, method="SLSQP")
    target = slsqp.energy + 1e-3
    # SLSQP evaluates one point at a time, so its sequential-step count is
    # its evaluation count up to the first history entry below the target.
    slsqp_steps = _steps_to_target(slsqp.history, target)
    emit_info("vqe/opt/slsqp",
              f"energy={slsqp.energy:.5f};steps_to_target={slsqp_steps}"
              f";evals={slsqp.n_evals};target={target:.5f}")

    adam = run_vqe(n, n, obs, n_layers=layers, max_bond=bond,
                   contract_bond=chi, maxiter=150, method="adam",
                   ensemble=8, lr=0.12)
    adam_steps = _steps_to_target(adam.history, target)
    emit_info("vqe/opt/adam-ens8",
              f"energy={adam.energy:.5f};steps_to_target={adam_steps}"
              f";target={target:.5f}")

    spsa = run_vqe(n, n, obs, n_layers=layers, max_bond=bond,
                   contract_bond=chi, maxiter=200, method="spsa",
                   ensemble=8, seed=3)
    spsa_steps = _steps_to_target(spsa.history, target)
    emit_info("vqe/opt/spsa-ens8",
              f"energy={spsa.energy:.5f};steps_to_target={spsa_steps}"
              f";target={target:.5f}")

    verdict = (adam_steps is not None and slsqp_steps is not None
               and adam_steps < slsqp_steps)
    emit_info("vqe/opt/verdict",
              f"adam_beats_slsqp={verdict}"
              f";adam={adam_steps};slsqp={slsqp_steps};spsa={spsa_steps}")


def _timed_steps(n, layers, obs, *, ensemble, mesh, steps):
    t0 = time.perf_counter()
    run_vqe(n, n, obs, n_layers=layers, max_bond=2, contract_bond=4,
            maxiter=steps, method="adam", ensemble=ensemble, mesh=mesh,
            lr=0.05)
    return time.perf_counter() - t0


def batched_throughput(n: int, layers: int, obs) -> None:
    """ensemble=8 on an 8-device mesh vs ensemble=1: circuits/sec."""
    import jax
    if jax.device_count() < 8:
        emit_info("vqe/batch/skip",
                  f"devices={jax.device_count()}<8 (run via make bench-vqe)")
        return
    from repro.launch.mesh import peps_mesh
    mesh = peps_mesh(1, 8)
    steps = 10
    # Warm the fused-step compile cache so the timed runs measure stepping.
    _timed_steps(n, layers, obs, ensemble=1, mesh=None, steps=2)
    _timed_steps(n, layers, obs, ensemble=8, mesh=mesh, steps=2)
    t1 = _timed_steps(n, layers, obs, ensemble=1, mesh=None, steps=steps)
    t8 = _timed_steps(n, layers, obs, ensemble=8, mesh=mesh, steps=steps)
    emit("vqe/batch/ens1", t1 / steps,
         f"circuits_per_s={steps * 1 / t1:.2f}")
    emit("vqe/batch/ens8-mesh", t8 / steps,
         f"circuits_per_s={steps * 8 / t8:.2f}"
         f";per_member_scaling=x{(t8 / 8) / t1:.2f}")


def main():
    n = 2 if SCALE == "small" else 3
    iters = 25 if SCALE == "small" else 60
    layers = 2
    obs = tfi_hamiltonian(n, n, jz=-1.0, hx=-3.5)
    bond_sweep(n, iters, layers, obs)
    optimizer_convergence(n, layers, obs)
    batched_throughput(n, layers, obs)
    save_rows("bench_vqe.json")


if __name__ == "__main__":
    main()
