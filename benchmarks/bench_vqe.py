"""Paper Fig. 14: VQE on the ferromagnetic TFI model (Jz=-1, hx=-3.5).

Lowest energy reached vs maximum PEPS bond dimension, with the statevector
backend as reference — reproducing the paper's monotone improvement with
bond dimension.  SLSQP (the paper's optimizer) over the Ry+CNOT ansatz.
"""
from __future__ import annotations

from benchmarks.common import SCALE, emit_info
from repro.core.observable import tfi_hamiltonian
from repro.core.vqe import run_vqe


def main():
    n = 2 if SCALE == "small" else 3
    iters = 25 if SCALE == "small" else 60
    layers = 2
    obs = tfi_hamiltonian(n, n, jz=-1.0, hx=-3.5)
    ref = run_vqe(n, n, obs, n_layers=layers, max_bond=4, maxiter=iters,
                  backend="statevector")
    emit_info(f"vqe/{n}x{n}/statevector",
              f"energy={ref.energy:.5f};evals={ref.n_evals}")
    bonds = (1, 2) if SCALE == "small" else (1, 2, 3, 4)
    for r in bonds:
        res = run_vqe(n, n, obs, n_layers=layers, max_bond=r,
                      contract_bond=max(2 * r, 4), maxiter=iters)
        emit_info(f"vqe/{n}x{n}/bond{r}",
                  f"energy={res.energy:.5f};evals={res.n_evals}")


if __name__ == "__main__":
    main()
