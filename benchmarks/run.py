# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--only evolution,rqc,...]

Figures covered (see DESIGN.md §7):
  Fig. 7  evolution      Fig. 8  contraction     Fig. 9  caching
  Fig. 10 rqc accuracy   Fig. 13 ite             Fig. 14 vqe
  Fig. 11/12 -> roofline table from the dry-run sweep
Scale with REPRO_BENCH_SCALE=small|paper (default small: CPU-sized).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_caching, bench_contraction, bench_evolution,
                        bench_ite, bench_roofline, bench_rqc, bench_vqe)
from benchmarks.common import emit_info, save_rows

SUITES = {
    "evolution": bench_evolution.main,      # Fig. 7
    "contraction": bench_contraction.main,  # Fig. 8 / Table II
    "caching": bench_caching.main,          # Fig. 9
    "rqc": bench_rqc.main,                  # Fig. 10
    "ite": bench_ite.main,                  # Fig. 13
    "vqe": bench_vqe.main,                  # Fig. 14
    "roofline": bench_roofline.main,        # Fig. 11/12 analogue
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        t0 = time.time()
        try:
            SUITES[name]()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
            emit_info(f"{name}/FAILED", f"{type(e).__name__}: {e}")
        emit_info(f"{name}/elapsed", f"{time.time()-t0:.1f}s")
    out = save_rows("benchmarks.json")
    print(f"# results saved to {out}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
