# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--only evolution,rqc,...]

Figures covered (see DESIGN.md §7):
  Fig. 7  evolution      Fig. 8  contraction     Fig. 9  caching
  Fig. 10 rqc accuracy   Fig. 13 ite             Fig. 14 vqe
  Fig. 11/12 -> roofline table from the dry-run sweep
Scale with REPRO_BENCH_SCALE=small|paper (default small: CPU-sized).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_caching, bench_contraction, bench_distributed,
                        bench_engines, bench_evolution, bench_ite,
                        bench_kernels, bench_resume, bench_roofline,
                        bench_rqc, bench_serving, bench_vqe)
from benchmarks.common import emit_info, save_rows

SUITES = {
    "evolution": bench_evolution,      # Fig. 7
    "contraction": bench_contraction,  # Fig. 8 / Table II
    "caching": bench_caching,          # Fig. 9
    "rqc": bench_rqc,                  # Fig. 10
    "ite": bench_ite,                  # Fig. 13
    "vqe": bench_vqe,                  # Fig. 14
    "roofline": bench_roofline,        # Fig. 11/12 analogue
    "distributed": bench_distributed,  # paper Section V (ISSUE 4)
    "engines": bench_engines,          # boundary-engine frontier (ISSUE 6)
    "kernels": bench_kernels,          # Pallas kernels + mixed precision (ISSUE 7)
    "resume": bench_resume,            # checkpoint overhead + warm start (ISSUE 8)
    "serving": bench_serving,          # batched query serving (ISSUE 9)
}


def _devices_available() -> int:
    import jax
    return len(jax.devices())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        mod = SUITES[name]
        # A suite may declare REQUIRES_DEVICES; when the process has fewer
        # (e.g. no XLA_FLAGS=--xla_force_host_platform_device_count=N), skip
        # it with a message instead of crashing/failing the whole sweep.
        need = getattr(mod, "REQUIRES_DEVICES", 1)
        have = _devices_available()
        if have < need:
            emit_info(
                f"{name}/SKIPPED",
                f"needs {need} devices but only {have} available; rerun with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
            continue
        t0 = time.time()
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
            emit_info(f"{name}/FAILED", f"{type(e).__name__}: {e}")
        emit_info(f"{name}/elapsed", f"{time.time()-t0:.1f}s")
    out = save_rows("benchmarks.json")
    print(f"# results saved to {out}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
