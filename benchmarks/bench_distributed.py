"""Column-sharded distributed boundary contraction scaling (ISSUE 4 tentpole).

Three sweeps over ``norm_squared`` via the two-layer zip-up with a
:class:`~repro.core.distributed.DistributedBMPS` option:

* **weak scaling**  — fixed columns *per shard* (the lattice grows with the
  shard count): the regime the paper's Section V targets, where one state is
  too large for a single device.
* **strong scaling** — fixed lattice, increasing shard count.
* **wavefront modes** — host (explicit placement) vs spmd (compiled
  ``shard_map`` + ``ppermute`` superstep) vs auto on a fixed lattice,
  reporting the superstep row counts and program-build/replay split
  alongside wall time (ISSUE 5 tentpole).

Each row reports wall time, the speedup vs the 1-shard run of the same
sweep, the relative deviation from the single-device ``BMPS`` value (must
be <= 1e-10 — the distributed sweep is arithmetically identical), and the
analytic halo traffic per row absorption
(:func:`repro.core.distributed.halo_bytes_per_row`).

NOTE on reading the numbers: under ``--xla_force_host_platform_device_count``
the "devices" are virtual slices of one CPU, so wall-clock speedups are NOT
expected — the sweeps validate the pipeline's dispatch/communication
structure and pin the equivalence + comm-volume numbers.  Real scaling needs
a real multi-chip mesh (see docs/distributed.md).

Run:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/bench_distributed.py
(or ``make bench-distributed``).
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax

from benchmarks.common import SCALE, emit, emit_info, save_rows, timeit
from repro.core.bmps import BMPS, norm_squared
from repro.core.distributed import DistributedBMPS, halo_bytes_per_row
from repro.core.peps import PEPS, random_peps

# benchmarks/run.py skips this suite (instead of crashing the sweep) when
# fewer devices are available; standalone runs proceed with a warning and
# round-robin shard wrapping.
REQUIRES_DEVICES = 8


def _state(nrow, ncol, bond=2, scale=2.2):
    s = random_peps(nrow, ncol, bond, jax.random.PRNGKey(3))
    return PEPS([[t * scale for t in row] for row in s.sites])


def _measure(tag, label, state, opt, base_t, key):
    """Time one sharded contraction; verify the 1e-10 equivalence first."""
    ref = complex(norm_squared(state, BMPS(opt.chi, opt.svd), key))
    val = complex(norm_squared(state, opt, key))
    rel = abs(val - ref) / max(abs(ref), 1e-300)
    assert rel <= 1e-10, (tag, label, rel)
    t = timeit(lambda: norm_squared(state, opt, key), repeats=3, warmup=1)
    halo = halo_bytes_per_row(state, opt)
    # efficiency: 1-shard time on the SAME lattice / p-shard time — the
    # honest metric for both sweeps (weak scaling grows the lattice with p,
    # so comparing against the p=1 *entry* would be meaningless)
    eff = "" if base_t is None else f"efficiency={base_t / t:.2f};"
    emit(f"distributed/{tag}/{label}", t,
         f"{eff}rel_err={rel:.1e};halo_bytes_per_row={halo}")
    return t


def main():
    n_dev = len(jax.devices())
    if n_dev < REQUIRES_DEVICES:
        emit_info("distributed/devices",
                  f"only {n_dev} devices (want {REQUIRES_DEVICES}); shards "
                  "wrap round-robin — scaling numbers are not meaningful")
    shard_counts = [1, 2, 4, 8]
    nrow, bond, chi = (6, 2, 16) if SCALE == "small" else (8, 3, 32)
    cols_per_shard = 2
    key = jax.random.PRNGKey(1)

    def opt_for(p, block):
        return DistributedBMPS.randomized(chi, niter=2, oversample=4,
                                          n_shards=p, block=block)

    # weak scaling: lattice grows with the shard count (fixed cols/shard);
    # each point's baseline is the 1-shard run of the SAME lattice
    for p in shard_counts:
        ncol = cols_per_shard * p
        state = _state(nrow, ncol, bond)
        base_t = timeit(lambda: norm_squared(state, opt_for(1, None), key),
                        repeats=3, warmup=1)
        _measure("weak", f"p{p}_ncol{ncol}", state, opt_for(p, None),
                 base_t, key)

    # strong scaling: fixed lattice, more shards (block-cyclic, width 1)
    ncol = cols_per_shard * max(shard_counts)
    state = _state(nrow, ncol, bond)
    base_t = None
    for p in shard_counts:
        t = _measure("strong", f"p{p}_ncol{ncol}", state, opt_for(p, 1),
                     base_t, key)
        if base_t is None:
            base_t = t

    # wavefront modes: host pipeline vs compiled SPMD superstep vs auto on
    # one fixed lattice (rows split ramp -> host, saturated -> superstep).
    # chi == bond^2 here so the boundary saturates after one row and most
    # rows are superstep-eligible — the steady-state regime the SPMD mode
    # targets.  First call per mode pays the plan + program build; the
    # pinned timing is the compiled replay.
    from repro.core import spmd
    nrow_w, ncol_w, bond_w = (6, 16, 2) if SCALE == "small" else (10, 24, 3)
    chi_w = bond_w * bond_w
    state = _state(nrow_w, ncol_w, bond_w, scale=2.4)
    ref = complex(norm_squared(state, BMPS.randomized(chi_w, niter=2,
                                                      oversample=4), key))
    for mode in ("host", "spmd", "auto"):
        opt = DistributedBMPS.randomized(chi_w, niter=2, oversample=4,
                                         n_shards=min(8, n_dev),
                                         wavefront=mode)
        spmd.reset_stats()
        val = complex(norm_squared(state, opt, key))   # warm (plan + build)
        rel = abs(val - ref) / max(abs(ref), 1e-300)
        assert rel <= 1e-10, (mode, rel)
        built = spmd.stats()["superstep_builds"]
        spmd.reset_stats()
        t = timeit(lambda: norm_squared(state, opt, key), repeats=3,
                   warmup=1)
        st = spmd.stats()
        emit(f"distributed/wavefront/{mode}", t,
             f"rel_err={rel:.1e};rows_spmd={st['rows_spmd'] // 4};"
             f"rows_host={st['rows_host'] // 4};builds_first_call={built}")
    emit_info("distributed/wavefront/config",
              f"nrow={nrow_w};ncol={ncol_w};bond={bond_w};chi={chi_w};"
              "NOTE=virtual CPU devices share one core - compare structure,"
              " not wall time")

    emit_info("distributed/config",
              f"nrow={nrow};bond={bond};chi={chi};devices={n_dev};"
              f"cols_per_shard={cols_per_shard}")


if __name__ == "__main__":
    main()
    out = save_rows("bench_distributed.json")
    print(f"# results saved to {out}", file=sys.stderr)
