"""Pallas micro-kernel + mixed-precision benchmark (ISSUE 7 acceptance).

Two measurements, one per tentpole half:

* ``kernels/<site>/...`` — forced-Pallas kernel vs the XLA dense reference
  on the tall-skinny GEMM shapes the rSVD chain produces, per dispatch
  site (gram / tall_apply / the zip-up first-column einsum).  Off-TPU the
  kernels run in **interpret mode**, so absolute kernel times are
  mode-dependent and NOT comparable across machines — the pinned quantity
  is the dense reference time plus the kernel-vs-dense ``rel_err`` in the
  derived column (which must stay at f32-rounding scale on every
  platform).  On a real TPU the same rows read out the compiled speedup.

* ``kernels/mixed/...`` — the accuracy-per-FLOP delta of
  ``precision="mixed"`` on the bench_engines grid: per (suite, chi) one
  exact and one mixed row with wall time and the relative value error of
  each against the suite's dense/statevector reference.  The mixed row's
  extra error column (``vs_exact``) is the precision-policy error alone —
  same chi, engine, and PRNG key as the exact row — and must sit inside
  the documented budget table (docs/contraction.md §6).

Run: ``PYTHONPATH=src python benchmarks/bench_kernels.py`` (or
``make bench-kernels``).  Pinned: ``benchmarks/baselines/bench_kernels.json``.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import SCALE, emit, emit_info, save_rows, timeit
from repro.core import bmps as B
from repro.core import peps as P
from repro.core import statevector as sv
from repro.core.circuits import (apply_circuit_exact_peps,
                                 apply_circuit_statevector, random_circuit)
from repro.core.ite import ite_run
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import QRUpdate
from repro.kernels import dispatch
from repro.kernels.gram import gram, gram_complex
from repro.kernels.matvec import planar_matmul

PRECISIONS = ("exact", "mixed")


def _rel(a, b):
    return abs(complex(a) - complex(b)) / abs(complex(b))


# ---------------------------------------------------------------------------
# Part 1: kernel vs XLA dense, per site
# ---------------------------------------------------------------------------

def bench_kernel_gemms():
    mode = "interpret" if dispatch.interpret_default() else "compiled"
    emit_info("kernels/mode", f"pallas={mode};backend={jax.default_backend()}")
    m, k = (4096, 64) if SCALE == "small" else (65536, 256)

    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    bmat = jax.random.normal(jax.random.PRNGKey(1), (k, k // 4), jnp.float32)
    c = (a[: m // 2] + 1j * a[m // 2:]).astype(jnp.complex64)

    cases = [
        ("gram", lambda: gram(a), lambda: a.T @ a),
        ("gram_complex", lambda: gram_complex(c), lambda: c.conj().T @ c),
        ("tall_apply", lambda: planar_matmul(a, bmat), lambda: a @ bmat),
    ]
    for name, kfn, dfn in cases:
        want = np.asarray(jax.block_until_ready(dfn()))
        got = np.asarray(jax.block_until_ready(kfn()))
        err = np.linalg.norm((got - want).ravel()) / np.linalg.norm(want.ravel())
        t_dense = timeit(lambda f=dfn: f())
        t_kernel = timeit(lambda f=kfn: f())
        emit(f"kernels/{name}/dense", t_dense, f"shape={m}x{k}")
        emit(f"kernels/{name}/pallas_{mode}", t_kernel,
             f"rel_err={err:.3e}")
        assert err < 1e-4, f"{name}: kernel disagrees with XLA ({err:.3e})"


# ---------------------------------------------------------------------------
# Part 2: accuracy-per-FLOP of precision="mixed" on the bench_engines grid
# ---------------------------------------------------------------------------

def _grid_rows(name, chis, contract_fn, reference):
    """Per (chi, precision): wall time + rel_err vs the suite reference;
    mixed rows add vs_exact (the precision error alone, identical solve)."""
    for chi in chis:
        vals = {}
        for prec in PRECISIONS:
            opt = B.BMPS(chi, precision=prec)
            vals[prec] = complex(contract_fn(opt))
            extra = ""
            if prec == "mixed":
                extra = f";vs_exact={_rel(vals['mixed'], vals['exact']):.3e}"
            emit(f"{name}/chi{chi}/{prec}",
                 timeit(lambda o=opt: contract_fn(o)),
                 f"rel_err={_rel(vals[prec], reference):.3e}" + extra,
                 precision=prec)


def bench_mixed_tfi():
    nrow = ncol = 4
    obs = tfi_hamiltonian(nrow, ncol, jz=-1.0, hx=-3.5)
    steps = 10 if SCALE == "small" else 30
    run = ite_run(P.computational_zeros(nrow, ncol), obs, steps=steps,
                  tau=0.05, update=QRUpdate(rank=3),
                  contract=B.BMPS(16), measure_every=steps)
    state = run.state
    merged = B.merge_layers(state.sites, state.sites)
    dense = complex(B.contract_exact_onelayer(merged)) * \
        float(np.exp(2.0 * state.log_scale))
    emit_info("kernels/mixed/tfi4x4", f"D=3;dense_norm={abs(dense):.6e}")
    key = jax.random.PRNGKey(17)
    _grid_rows("kernels/mixed/tfi4x4", (4, 8),
               lambda opt: B.norm_squared(state, opt, key), dense)


def bench_mixed_rqc():
    n = 3
    circ = random_circuit(n, n, 8, seed=3)
    state = apply_circuit_exact_peps(P.computational_zeros(n, n), circ)
    vec = apply_circuit_statevector(sv.zeros(n * n), circ)
    bits = np.zeros((n, n), dtype=int)
    exact = complex(vec[(0,) * (n * n)])
    emit_info("kernels/mixed/rqc3x3",
              f"bond={state.max_bond()};|amp|={abs(exact):.3e}")
    key = jax.random.PRNGKey(17)
    _grid_rows("kernels/mixed/rqc3x3", (4, 8),
               lambda opt: B.amplitude(state, bits, opt, key), exact)


def main():
    prev = dispatch.set_kernel_backend("pallas")
    try:
        bench_kernel_gemms()
    finally:
        dispatch.set_kernel_backend(prev)
    bench_mixed_tfi()
    bench_mixed_rqc()


if __name__ == "__main__":
    main()
    save_rows("bench_kernels.json")
