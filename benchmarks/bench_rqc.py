"""Paper Fig. 10: RQC contraction relative error vs contraction bond dim.

A 4x4 PEPS is evolved EXACTLY through 8 RQC layers (initial bond 16, as in
the paper), then one amplitude is contracted with BMPS and IBMPS at varying
chi and compared against the exact statevector amplitude.  The headline
claim — implicit randomized SVD adds no error over direct SVD, and the
error drops to machine epsilon above a chi threshold — is measured here.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import SCALE, emit_info
from repro.core import bmps as B
from repro.core import statevector as sv
from repro.core.circuits import (apply_circuit_exact_peps,
                                 apply_circuit_statevector, random_circuit)
from repro.core.peps import computational_zeros
from repro.core.einsumsvd import DirectSVD, RandomizedSVD


def main():
    n = 4
    circ = random_circuit(n, n, 8, seed=3)
    state = apply_circuit_exact_peps(computational_zeros(n, n), circ)
    vec = apply_circuit_statevector(sv.zeros(n * n), circ)
    bits = np.zeros((n, n), dtype=int)
    exact = complex(vec[(0,) * (n * n)])
    emit_info(f"rqc/{n}x{n}", f"bond={state.max_bond()};|amp|={abs(exact):.3e}")
    chis = (2, 4, 8, 16, 32, 64) if SCALE == "small" else (2, 4, 8, 16, 32, 64, 128)
    for chi in chis:
        a_b = complex(B.amplitude(state, bits, B.BMPS(chi, DirectSVD())))
        a_i = complex(B.amplitude(state, bits,
                                  B.BMPS(chi, RandomizedSVD(niter=4, oversample=8))))
        e_b = abs(a_b - exact) / abs(exact)
        e_i = abs(a_i - exact) / abs(exact)
        emit_info(f"rqc/{n}x{n}/chi{chi}",
                  f"bmps_relerr={e_b:.3e};ibmps_relerr={e_i:.3e}")


if __name__ == "__main__":
    main()
