"""Roofline table from the dry-run sweep (paper Fig. 11/12 analogue).

The container is CPU-only, so instead of wall-clock scaling curves the
scaling story is told by the compiled-artifact roofline terms per
(arch x shape x mesh) — reads results/dryrun.json produced by
``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import RESULTS_DIR, emit_info


def main():
    path = RESULTS_DIR / "dryrun.json"
    if not path.exists():
        emit_info("roofline/missing", f"run dryrun --all first ({path})")
        return
    rows = json.loads(path.read_text())
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    errors = [r for r in rows if r.get("status") == "error"]
    emit_info("roofline/cells", f"ok={len(ok)};skipped={len(skipped)};"
                                f"errors={len(errors)}")
    for r in sorted(ok, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        emit_info(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            f"bottleneck={r['bottleneck']};"
            f"compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"frac={r.get('roofline_frac', 0):.4f};"
            f"useful={r.get('useful_ratio', 0):.3f}")
    for r in skipped:
        emit_info(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                  f"SKIPPED:{r.get('reason','')[:60]}")
    for r in errors:
        emit_info(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                  f"ERROR:{r.get('error','')[:80]}")


if __name__ == "__main__":
    main()
