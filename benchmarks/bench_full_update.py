"""Full update vs QR simple update: energy error and wall-clock per sweep
(ISSUE 2 acceptance benchmark).

4x4 transverse-field Ising model at bond D=3, equal Trotter steps for every
variant:

* **qr**          — ``QRUpdate`` (Alg. 1 simple update), the speed baseline.
* **full/cad=N**  — ``FullUpdate`` with the cached row environments refreshed
  every N gate applications (N=40 is once per Trotter step on this grid;
  N=8 refreshes five times per step for tighter environments).

The energy error is measured against the exact statevector ITE reference
(paper Fig. 13 methodology).  Planner fused-cache hit rates are reported for
the post-warmup window — after the first step the evolution loop replays
compiled ALS/environment code, so the hit rate should be >90%.

Run: ``PYTHONPATH=src python benchmarks/bench_full_update.py`` (or
``make bench-full-update``).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax

from benchmarks.common import SCALE, emit, emit_info, save_rows
from repro.core import bmps as B
from repro.core import peps as P
from repro.core.ite import ite_run, ite_statevector
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import FullUpdate, QRUpdate


def main():
    nrow = ncol = 4
    bond, chi_env, chi_meas = 3, 12, 16
    tau = 0.05
    steps = 30 if SCALE == "small" else 60
    n_gates = 2 * nrow * ncol - nrow - ncol + nrow * ncol  # 2-site + 1-site

    obs = tfi_hamiltonian(nrow, ncol, jz=-1.0, hx=-3.5)
    _, e_ref = ite_statevector(nrow, ncol, obs, tau, steps=2 * steps)
    emit_info(f"full_update/{nrow}x{ncol}/reference", f"E_ref={e_ref:.8f}")

    variants = [
        ("qr", QRUpdate(rank=bond)),
        ("full/cad=40", FullUpdate(rank=bond, chi=chi_env,
                                   env_refresh_every=n_gates)),
        ("full/cad=8", FullUpdate(rank=bond, chi=chi_env,
                                  env_refresh_every=8)),
    ]
    kw = dict(tau=tau, contract=B.BMPS(chi_meas), measure_every=steps)
    for name, upd in variants:
        # warm one step separately so the reported hit rate covers the
        # steady-state window the evolution loop actually lives in
        t0 = time.perf_counter()
        first = ite_run(P.computational_zeros(nrow, ncol), obs, steps=1,
                        update=upd, **kw)
        t_first = time.perf_counter() - t0
        t0 = time.perf_counter()
        rest = ite_run(first.state, obs, steps=steps - 1, update=upd, **kw)
        t_rest = time.perf_counter() - t0
        err = abs(rest.energies[-1] - e_ref) / abs(e_ref)
        st = rest.planner_stats
        total = st["fused_hits"] + st["fused_misses"]
        hit = st["fused_hits"] / max(total, 1)
        per_sweep = t_rest / (steps - 1)
        derived = (f"rel_err={err:.3e},fused_hit_rate={hit:.3f},"
                   f"warmup_s={t_first:.2f}")
        if rest.fidelities:
            derived += f",min_fidelity={min(rest.fidelities):.6f}"
        emit(f"full_update/{nrow}x{ncol}/D{bond}/{name}/per_sweep",
             per_sweep, derived)
        print(f"# {name}: E={rest.energies[-1]:.8f} rel_err={err:.3e} "
              f"{per_sweep*1e3:.0f} ms/sweep (hit={hit:.3f})")

    path = save_rows("bench_full_update.json")
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
