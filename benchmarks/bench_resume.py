"""Robustness overheads: checkpoint-save cost per ITE step and the
cold-vs-warm planner path-cache startup (ISSUE 8).

Two questions a service owner asks before turning the hardening on:

1. What does ``checkpoint_every=1`` cost an ITE step?  Measured as the
   wall-time delta of an identical evolution with and without async
   checkpointing (the device->host snapshot is synchronous; the disk write
   overlaps the next step).
2. What does the persistent planner cache save a restarted replica?
   Measured honestly: only the opt_einsum *path searches* are persisted —
   the jit compiles still happen in the fresh process — so the number
   reported is the path-search time itself (cold search vs preloaded
   lookup), next to the path-cache hit counters that prove the warm replay
   ran with zero misses.
"""
from __future__ import annotations

import shutil
import tempfile

import jax

from benchmarks.common import SCALE, emit, emit_info, timeit
from repro.core import planner
from repro.core.bmps import BMPS
from repro.core.einsumsvd import RandomizedSVD
from repro.core.ite import ite_run
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import QRUpdate, computational_zeros


def _ite(steps, ckpt_dir=None, every=0):
    svd = RandomizedSVD(niter=2, oversample=4)
    nrow, ncol = (3, 3) if SCALE == "small" else (4, 4)
    obs = tfi_hamiltonian(nrow, ncol)
    return ite_run(computational_zeros(nrow, ncol), obs, 0.05, steps,
                   QRUpdate(rank=2, svd=svd), BMPS(8, svd=svd),
                   measure_every=steps, key=jax.random.PRNGKey(0),
                   checkpoint_dir=ckpt_dir, checkpoint_every=every,
                   resume=False)


def bench_checkpoint_overhead():
    steps = 4 if SCALE == "small" else 10
    _ite(steps)   # warm the planner/jit caches so the delta is IO-only
    t_off = timeit(lambda: _ite(steps), repeats=3, warmup=0)
    d = tempfile.mkdtemp(prefix="bench_resume_")
    try:
        t_on = timeit(lambda: _ite(steps, ckpt_dir=d, every=1),
                      repeats=3, warmup=0)
    finally:
        shutil.rmtree(d, ignore_errors=True)
    per_step = (t_on - t_off) / steps
    emit("resume/ite_step_plain", t_off / steps)
    emit("resume/ite_step_ckpt_every_1", t_on / steps)
    emit("resume/ckpt_overhead_per_step", max(per_step, 0.0),
         f"{100.0 * max(per_step, 0.0) * steps / t_off:.1f}% of run")


def bench_path_cache_startup():
    # cold: real opt_einsum searches for every distinct signature
    planner.clear()
    t_cold = timeit(lambda: _ite(2), repeats=1, warmup=0)
    stats = planner.stats()
    cold_misses = stats["path_misses"]
    f = tempfile.mktemp(suffix=".json")
    n = planner.save_path_cache(f)

    # warm: preload, replay the identical workload (jit compiles still run —
    # only the path searches are persisted; the counters prove zero misses)
    planner.clear()
    t_load = timeit(lambda: planner.load_path_cache(f), repeats=1, warmup=0)
    before = planner.stats()
    t_warm = timeit(lambda: _ite(2), repeats=1, warmup=0)
    delta = planner.stats_since(before)
    emit("resume/startup_cold", t_cold, f"{cold_misses} path searches")
    emit("resume/startup_warm_preloaded", t_warm,
         f"misses={delta['path_misses']} hits={delta['path_hits']}")
    emit("resume/path_cache_load", t_load, f"{n} entries")
    emit_info("resume/warm_zero_misses", str(delta["path_misses"] == 0))


def main():
    bench_checkpoint_overhead()
    bench_path_cache_startup()


if __name__ == "__main__":
    from benchmarks.common import save_rows
    main()
    save_rows("bench_resume.json")
