"""Paper Fig. 9: expectation-value caching speedup.

<psi|H|psi> for the TFI Hamiltonian (one-site terms on all sites + two-site
terms on all neighbour pairs, as in the paper) with and without the
row-environment cache.  Timed EAGERLY (library-primitive granularity, like
the paper's NumPy/CTF backends): under one big jit, XLA's CSE would
silently deduplicate the per-term environment recomputations and hand the
no-cache path the cached structure for free.  The no-cache cost is measured
on a term subset and scaled (noted in `derived`).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import SCALE, emit, emit_info
from repro.core import bmps as B
from repro.core.environments import row_environments, top_environments, \
    trivial_env, _flip_rows
from repro.core.expectation import _term_value, norm_from_envs, term_rows
from repro.core.observable import Observable, tfi_hamiltonian
from repro.core.peps import random_peps
from repro.core.einsumsvd import DirectSVD


def _eval_cached(st, obs, opt):
    top, bottom = row_environments(st, opt)
    norm = norm_from_envs(st, top, bottom)
    total = 0.0
    for term in obs:
        i0, i1 = term_rows(term, st.ncol)
        total = total + term.coeff * _term_value(st, term, top[i0], bottom[i1])
    return total / norm


def _eval_term_nocache(st, term, opt, key):
    i0, i1 = term_rows(term, st.ncol)
    k1, k2 = jax.random.split(key)
    top_env = (trivial_env(st.ncol, st.dtype) if i0 == 0 else
               top_environments(st.sites[:i0], st.sites[:i0], opt, k1)[i0])
    if i1 == st.nrow - 1:
        bot_env = trivial_env(st.ncol, st.dtype)
    else:
        sub = st.sites[i1 + 1:]
        bot_env = top_environments(_flip_rows(sub), _flip_rows(sub), opt,
                                   k2)[len(sub)]
    return _term_value(st, term, top_env, bot_env)


def main():
    grids = (4, 5) if SCALE == "small" else (4, 6, 8, 12)
    bond = 3
    subset = 12
    for n in grids:
        st = random_peps(n, n, bond, jax.random.PRNGKey(0))
        obs = tfi_hamiltonian(n, n)
        opt = B.BMPS(bond * bond, DirectSVD())

        # warm the per-shape eager compile caches for both paths
        jax.block_until_ready(_eval_cached(st, obs, opt))
        key = jax.random.PRNGKey(3)
        for term in obs.terms[:subset]:
            key, sub = jax.random.split(key)
            jax.block_until_ready(_eval_term_nocache(st, term, opt, sub))

        t0 = time.perf_counter()
        jax.block_until_ready(_eval_cached(st, obs, opt))
        t_cache = time.perf_counter() - t0

        key = jax.random.PRNGKey(3)
        t0 = time.perf_counter()
        for term in obs.terms[:subset]:
            key, sub = jax.random.split(key)
            jax.block_until_ready(_eval_term_nocache(st, term, opt, sub))
        t_sub = time.perf_counter() - t0
        t_nocache = t_sub * len(obs) / min(subset, len(obs))

        emit(f"caching/{n}x{n}/cached", t_cache, f"bond={bond};terms={len(obs)}")
        emit(f"caching/{n}x{n}/nocache", t_nocache,
             f"extrapolated_from={min(subset, len(obs))}_terms")
        emit_info(f"caching/{n}x{n}/speedup", f"{t_nocache/t_cache:.2f}x")


if __name__ == "__main__":
    main()
