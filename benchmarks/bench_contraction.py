"""Paper Fig. 8 / Table II: PEPS contraction time vs bond dimension.

BMPS (direct SVD) vs IBMPS (implicit randomized SVD) on a PEPS without
physical indices (generated directly, as the paper does), plus two-layer
IBMPS on the <psi|psi> network and the exact contraction for small bonds.
Also fits the time~r^alpha scaling exponents to show the asymptotic gap.
"""
from __future__ import annotations

import math

import jax

from benchmarks.common import SCALE, emit, emit_info, timeit
from repro.core import bmps as B
from repro.core.peps import random_onelayer, random_peps
from repro.core.einsumsvd import DirectSVD, RandomizedSVD


def main():
    grid = 6 if SCALE == "small" else 8
    bonds = (2, 4, 8) if SCALE == "small" else (2, 4, 8, 16, 32)
    times = {"bmps": [], "ibmps": []}
    for r in bonds:
        rows = random_onelayer(grid, grid, r, jax.random.PRNGKey(0))
        chi = r  # contraction bond = initial bond (paper Fig. 8 setup)
        for name, svd in (("bmps", DirectSVD()),
                          ("ibmps", RandomizedSVD(niter=2, oversample=4))):
            fn = jax.jit(lambda rw, o=B.BMPS(chi, svd): B.contract_onelayer(rw, o))
            t = timeit(fn, rows, repeats=2)
            times[name].append((r, t))
            emit(f"contraction/{grid}x{grid}/r{r}/{name}", t, f"chi={chi}")
        if r <= 4:
            t = timeit(jax.jit(B.contract_exact_onelayer), rows, repeats=2)
            emit(f"contraction/{grid}x{grid}/r{r}/exact", t, "")
        # two-layer IBMPS on <psi|psi> (phys PEPS of bond sqrt-ish scale)
        if r <= 8:
            st = random_peps(grid, grid, r, jax.random.PRNGKey(1))
            fn = jax.jit(lambda s, o=B.BMPS(chi, RandomizedSVD(niter=2, oversample=4)):
                         B.contract_twolayer(s.sites, s.sites, o))
            t = timeit(fn, st, repeats=2)
            emit(f"contraction/{grid}x{grid}/r{r}/two-layer-ibmps", t, f"chi={chi}")
    # scaling exponents (log-log slope over the last two points)
    for name, ts in times.items():
        if len(ts) >= 2:
            (r0, t0), (r1, t1) = ts[-2], ts[-1]
            alpha = math.log(t1 / t0) / math.log(r1 / r0)
            emit_info(f"contraction/scaling/{name}", f"alpha={alpha:.2f}")


if __name__ == "__main__":
    main()
