"""Boundary-engine accuracy-per-FLOP frontier (ISSUE 6 acceptance benchmark).

Zip-up truncation is greedy — the SVD at column j cannot see columns > j —
while the variational engine ALS-fits the whole boundary row at fixed chi
(zip-up-seeded).  This benchmark measures what that buys on the two suites
the repo's accuracy claims live on:

* ``engines/tfi4x4``  — <psi|psi> of an ITE-evolved 4x4 transverse-field
  Ising PEPS at bond D=3 (two-layer contraction), reference = dense
  merged-pair contraction;
* ``engines/rqc4x4``  — one amplitude of a 4x4 PEPS evolved exactly through
  8 random-circuit layers (one-layer contraction), reference = exact
  statevector amplitude.

Per (suite, chi, engine) one row reports the relative error and the median
wall time — together the accuracy-per-FLOP frontier: at equal chi the
variational engine sits below zip-up in error at a constant-factor time
premium, i.e. it reaches a given error at smaller chi.  DirectSVD is used
throughout so the frontier is deterministic and pinnable
(``benchmarks/baselines/bench_engines.json``); the closing
``engines/frontier`` row lists every chi where variational beats zip-up —
non-empty is the pinned acceptance criterion.

Run: ``PYTHONPATH=src python benchmarks/bench_engines.py`` (or
``make bench-engines``).
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np
import jax

from benchmarks.common import SCALE, emit, emit_info, save_rows, timeit
from repro.core import bmps as B
from repro.core import peps as P
from repro.core import statevector as sv
from repro.core.circuits import (apply_circuit_exact_peps,
                                 apply_circuit_statevector, random_circuit)
from repro.core.ite import ite_run
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import QRUpdate

ENGINES = ("zipup", "variational")


def _frontier(name, chis, errors, times):
    """Emit per-chi rows + the summary row of chis where variational wins."""
    wins = [c for c in chis
            if errors[("variational", c)] < errors[("zipup", c)]]
    for eng in ENGINES:
        for chi in chis:
            emit(f"{name}/chi{chi}/{eng}", times[(eng, chi)],
                 f"rel_err={errors[(eng, chi)]:.3e}", engine=eng)
    emit_info(f"{name}/frontier",
              f"variational_wins_at_chi={':'.join(map(str, wins)) or 'none'}")
    return wins


def bench_tfi():
    nrow = ncol = 4
    obs = tfi_hamiltonian(nrow, ncol, jz=-1.0, hx=-3.5)
    steps = 10 if SCALE == "small" else 30
    run = ite_run(P.computational_zeros(nrow, ncol), obs, steps=steps,
                  tau=0.05, update=QRUpdate(rank=3),
                  contract=B.BMPS(16), measure_every=steps)
    state = run.state
    merged = B.merge_layers(state.sites, state.sites)
    dense = complex(B.contract_exact_onelayer(merged)) * \
        float(np.exp(2.0 * state.log_scale))
    emit_info("engines/tfi4x4", f"D=3;dense_norm={abs(dense):.6e}")
    key = jax.random.PRNGKey(17)
    chis = (2, 3, 4, 6, 8)
    errors, times = {}, {}
    for eng in ENGINES:
        for chi in chis:
            opt = B.BMPS(chi, engine=eng)
            val = complex(B.norm_squared(state, opt, key))
            errors[(eng, chi)] = abs(val - dense) / abs(dense)
            times[(eng, chi)] = timeit(
                lambda o=opt: B.norm_squared(state, o, key))
    return _frontier("engines/tfi4x4", chis, errors, times)


def bench_rqc():
    n = 4
    circ = random_circuit(n, n, 8, seed=3)
    state = apply_circuit_exact_peps(P.computational_zeros(n, n), circ)
    vec = apply_circuit_statevector(sv.zeros(n * n), circ)
    bits = np.zeros((n, n), dtype=int)
    exact = complex(vec[(0,) * (n * n)])
    emit_info("engines/rqc4x4", f"bond={state.max_bond()};|amp|={abs(exact):.3e}")
    key = jax.random.PRNGKey(17)
    chis = (4, 8, 16, 32)
    errors, times = {}, {}
    for eng in ENGINES:
        for chi in chis:
            opt = B.BMPS(chi, engine=eng)
            val = complex(B.amplitude(state, bits, opt, key))
            errors[(eng, chi)] = abs(val - exact) / abs(exact)
            times[(eng, chi)] = timeit(
                lambda o=opt: B.amplitude(state, bits, o, key))
    return _frontier("engines/rqc4x4", chis, errors, times)


def main():
    wins = bench_tfi() + bench_rqc()
    if not wins:
        # RuntimeError (not SystemExit) so benchmarks.run records the suite
        # as failed instead of aborting the whole sweep
        raise RuntimeError(
            "acceptance violation: variational never beat zip-up at any chi")


if __name__ == "__main__":
    main()
    save_rows("bench_engines.json")
