"""Paper Fig. 13: imaginary time evolution of the J1-J2 Heisenberg model.

Energy after ITE vs evolution bond dimension r, against the statevector-ITE
reference (the paper's baseline), including the m=r vs m=r^2 contraction
bond comparison of Fig. 13b.  Grid is 3x3 at small scale (CPU) and the
paper's 4x4 at REPRO_BENCH_SCALE=paper.
"""
from __future__ import annotations

from benchmarks.common import SCALE, emit_info
from repro.core import bmps as B
from repro.core.ite import ite_run, ite_statevector
from repro.core.observable import j1j2_hamiltonian
from repro.core.peps import QRUpdate, computational_zeros
from repro.core.einsumsvd import RandomizedSVD


def main():
    n = 3 if SCALE == "small" else 4
    steps = 60 if SCALE == "small" else 150
    tau = 0.05
    obs = j1j2_hamiltonian(n, n)
    _, e_ref = ite_statevector(n, n, obs, tau, steps=max(steps * 2, 200))
    emit_info(f"ite/{n}x{n}/statevector", f"energy={e_ref:.6f}")
    bonds = (1, 2, 3) if SCALE == "small" else (1, 2, 3, 4)
    for r in bonds:
        for m_name, m in (("m=r", max(r, 2)), ("m=r^2", max(r * r, 2))):
            res = ite_run(computational_zeros(n, n), obs, tau, steps,
                          update=QRUpdate(rank=r),
                          contract=B.BMPS(m, RandomizedSVD(niter=2, oversample=4)),
                          measure_every=steps)
            err = abs(res.energies[-1] - e_ref) / abs(e_ref)
            emit_info(f"ite/{n}x{n}/r{r}/{m_name}",
                      f"energy={res.energies[-1]:.6f};relerr={err:.3e}")


if __name__ == "__main__":
    main()
