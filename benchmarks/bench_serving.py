"""Serving throughput: batched+prefix-cached amplitudes vs per-query cold.

The acceptance benchmark for the PEPS query serving engine
(``repro.core.serving``): a 6x6 RQC-evolved state served at chi=8, with a
batch of bitstring queries sharing their rows ``0..nrow-2`` prefix (the
sampling-sweep regime the prefix cache targets).

* ``serving/cold_per_query``  — one full boundary sweep per amplitude
  (``bmps.amplitude`` in a loop; compile excluded by warmup).
* ``serving/batched_cached``  — ``ServingEngine.amplitude_batch`` with a
  warm prefix cache: the shared-prefix sweep is cached, only the batched
  final-row close runs per query.
* ``serving/speedup``         — must be >= 5x (pinned in baselines/).
* ``serving/equivalence``     — max |served - direct| must be <= 1e-10.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit, emit_info, save_rows, timeit
from repro.core import bmps as B
from repro.core.circuits import apply_circuit_exact_peps, random_circuit
from repro.core.einsumsvd import DirectSVD
from repro.core.peps import computational_zeros
from repro.core.serving import ServingEngine

GRID = 6
LAYERS = 8
CHI = 8
BATCH = 64 if SCALE == "small" else 256
COLD_QUERIES = 4 if SCALE == "small" else 16


def main() -> None:
    # DirectSVD: amplitude closures of deep RQC states live in the *small*
    # singular directions, which RandomizedSVD's power iterations smear —
    # the per-query reference itself drifts there (see docs/serving.md).
    option = B.BMPS(CHI, DirectSVD())
    circ = random_circuit(GRID, GRID, LAYERS, seed=7)
    state = apply_circuit_exact_peps(computational_zeros(GRID, GRID), circ)
    emit_info("serving/state",
              f"{GRID}x{GRID} RQC depth {LAYERS} bond {state.max_bond()} "
              f"chi {CHI}")

    rng = np.random.default_rng(0)
    prefix = rng.integers(0, 2, (GRID - 1, GRID))
    finals = rng.integers(0, 2, (BATCH, 1, GRID))
    bits = np.concatenate(
        [np.broadcast_to(prefix, (BATCH, GRID - 1, GRID)), finals], axis=1)

    # -- cold: one full one-layer boundary sweep per query -------------------
    cold_bits = bits[:COLD_QUERIES]

    def cold_loop():
        return [B.amplitude(state, b, option) for b in cold_bits]

    t_cold = timeit(cold_loop) / COLD_QUERIES
    emit("serving/cold_per_query", t_cold, f"qps={1.0 / t_cold:.1f}")

    # -- served: warm prefix cache + batched final-row close -----------------
    with ServingEngine(start=False) as engine:
        engine.register_state("rqc", state, option)
        engine.amplitude_batch("rqc", bits)  # populate cache, compile buckets
        t_served = timeit(engine.amplitude_batch, "rqc", bits) / BATCH
        emit("serving/batched_cached", t_served, f"qps={1.0 / t_served:.1f}")

        speedup = t_cold / t_served
        emit_info("serving/speedup",
                  f"x{speedup:.1f} (cold per-query vs batched+cached, "
                  f"batch {BATCH})")

        served = np.asarray(engine.amplitude_batch("rqc", bits))
        direct = np.asarray([complex(B.amplitude(state, b, option))
                             for b in cold_bits])
        err = float(np.abs(served[:COLD_QUERIES] - direct).max())
        emit_info("serving/equivalence",
                  f"max|served-direct|={err:.2e} over {COLD_QUERIES} queries "
                  f"(tol 1e-10)")

        st = engine.stats()
        ps = st["per_state"]["rqc"]
        emit_info("serving/cache",
                  f"prefix_hits={ps['prefix_hits']} "
                  f"prefix_misses={ps['prefix_misses']} "
                  f"rows_absorbed={st['rows_absorbed']} "
                  f"padded={st['padded_queries']}")


if __name__ == "__main__":
    main()
    save_rows("bench_serving.json")
