PY ?= python

# Tier-1 verification command (see ROADMAP.md).
.PHONY: test
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

.PHONY: test-fast
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

.PHONY: bench-planner
bench-planner:
	PYTHONPATH=src $(PY) benchmarks/bench_planner.py

.PHONY: bench-full-update
bench-full-update:
	PYTHONPATH=src $(PY) benchmarks/bench_full_update.py

# Intra-state column-sharded contraction (8 virtual CPU devices).
.PHONY: bench-distributed
bench-distributed:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src $(PY) benchmarks/bench_distributed.py

.PHONY: test-distributed
test-distributed:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_distributed.py \
	    tests/test_spmd.py

.PHONY: docs-check
docs-check:
	$(PY) tools/check_doc_links.py
