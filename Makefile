PY ?= python

# Tier-1 verification command (see ROADMAP.md).
.PHONY: test
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

.PHONY: test-fast
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

.PHONY: bench-planner
bench-planner:
	PYTHONPATH=src $(PY) benchmarks/bench_planner.py

.PHONY: bench-full-update
bench-full-update:
	PYTHONPATH=src $(PY) benchmarks/bench_full_update.py
