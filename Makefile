PY ?= python

# Tier-1 verification command (see ROADMAP.md).
.PHONY: test
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

.PHONY: test-fast
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

.PHONY: bench-planner
bench-planner:
	PYTHONPATH=src $(PY) benchmarks/bench_planner.py

.PHONY: bench-full-update
bench-full-update:
	PYTHONPATH=src $(PY) benchmarks/bench_full_update.py

# Intra-state column-sharded contraction (8 virtual CPU devices).
.PHONY: bench-distributed
bench-distributed:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src $(PY) benchmarks/bench_distributed.py

.PHONY: test-distributed
test-distributed:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_distributed.py \
	    tests/test_spmd.py

# Boundary-engine layer (zipup/variational): refactor-identity goldens,
# variational accuracy, dispatch, and the SPMD marshalling assertion
# (which needs >= 2 devices, hence the forced device count).
.PHONY: test-engines
test-engines:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_engines.py

# Accuracy-per-FLOP frontier: zip-up vs variational on 4x4 TFI + RQC.
.PHONY: bench-engines
bench-engines:
	PYTHONPATH=src $(PY) benchmarks/bench_engines.py

# Kernel lane: property-based kernel-vs-dense parity + dispatch gating.
.PHONY: test-kernels
test-kernels:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_kernels.py \
	    tests/test_dispatch.py

# Per-precision error-budget tier: exact re-pins goldens, mixed is
# measured against every budget documented in docs/contraction.md §6.
.PHONY: test-precision
test-precision:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_precision.py

# Pallas kernel vs XLA + accuracy-per-FLOP of precision="mixed".
.PHONY: bench-kernels
bench-kernels:
	PYTHONPATH=src $(PY) benchmarks/bench_kernels.py

# Robustness lane: fault injection + the guard ladder, checkpoint
# dtype/atomicity/GC, and the ITE/VQE chaos-resume tests (subprocess
# kill/resume, incl. the 8-virtual-device distributed variant — the
# subprocesses force their own device count, so no XLA_FLAGS here).
.PHONY: test-robustness
test-robustness:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_runtime_guard.py \
	    tests/test_checkpoint.py tests/test_resume.py

# Checkpoint-save overhead per ITE step + cold-vs-warm planner-cache startup.
.PHONY: bench-resume
bench-resume:
	PYTHONPATH=src $(PY) benchmarks/bench_resume.py

# Gradient-correctness lane (ISSUE 10): AD-vs-finite-difference property
# sweep over grids x chi x boundary engines, degenerate-spectrum SVD/QR
# gradients, vmapped-ensemble PRNG contract, and mesh-sharded == unsharded
# batched execution (hence the 8 forced virtual devices).
.PHONY: test-vqe
test-vqe:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_vqe_grad.py

# adam-vs-SLSQP-vs-SPSA convergence (evals to tolerance) + batched
# ensemble throughput on 8 virtual devices.
.PHONY: bench-vqe
bench-vqe:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src:. $(PY) benchmarks/bench_vqe.py

# Serving lane: served-vs-per-query equivalence (property-based), threaded
# concurrency, and cache-lifecycle (invalidation / LRU eviction) tests.
.PHONY: test-serving
test-serving:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_serving.py

# Batched+prefix-cached serving throughput vs per-query cold contraction.
.PHONY: bench-serving
bench-serving:
	PYTHONPATH=src $(PY) benchmarks/bench_serving.py

.PHONY: docs-check
docs-check:
	$(PY) tools/check_doc_links.py
