"""Quickstart: the Koala-style public API in 60 lines.

Build a PEPS, apply gates, and compute an expectation value with the
paper's machinery (QR-SVD simple update + two-layer IBMPS contraction with
intermediate caching) — the jnp analogue of the paper's Section V example.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import peps, gates
from repro.core.peps import QRUpdate, apply_operator
from repro.core.bmps import BMPS, norm_squared
from repro.core.observable import Observable
from repro.core.expectation import expectation
from repro.core.einsumsvd import RandomizedSVD

# Create a 2x3 PEPS in |000000>
qstate = peps.computational_zeros(nrow=2, ncol=3)

# Apply one-site and two-site operators with QR-SVD (Alg. 1 + Alg. 5)
Y = gates.gate("Y")
CX = gates.gate("CX")
qstate = apply_operator(qstate, gates.gate("H"), [0])
qstate = apply_operator(qstate, Y, [1])
qstate = apply_operator(qstate, CX, [1, 4], QRUpdate(rank=2))
qstate = apply_operator(qstate, CX, [0, 1], QRUpdate(rank=4))

# Calculate an expectation value with (implicit-randomized-SVD) BMPS + cache
H = Observable.ZZ(3, 4) + 0.2 * Observable.X(1)
contract = BMPS(chi=4, svd=RandomizedSVD(niter=4))
result = expectation(qstate, H, contract, use_cache=True)
print("<psi|H|psi>/<psi|psi> =", complex(result))

nrm = norm_squared(qstate, contract)
print("<psi|psi>            =", complex(nrm))

# cross-check against the exact statevector
from repro.core import statevector as sv
from repro.core.peps import to_statevector

vec = to_statevector(qstate)
print("exact                =", complex(sv.expectation(vec, H.as_tuples())))
