"""End-to-end driver 3: random-quantum-circuit amplitude via approximate
PEPS contraction (paper Section VI-B, Fig. 10).

Evolves a 4x4 PEPS exactly through 8 RQC layers (bond 16), then contracts
one amplitude with BMPS and IBMPS at increasing chi, against the exact
statevector value.  ``--engine both`` additionally contracts every chi with
the variational boundary engine and prints the zip-up vs variational error
gap at equal chi (the accuracy-per-FLOP trade of docs/contraction.md).

    PYTHONPATH=src python examples/rqc_amplitude.py [--engine both]
"""
import argparse

import numpy as np

from repro.core import bmps as B
from repro.core import statevector as sv
from repro.core.circuits import (apply_circuit_exact_peps,
                                 apply_circuit_statevector, random_circuit)
from repro.core.peps import computational_zeros
from repro.core.einsumsvd import DirectSVD, RandomizedSVD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("zipup", "variational", "both"),
                    default="zipup",
                    help="boundary engine; 'both' prints the zip-up vs "
                         "variational error gap at equal chi")
    args = ap.parse_args()

    n, layers = 4, 8
    circ = random_circuit(n, n, layers, seed=7)
    print(f"{n}x{n} RQC, {layers} layers, {len(circ)} gates")

    state = apply_circuit_exact_peps(computational_zeros(n, n), circ)
    print(f"exact PEPS evolution: bond dimension {state.max_bond()}")

    vec = apply_circuit_statevector(sv.zeros(n * n), circ)
    bits = np.zeros((n, n), dtype=int)
    exact = complex(vec[(0,) * (n * n)])
    print(f"exact amplitude <0...0|psi> = {exact:.6e}")

    engines = (("zipup", "variational") if args.engine == "both"
               else (args.engine,))
    for chi in (4, 8, 16, 32):
        errs = {}
        for eng in engines:
            a_b = complex(B.amplitude(state, bits,
                                      B.BMPS(chi, DirectSVD(), engine=eng)))
            a_i = complex(B.amplitude(
                state, bits,
                B.BMPS(chi, RandomizedSVD(niter=4, oversample=8), engine=eng)))
            errs[eng] = abs(a_b - exact) / abs(exact)
            print(f"  chi={chi:3d} [{eng:11s}]: BMPS err {errs[eng]:.2e}   "
                  f"IBMPS err {abs(a_i-exact)/abs(exact):.2e}")
        if len(errs) == 2 and errs["variational"] > 0:
            gap = errs["zipup"] / errs["variational"]
            print(f"  chi={chi:3d} error gap: zipup/variational = x{gap:.1f}")


if __name__ == "__main__":
    main()
