"""End-to-end driver 3: random-quantum-circuit amplitude via approximate
PEPS contraction (paper Section VI-B, Fig. 10).

Evolves a 4x4 PEPS exactly through 8 RQC layers (bond 16), then serves a
batch of amplitudes sharing a bit prefix through the query serving engine
(``repro.core.serving``) at increasing chi, against the exact statevector
values.  The chi sweep reuses each state's cached prefix environments, so
besides the BMPS error column the driver prints the per-query speedup of
batched+cached serving over the per-query ``bmps.amplitude`` loop.
``--engine both`` additionally sweeps the variational boundary engine and
prints the zip-up vs variational error gap at equal chi (the
accuracy-per-FLOP trade of docs/contraction.md).

    PYTHONPATH=src python examples/rqc_amplitude.py [--engine both]
"""
import argparse
import time

import numpy as np

from repro.core import bmps as B
from repro.core import statevector as sv
from repro.core.circuits import (apply_circuit_exact_peps,
                                 apply_circuit_statevector, random_circuit)
from repro.core.peps import computational_zeros
from repro.core.einsumsvd import DirectSVD, RandomizedSVD
from repro.core.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("zipup", "variational", "both"),
                    default="zipup",
                    help="boundary engine; 'both' prints the zip-up vs "
                         "variational error gap at equal chi")
    args = ap.parse_args()

    n, layers = 4, 8
    circ = random_circuit(n, n, layers, seed=7)
    print(f"{n}x{n} RQC, {layers} layers, {len(circ)} gates")

    state = apply_circuit_exact_peps(computational_zeros(n, n), circ)
    print(f"exact PEPS evolution: bond dimension {state.max_bond()}")

    vec = apply_circuit_statevector(sv.zeros(n * n), circ)

    # a batch of queries sharing the all-zeros row prefix: <0..0 f|psi> for
    # every final-row bitstring f — the serving cache pays the prefix sweep
    # once per (chi, engine) and closes all 2^n final rows in one batch.
    finals = np.array([[(k >> j) & 1 for j in range(n)]
                       for k in range(2 ** n)])
    bits_batch = np.concatenate(
        [np.zeros((2 ** n, n - 1, n), dtype=int), finals[:, None, :]], axis=1)
    exact = np.array([complex(vec[tuple(b.reshape(-1))]) for b in bits_batch])
    print(f"exact amplitude <0...0|psi> = {exact[0]:.6e} "
          f"(+ {len(exact) - 1} more final-row queries)")

    engines = (("zipup", "variational") if args.engine == "both"
               else (args.engine,))
    chis = (4, 8, 16, 32)
    with ServingEngine(start=False, max_states=len(chis) * len(engines)) \
            as served:
        for chi in chis:
            errs = {}
            for eng in engines:
                opt = B.BMPS(chi, DirectSVD(), engine=eng)
                name = f"rqc-chi{chi}-{eng}"
                served.register_state(name, state, opt)
                served.amplitude_batch(name, bits_batch)  # warm cache+compile
                t0 = time.perf_counter()
                amps = np.asarray(served.amplitude_batch(name, bits_batch))
                t_served = (time.perf_counter() - t0) / len(bits_batch)

                B.amplitude(state, bits_batch[0], opt)  # compile warmup
                t0 = time.perf_counter()
                direct = [complex(B.amplitude(state, b, opt))
                          for b in bits_batch]
                t_direct = (time.perf_counter() - t0) / len(bits_batch)

                a_i = complex(B.amplitude(
                    state, bits_batch[0],
                    B.BMPS(chi, RandomizedSVD(niter=4, oversample=8),
                           engine=eng)))
                errs[eng] = abs(amps[0] - exact[0]) / abs(exact[0])
                batch_err = np.max(np.abs(amps - exact) / np.abs(exact))
                gap_vs_direct = np.max(np.abs(amps - np.asarray(direct)))
                print(f"  chi={chi:3d} [{eng:11s}]: BMPS err {errs[eng]:.2e} "
                      f"(batch max {batch_err:.2e})   "
                      f"IBMPS err {abs(a_i-exact[0])/abs(exact[0]):.2e}")
                print(f"  chi={chi:3d} [{eng:11s}]: served {t_served*1e3:.2f}"
                      f"ms/query vs per-query {t_direct*1e3:.2f}ms "
                      f"-> x{t_direct/max(t_served, 1e-12):.1f} "
                      f"(|served-direct| max {gap_vs_direct:.1e})")
            if len(errs) == 2 and errs["variational"] > 0:
                gap = errs["zipup"] / errs["variational"]
                print(f"  chi={chi:3d} error gap: zipup/variational = x{gap:.1f}")


if __name__ == "__main__":
    main()
