"""End-to-end driver 3: random-quantum-circuit amplitude via approximate
PEPS contraction (paper Section VI-B, Fig. 10).

Evolves a 4x4 PEPS exactly through 8 RQC layers (bond 16), then contracts
one amplitude with BMPS and IBMPS at increasing chi, against the exact
statevector value.

    PYTHONPATH=src python examples/rqc_amplitude.py
"""
import numpy as np

from repro.core import bmps as B
from repro.core import statevector as sv
from repro.core.circuits import (apply_circuit_exact_peps,
                                 apply_circuit_statevector, random_circuit)
from repro.core.peps import computational_zeros
from repro.core.einsumsvd import DirectSVD, RandomizedSVD


def main():
    n, layers = 4, 8
    circ = random_circuit(n, n, layers, seed=7)
    print(f"{n}x{n} RQC, {layers} layers, {len(circ)} gates")

    state = apply_circuit_exact_peps(computational_zeros(n, n), circ)
    print(f"exact PEPS evolution: bond dimension {state.max_bond()}")

    vec = apply_circuit_statevector(sv.zeros(n * n), circ)
    bits = np.zeros((n, n), dtype=int)
    exact = complex(vec[(0,) * (n * n)])
    print(f"exact amplitude <0...0|psi> = {exact:.6e}")

    for chi in (4, 8, 16, 32):
        a_b = complex(B.amplitude(state, bits, B.BMPS(chi, DirectSVD())))
        a_i = complex(B.amplitude(state, bits,
                                  B.BMPS(chi, RandomizedSVD(niter=4, oversample=8))))
        print(f"  chi={chi:3d}: BMPS err {abs(a_b-exact)/abs(exact):.2e}   "
              f"IBMPS err {abs(a_i-exact)/abs(exact):.2e}")


if __name__ == "__main__":
    main()
