"""End-to-end driver 4: train a ~100M-param LM for a few hundred steps on
the synthetic pipeline, with checkpointing and auto-resume.

This wraps launch/train.py with a near-100M dense config (a smollm-family
model) sized for CPU execution.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12L x 768d x 12H, vocab 32k — GPT-2-small-ish, in the
    # smollm (llama) family; full fidelity training loop, CPU-sized batch.
    import repro.configs as configs
    from repro.models.common import Config
    import jax.numpy as jnp

    cfg = Config(name="demo-100m", family="dense", n_layers=12, d_model=768,
                 n_heads=12, n_kv_heads=12, d_head=64, d_ff=2048, vocab=32000,
                 param_dtype=jnp.float32, act_dtype=jnp.float32, remat=False)
    # register it so launch/train.py can find it
    configs._MODULES["demo-100m"] = None
    configs.get = (lambda orig: (lambda name: cfg if name == "demo-100m"
                                 else orig(name)))(configs.get)

    train_mod.main([
        "--arch", "demo-100m", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--lr", "3e-4",
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "100",
    ])


if __name__ == "__main__":
    main()
