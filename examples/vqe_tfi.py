"""End-to-end driver 2: VQE on the ferromagnetic transverse-field Ising
model (paper Section VI-D2, Fig. 14) — SLSQP over the Ry+CNOT ansatz with
PEPS-simulated energies.

    PYTHONPATH=src python examples/vqe_tfi.py [--grid 2] [--bond 2]
"""
import argparse

from repro.core.observable import tfi_hamiltonian
from repro.core.vqe import run_vqe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=2)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--bond", type=int, default=2)
    ap.add_argument("--maxiter", type=int, default=30)
    args = ap.parse_args()

    n = args.grid
    obs = tfi_hamiltonian(n, n, jz=-1.0, hx=-3.5)  # paper Fig. 14 setting
    print(f"TFI model on {n}x{n} (Jz=-1, hx=-3.5), "
          f"{args.layers}-layer Ry+CNOT ansatz")

    ref = run_vqe(n, n, obs, n_layers=args.layers, max_bond=4,
                  maxiter=args.maxiter, backend="statevector")
    print(f"statevector VQE: E = {ref.energy:.5f}  ({ref.n_evals} evals)")

    res = run_vqe(n, n, obs, n_layers=args.layers, max_bond=args.bond,
                  maxiter=args.maxiter)
    print(f"PEPS VQE (bond {args.bond}): E = {res.energy:.5f}  "
          f"({res.n_evals} evals)")


if __name__ == "__main__":
    main()
