"""End-to-end driver 2: VQE on the ferromagnetic transverse-field Ising
model (paper Section VI-D2, Fig. 14) — PEPS-simulated energies over the
Ry+CNOT ansatz.

    PYTHONPATH=src python examples/vqe_tfi.py [--grid 2] [--bond 2]

``--method`` picks the optimizer (see docs/vqe.md): ``SLSQP`` is the
paper's gradient-free reference; ``adam`` follows the exact JAX gradient
through the PEPS contraction; ``spsa`` is the stochastic 2-point baseline.
``--ensemble k`` (adam/spsa) advances k independently-seeded circuits in
one compiled vmapped program, e.g.

    PYTHONPATH=src python examples/vqe_tfi.py --method adam --ensemble 8
"""
import argparse

from repro.core.observable import tfi_hamiltonian
from repro.core.vqe import run_vqe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=2)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--bond", type=int, default=2)
    ap.add_argument("--maxiter", type=int, default=30)
    ap.add_argument("--method", default="SLSQP",
                    choices=["SLSQP", "adam", "spsa"])
    ap.add_argument("--ensemble", type=int, default=1,
                    help="parameter sets advanced in one vmapped program "
                         "(adam/spsa only)")
    ap.add_argument("--lr", type=float, default=0.05,
                    help="adam learning rate")
    args = ap.parse_args()

    n = args.grid
    obs = tfi_hamiltonian(n, n, jz=-1.0, hx=-3.5)  # paper Fig. 14 setting
    print(f"TFI model on {n}x{n} (Jz=-1, hx=-3.5), "
          f"{args.layers}-layer Ry+CNOT ansatz")

    ref = run_vqe(n, n, obs, n_layers=args.layers, max_bond=4,
                  maxiter=args.maxiter, backend="statevector")
    print(f"statevector VQE: E = {ref.energy:.5f}  ({ref.n_evals} evals)")

    res = run_vqe(n, n, obs, n_layers=args.layers, max_bond=args.bond,
                  maxiter=args.maxiter, method=args.method,
                  ensemble=args.ensemble, lr=args.lr)
    tag = f"{args.method}, ensemble {args.ensemble}" if args.ensemble > 1 \
        else args.method
    print(f"PEPS VQE (bond {args.bond}, {tag}): E = {res.energy:.5f}  "
          f"({res.n_evals} evals)")
    if res.ensemble_energies is not None:
        print("ensemble final energies:",
              " ".join(f"{e:.5f}" for e in res.ensemble_energies))


if __name__ == "__main__":
    main()
