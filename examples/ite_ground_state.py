"""End-to-end driver 1: ground state of the J1-J2 model via imaginary time
evolution (paper Section VI-D1, Fig. 13).

Demonstrates both truncation tiers: the QR simple update (``--update qr``,
paper Alg. 1) and the environment-aware full update (``--update full``,
Lubasch et al. arXiv:1405.3259).  The default ``--update both`` runs the
two back to back at the same bond dimension and Trotter schedule and
prints the energy-error gap — the accuracy the neighborhood environment
buys at fixed D.

    PYTHONPATH=src python examples/ite_ground_state.py [--grid 3] [--steps 80]
"""
import argparse

from repro.core import bmps as B
from repro.core.ite import ite_run, ite_statevector
from repro.core.observable import j1j2_hamiltonian
from repro.core.peps import FullUpdate, QRUpdate, computational_zeros
from repro.core.einsumsvd import RandomizedSVD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=3)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--tau", type=float, default=0.05)
    ap.add_argument("--bond", type=int, default=2)
    ap.add_argument("--chi", type=int, default=8)
    ap.add_argument("--update", choices=("qr", "full", "both"), default="both",
                    help="two-site truncation: QR simple update, "
                         "environment-aware full update, or both (A/B)")
    ap.add_argument("--env-refresh", type=int, default=None,
                    help="full update: gate applications between row-"
                         "environment refreshes (default: once per step)")
    ap.add_argument("--engine", choices=("zipup", "variational"),
                    default="zipup",
                    help="boundary engine for the evolution-time energy "
                         "measurements; the final state is always re-"
                         "measured with BOTH engines at equal chi and the "
                         "error gap printed")
    args = ap.parse_args()

    n = args.grid
    obs = j1j2_hamiltonian(n, n)  # J1=1.0, J2=0.5, h=0.2 (paper Fig. 13)
    print(f"J1-J2 model on {n}x{n}: {len(obs)} local terms")

    _, e_ref = ite_statevector(n, n, obs, args.tau, steps=2 * args.steps)
    print(f"statevector ITE reference energy: {e_ref:.6f}")

    cadence = args.env_refresh if args.env_refresh is not None else len(obs)
    updates = {
        "qr": QRUpdate(rank=args.bond),
        "full": FullUpdate(rank=args.bond, chi=max(2 * args.chi, 8),
                           env_refresh_every=cadence),
    }
    names = ("qr", "full") if args.update == "both" else (args.update,)

    errors = {}
    for name in names:
        print(f"-- update={name!r}")

        def progress(step, energy, state):
            print(f"  step {step:4d}  E = {energy:.6f}  "
                  f"(err {abs(energy-e_ref)/abs(e_ref):.2e})")

        res = ite_run(
            computational_zeros(n, n), obs, args.tau, args.steps,
            update=updates[name],
            contract=B.BMPS(args.chi, RandomizedSVD(niter=2, oversample=4),
                            engine=args.engine),
            measure_every=max(args.steps // 8, 1), callback=progress)
        errors[name] = abs(res.energies[-1] - e_ref) / abs(e_ref)
        # engine A/B on the converged state: same chi, same key — the gap is
        # purely the boundary-absorption strategy (greedy vs ALS-fitted)
        from repro.core.expectation import expectation
        by_engine = {
            eng: float(expectation(res.state, obs,
                                   B.BMPS(args.chi, engine=eng)).real)
            for eng in ("zipup", "variational")}
        gaps = {eng: abs(e - e_ref) / abs(e_ref)
                for eng, e in by_engine.items()}
        print(f"  energy measured at chi={args.chi}: "
              f"zipup err {gaps['zipup']:.3e} vs "
              f"variational err {gaps['variational']:.3e}")
        line = (f"update={name!r} (r={args.bond}, chi={args.chi}) final "
                f"energy: {res.energies[-1]:.6f} vs reference {e_ref:.6f}")
        if res.fidelities:
            line += f"  [min bond fidelity {min(res.fidelities):.6f}]"
        print(line)

    if len(errors) == 2:
        gap = errors["qr"] / max(errors["full"], 1e-300)
        verdict = (f"full update is x{gap:.1f} more accurate" if gap >= 1.0
                   else f"full update is x{1.0 / gap:.1f} LESS accurate "
                        "(unexpected: try more steps or a tighter "
                        "--env-refresh)")
        print(f"\nenergy-error gap at D={args.bond}: "
              f"qr {errors['qr']:.3e} vs full {errors['full']:.3e} "
              f"-> {verdict}")


if __name__ == "__main__":
    main()
