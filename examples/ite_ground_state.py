"""End-to-end driver 1: ground state of the J1-J2 model via imaginary time
evolution (paper Section VI-D1, Fig. 13).

    PYTHONPATH=src python examples/ite_ground_state.py [--grid 3] [--steps 80]
"""
import argparse

from repro.core import bmps as B
from repro.core.ite import ite_run, ite_statevector
from repro.core.observable import j1j2_hamiltonian
from repro.core.peps import QRUpdate, computational_zeros
from repro.core.einsumsvd import RandomizedSVD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=3)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--tau", type=float, default=0.05)
    ap.add_argument("--bond", type=int, default=2)
    ap.add_argument("--chi", type=int, default=8)
    args = ap.parse_args()

    n = args.grid
    obs = j1j2_hamiltonian(n, n)  # J1=1.0, J2=0.5, h=0.2 (paper Fig. 13)
    print(f"J1-J2 model on {n}x{n}: {len(obs)} local terms")

    _, e_ref = ite_statevector(n, n, obs, args.tau, steps=2 * args.steps)
    print(f"statevector ITE reference energy: {e_ref:.6f}")

    def progress(step, energy, state):
        print(f"  step {step:4d}  E = {energy:.6f}  "
              f"(err {abs(energy-e_ref)/abs(e_ref):.2e})")

    res = ite_run(
        computational_zeros(n, n), obs, args.tau, args.steps,
        update=QRUpdate(rank=args.bond),
        contract=B.BMPS(args.chi, RandomizedSVD(niter=2, oversample=4)),
        measure_every=max(args.steps // 8, 1), callback=progress)
    print(f"PEPS ITE (r={args.bond}, chi={args.chi}) final energy: "
          f"{res.energies[-1]:.6f} vs reference {e_ref:.6f}")


if __name__ == "__main__":
    main()
