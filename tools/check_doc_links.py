#!/usr/bin/env python
"""Doc hygiene: fail on broken intra-repo links in docs/ and README.md.

Scans markdown files for inline links/images ``[text](target)`` and
reference definitions ``[label]: target`` and verifies that every
*relative* target resolves to an existing file or directory (anchors and
query strings are stripped; ``http(s)://``, ``mailto:`` and pure-anchor
links are ignored).  Used by CI and ``make docs-check`` — a link that rots
when a module or doc moves should fail the build, not a reader.

Exit status: 0 when clean, 1 with a per-link report otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCAN = [REPO / "README.md", *sorted((REPO / "docs").glob("**/*.md"))]

# inline [text](target) — tolerates one level of nested () in the target;
# images share the syntax (the leading ! is irrelevant to the target check)
_INLINE = re.compile(r"\[[^\]]*\]\(\s*(<[^>]*>|[^()\s]+(?:\([^()]*\)[^()\s]*)*)\s*\)")
# reference definitions: [label]: target
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_EXTERNAL = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")  # any URI scheme


def _strip_code(text: str) -> str:
    """Drop fenced and inline code spans — links there are examples."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def iter_links(text: str):
    for m in _INLINE.finditer(text):
        yield m.group(1).strip("<>")
    for m in _REFDEF.finditer(text):
        yield m.group(1).strip("<>")


def check_file(path: Path) -> list:
    broken = []
    for target in iter_links(_strip_code(path.read_text())):
        if not target or target.startswith("#") or _EXTERNAL.match(target):
            continue
        rel = target.split("#", 1)[0].split("?", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            broken.append((target, resolved))
    return broken


def main() -> int:
    missing_docs = [p for p in SCAN if not p.exists()]
    all_broken = []
    for path in SCAN:
        if not path.exists():
            continue
        for target, resolved in check_file(path):
            all_broken.append((path.relative_to(REPO), target, resolved))
    for path, target, resolved in all_broken:
        print(f"BROKEN  {path}: ({target}) -> {resolved}", file=sys.stderr)
    for path in missing_docs:
        print(f"MISSING {path.relative_to(REPO)}", file=sys.stderr)
    n = len(SCAN) - len(missing_docs)
    if all_broken or missing_docs:
        print(f"doc-link check FAILED: {len(all_broken)} broken link(s) "
              f"across {n} file(s)", file=sys.stderr)
        return 1
    print(f"doc-link check OK: {n} file(s) clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
