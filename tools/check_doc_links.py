#!/usr/bin/env python
"""Doc hygiene: fail on broken intra-repo links and stale code references.

Two checks over docs/ and README.md, both static (no repo imports — the CI
doc job installs nothing):

1. **Links** — inline links/images ``[text](target)`` and reference
   definitions ``[label]: target``: every *relative* target must resolve to
   an existing file or directory (anchors and query strings are stripped;
   ``http(s)://``, ``mailto:`` and pure-anchor links are ignored).
2. **Code references** — inline code spans that name repo code must still
   resolve, because prose references are the main doc-rot vector now that
   the docs span many files:

   * path-like spans (``core/spmd.py``, ``tests/test_spmd.py::test_x``,
     ``docs/contraction.md``) must exist under the repo root, ``src/`` or
     ``src/repro/`` (``::symbol`` additionally checked via ast);
   * dotted spans whose first component is a repro module or package
     (``repro.core.spmd``, ``bmps.zipup_block``, ``planner.fused_fn``)
     must resolve to that module, and a trailing lowercase attribute must
     be a module-level name (checked by parsing the module with ``ast`` —
     never by importing).  Spans starting with anything else (``jax.*``,
     ``np.*``, local variables) are ignored, as are capitalized
     attributes (class members are out of scope for a static check).

Used by CI (doc-hygiene job) and ``make docs-check``.
Exit status: 0 when clean, 1 with a per-reference report otherwise.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCAN = [REPO / "README.md", *sorted((REPO / "docs").glob("**/*.md"))]
SRC = REPO / "src" / "repro"

# inline [text](target) — tolerates one level of nested () in the target;
# images share the syntax (the leading ! is irrelevant to the target check)
_INLINE = re.compile(r"\[[^\]]*\]\(\s*(<[^>]*>|[^()\s]+(?:\([^()]*\)[^()\s]*)*)\s*\)")
# reference definitions: [label]: target
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
_EXTERNAL = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")  # any URI scheme


def _strip_code(text: str) -> str:
    """Drop fenced and inline code spans — links there are examples."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def iter_links(text: str):
    for m in _INLINE.finditer(text):
        yield m.group(1).strip("<>")
    for m in _REFDEF.finditer(text):
        yield m.group(1).strip("<>")


def check_file(path: Path) -> list:
    broken = []
    for target in iter_links(_strip_code(path.read_text())):
        if not target or target.startswith("#") or _EXTERNAL.match(target):
            continue
        rel = target.split("#", 1)[0].split("?", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            broken.append((target, resolved))
    return broken


# ---------------------------------------------------------------------------
# Code-reference checking (inline `code` spans)
# ---------------------------------------------------------------------------

_CODE_SPAN = re.compile(r"`([^`\n]+)`")
# a path-like ref: dir/file.py or file.md, optional ::symbol suffix
_PATH_REF = re.compile(r"^([\w./-]+\.(?:py|md))(?:::(\w+))?$")
# a dotted ref: module.attr[.attr...], optionally with trailing ()
_DOTTED_REF = re.compile(r"^([A-Za-z_]\w*(?:\.[A-Za-z_]\w*)+)(?:\(\))?$")

# roots a bare relative path may live under, in resolution order
_PATH_ROOTS = (REPO, REPO / "src", SRC)


def _module_index():
    """Map basename and dotted names of every repro module/package to its
    file, e.g. 'bmps' / 'repro.core.bmps' -> src/repro/core/bmps.py.
    Ambiguous basenames map to None (never checkable by basename alone)."""
    index = {}

    def add(key, path):
        index[key] = None if key in index and index[key] != path else path

    for py in SRC.rglob("*.py"):
        rel = py.relative_to(SRC.parent)
        dotted = ".".join(rel.with_suffix("").parts)
        if py.name == "__init__.py":
            dotted = ".".join(rel.parent.parts)
            add(dotted, py)
            add(rel.parent.name, py)
            continue
        add(dotted, py)
        add(py.stem, py)
    return index


def _module_symbols(py: Path):
    """Module-level names of a python file, via ast (no import)."""
    try:
        tree = ast.parse(py.read_text())
    except SyntaxError:
        return None
    names = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                names.add(a.asname or a.name.split(".")[0])
    return names


def _check_path_ref(ref: str, sym) -> bool:
    for root in _PATH_ROOTS:
        p = (root / ref)
        if p.exists():
            if sym and p.suffix == ".py":
                names = _module_symbols(p)
                return names is None or sym in names
            return True
    return False


def check_code_refs(path: Path, index) -> list:
    """Stale path-like / dotted code references in ``path``'s inline code."""
    text = re.sub(r"```.*?```", "", path.read_text(), flags=re.DOTALL)
    stale = []
    for m in _CODE_SPAN.finditer(text):
        span = m.group(1).strip()
        pm = _PATH_REF.match(span)
        if pm:
            if not _check_path_ref(pm.group(1), pm.group(2)):
                stale.append(span)
            continue
        dm = _DOTTED_REF.match(span)
        if not dm:
            continue
        parts = dm.group(1).split(".")
        # longest prefix that names a known module wins; unknown first
        # components (jax, np, local variables) are out of scope
        py = None
        rest = []
        for cut in range(len(parts), 0, -1):
            hit = index.get(".".join(parts[:cut]))
            if hit is not None:
                py, rest = hit, parts[cut:]
                break
        if py is None:
            if parts[0] in index:  # ambiguous basename: skip, not stale
                continue
            if parts[0] == "repro":  # claims to be ours but is not
                stale.append(span)
            continue
        if not rest:
            continue
        if len(rest) > 1 or not rest[0][0].islower():
            continue  # class attributes / nested chains: out of scope
        names = _module_symbols(py)
        if names is not None and rest[0] not in names:
            stale.append(span)
    return stale


def main() -> int:
    missing_docs = [p for p in SCAN if not p.exists()]
    index = _module_index()
    all_broken, all_stale = [], []
    for path in SCAN:
        if not path.exists():
            continue
        for target, resolved in check_file(path):
            all_broken.append((path.relative_to(REPO), target, resolved))
        for span in check_code_refs(path, index):
            all_stale.append((path.relative_to(REPO), span))
    for path, target, resolved in all_broken:
        print(f"BROKEN  {path}: ({target}) -> {resolved}", file=sys.stderr)
    for path, span in all_stale:
        print(f"STALE   {path}: `{span}` does not resolve against src/repro",
              file=sys.stderr)
    for path in missing_docs:
        print(f"MISSING {path.relative_to(REPO)}", file=sys.stderr)
    n = len(SCAN) - len(missing_docs)
    if all_broken or all_stale or missing_docs:
        print(f"doc-link check FAILED: {len(all_broken)} broken link(s), "
              f"{len(all_stale)} stale code reference(s) across {n} file(s)",
              file=sys.stderr)
        return 1
    print(f"doc-link check OK: {n} file(s) clean (links + code references)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
