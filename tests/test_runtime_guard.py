"""Runtime guard: fault-injection registry, the escalation ladder, the
full-update fidelity floor, and the persistent planner path cache."""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults, planner, runtime_guard
from repro.core.bmps import BMPS
from repro.core.einsumsvd import DirectSVD, RandomizedSVD, einsumsvd
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import FullUpdate, computational_zeros
from repro.core.precision import wrap_svd
from repro.kernels import dispatch


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    yield
    faults.clear()


def _solve(option, key=None, rank=8, dtype=jnp.float32):
    k = jax.random.PRNGKey(7)
    t = jax.random.normal(k, (64, 32), dtype=dtype)
    return einsumsvd(option, [t], ["ab"], "a", "b", rank,
                     absorb="none", key=key if key is not None else k)


# ---------------------------------------------------------------------------
# The fault registry itself
# ---------------------------------------------------------------------------

class TestFaultRegistry:
    def test_fires_on_exactly_the_nth_call(self):
        faults.arm("x", nth=3)
        assert faults.should_fire("x") is None
        assert faults.should_fire("x") is None
        spec = faults.should_fire("x")
        assert spec is not None and spec.fired == 1
        assert faults.should_fire("x") is None    # one-shot by default

    def test_times_fires_a_contiguous_window(self):
        faults.arm("x", nth=2, times=2)
        hits = [faults.should_fire("x") is not None for _ in range(5)]
        assert hits == [False, True, True, False, False]

    def test_rearm_resets_the_call_counter(self):
        faults.arm("x", nth=1)
        assert faults.should_fire("x") is not None
        faults.arm("x", nth=1)
        assert faults.should_fire("x") is not None

    def test_unarmed_site_is_a_noop(self):
        assert faults.should_fire("never-armed") is None

    def test_armed_context_disarms_on_exit(self):
        with faults.armed("x"):
            assert "x" in faults.active()
        assert "x" not in faults.active()

    def test_bad_spec_rejected(self):
        with pytest.raises(ValueError):
            faults.arm("x", nth=0)
        with pytest.raises(ValueError):
            faults.arm("x", times=0)


# ---------------------------------------------------------------------------
# Detection + the escalation ladder
# ---------------------------------------------------------------------------

class TestGuardLadder:
    def test_unguarded_corruption_propagates(self):
        """Without an active guard the library behaves exactly as before:
        an injected NaN flows through to the caller."""
        with faults.armed("einsumsvd.result", action="nan"):
            u, s, v = _solve(DirectSVD())
        assert np.isnan(np.asarray(s)).any()

    def test_nan_recovers_on_the_exact_svd_rung(self):
        before = planner.stats()
        with faults.armed("einsumsvd.result", action="nan"):
            with runtime_guard.RuntimeGuard() as g:
                u, s, v = _solve(RandomizedSVD())
        assert np.isfinite(np.asarray(s)).all()
        actions = [e.action for e in g.report.events]
        assert actions == ["detected", "retry:exact_svd",
                           "recovered:exact_svd"]
        assert g.report.ok
        delta = planner.stats_since(before)
        assert delta["guard_nan_events"] == 1
        assert delta["guard_rung_exact_svd"] == 1
        assert delta["guard_recovered"] == 1

    def test_recovery_is_within_the_exact_budget(self):
        """The exact-SVD rung is deterministic LAPACK: the recovered
        spectrum must match a clean DirectSVD solve to the exact-tier
        budget (core/precision.py: 1e-12)."""
        from repro.core.precision import error_budget
        _, s_clean, _ = _solve(DirectSVD())
        with faults.armed("einsumsvd.result", action="nan"):
            with runtime_guard.RuntimeGuard():
                _, s_rec, _ = _solve(RandomizedSVD())
        rel = (np.linalg.norm(np.asarray(s_rec) - np.asarray(s_clean))
               / np.linalg.norm(np.asarray(s_clean)))
        assert rel <= error_budget("contract_onelayer", "exact")

    def test_collapse_detected_and_recovered(self):
        with faults.armed("einsumsvd.result", action="zero"):
            with runtime_guard.RuntimeGuard() as g:
                u, s, v = _solve(RandomizedSVD())
        assert float(np.max(np.abs(np.asarray(s)))) > 0
        assert g.report.causes() == {"collapse": 1}

    def test_mixed_precision_escalates_to_exact_precision(self):
        """Two consecutive corrupted solves climb past exact_svd to the
        precision-unwrap rung (mixed -> exact)."""
        opt = wrap_svd(RandomizedSVD(), "mixed")
        with faults.armed("einsumsvd.result", action="nan", times=2):
            with runtime_guard.RuntimeGuard() as g:
                u, s, v = _solve(opt, dtype=jnp.float64)
        assert np.isfinite(np.asarray(s)).all()
        actions = [e.action for e in g.report.events]
        assert "retry:exact_precision" in actions
        assert actions[-1] == "recovered:exact_precision"
        assert g.report.counters["guard_rung_exact_precision"] == 1

    def test_kernel_fault_takes_the_dense_rung_first(self):
        """A raising kernel site retries dense-first (keeping the original
        solver) and restores the per-site mode afterwards."""
        planner.clear()    # cached fused executables skip Python dispatch
        prev = dispatch.set_kernel_backend("pallas", site="gram")
        try:
            with faults.armed("kernel.gram", times=99):
                with runtime_guard.RuntimeGuard() as g:
                    u, s, v = _solve(RandomizedSVD())
        finally:
            dispatch.set_kernel_backend("auto")
        assert np.isfinite(np.asarray(s)).all()
        actions = [e.action for e in g.report.events]
        assert actions == ["detected", "retry:dense_kernel",
                           "recovered:dense_kernel"]
        assert g.report.causes() == {"exception": 1}

    def test_kernel_fault_unguarded_raises_injected_fault(self):
        planner.clear()
        dispatch.set_kernel_backend("pallas", site="gram")
        try:
            with faults.armed("kernel.gram"):
                with pytest.raises(faults.InjectedFault) as ei:
                    _solve(RandomizedSVD())
            assert ei.value.site == "kernel.gram"
        finally:
            dispatch.set_kernel_backend("auto")

    def test_exhausted_ladder_raises_structured_never_nan(self):
        with faults.armed("einsumsvd.result", action="nan", times=99):
            with runtime_guard.RuntimeGuard() as g:
                with pytest.raises(runtime_guard.GuardExhaustedError) as ei:
                    _solve(RandomizedSVD())
        err = ei.value
        assert err.site == "einsumsvd" and err.cause == "nan"
        assert err.attempts >= 1 and err.events
        assert not g.report.ok
        assert g.report.counters["guard_exhausted"] == 1

    def test_max_retries_bounds_the_ladder(self):
        cfg = runtime_guard.GuardConfig(max_retries=1)
        with faults.armed("einsumsvd.result", action="nan", times=99):
            with runtime_guard.RuntimeGuard(cfg) as g:
                with pytest.raises(runtime_guard.GuardExhaustedError) as ei:
                    _solve(RandomizedSVD())
        assert ei.value.attempts == 1

    def test_resolve_accepts_the_documented_forms(self):
        assert runtime_guard.resolve(None) is None
        assert runtime_guard.resolve(False) is None
        assert isinstance(runtime_guard.resolve(True), runtime_guard.RuntimeGuard)
        cfg = runtime_guard.GuardConfig(max_retries=7)
        assert runtime_guard.resolve(cfg).config.max_retries == 7
        g = runtime_guard.RuntimeGuard()
        assert runtime_guard.resolve(g) is g
        with pytest.raises(TypeError):
            runtime_guard.resolve("yes")

    def test_counters_surface_in_planner_stats(self):
        s = planner.stats()
        for k in ("guard_nan_events", "guard_recovered", "guard_exhausted",
                  "guard_rung_dense_kernel"):
            assert k in s


# ---------------------------------------------------------------------------
# Full-update fidelity floor
# ---------------------------------------------------------------------------

def _tiny_full_ite(guard, steps=1):
    from repro.core.ite import ite_run
    obs = tfi_hamiltonian(2, 2)
    st = computational_zeros(2, 2)
    return ite_run(st, obs, 0.05, steps, FullUpdate(rank=2, chi=8),
                   BMPS(8), measure_every=1, guard=guard)


class TestFidelityFloor:
    def test_degraded_accepted_warns_and_continues(self):
        cfg = runtime_guard.GuardConfig(fidelity_floor=1.5)  # unreachable
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            res = _tiny_full_ite(cfg)
        assert any("fidelity" in str(x.message) for x in w)
        assert res.guard is not None
        assert res.guard.counters.get("guard_fidelity_events", 0) >= 1
        assert res.guard.counters.get("guard_degraded_accepted", 0) >= 1
        assert all(np.isfinite(e) for e in res.energies)
        assert res.guard.ok    # degraded != exhausted

    def test_strict_floor_raises_structured(self):
        cfg = runtime_guard.GuardConfig(fidelity_floor=1.5,
                                        fidelity_strict=True)
        with pytest.raises(runtime_guard.GuardExhaustedError) as ei:
            _tiny_full_ite(cfg)
        assert ei.value.site == "full_update"
        assert ei.value.cause == "fidelity"

    def test_clean_run_has_an_empty_report(self):
        res = _tiny_full_ite(True)
        assert res.guard is not None and res.guard.ok
        assert res.guard.events == []


# ---------------------------------------------------------------------------
# Gradient-mode guarding (ISSUE 10): evaluation-granularity escalation
# ---------------------------------------------------------------------------

class TestGradModeGuard:
    """The per-solve guard host-syncs and cannot run under tracing, so the
    gradient path guards whole evaluations: a fault inside a grad-mode
    energy evaluation escalates through the ladder instead of surfacing as
    a NaN gradient (docs/vqe.md)."""

    def _grad(self, guard=None, svd=None):
        from repro.core.peps import QRUpdate
        from repro.core.vqe import vqe_energy_and_grad
        obs = tfi_hamiltonian(2, 2)
        upd = QRUpdate(rank=2) if svd is None else QRUpdate(rank=2, svd=svd)
        con = BMPS(8) if svd is None else BMPS(8, svd=svd)
        th = np.random.default_rng(1).uniform(-0.3, 0.3, 4)
        return vqe_energy_and_grad(th, 2, 2, obs, upd, con, guard=guard)

    def test_guarded_grad_recovers_finite(self):
        g = runtime_guard.RuntimeGuard()
        with faults.armed("einsumsvd.result", nth=1, action="nan", times=1):
            e, grad = self._grad(guard=g)
        assert np.isfinite(float(e))
        assert np.all(np.isfinite(np.asarray(grad)))
        assert g.report.counters.get("guard_nan_events", 0) == 1
        assert g.report.counters.get("guard_recovered", 0) == 1
        assert g.report.events[0].site == "vqe_grad"
        assert any(ev.action.startswith("recovered:")
                   for ev in g.report.events)

    def test_unguarded_grad_propagates_nan(self):
        with faults.armed("einsumsvd.result", nth=1, action="nan", times=1):
            e, grad = self._grad()
        assert not np.all(np.isfinite(np.asarray(grad)))

    def test_randomized_svd_takes_exact_svd_rung_first(self):
        g = runtime_guard.RuntimeGuard()
        svd = RandomizedSVD(niter=2, oversample=4)
        with faults.armed("einsumsvd.result", nth=1, action="nan", times=1):
            e, grad = self._grad(guard=g, svd=svd)
        assert np.all(np.isfinite(np.asarray(grad)))
        assert g.report.counters.get("guard_rung_exact_svd", 0) == 1

    def test_guarded_grad_exhaustion_is_structured(self):
        """A persistent fault (times larger than any rung count) exhausts
        the ladder as GuardExhaustedError — never a NaN result."""
        g = runtime_guard.RuntimeGuard()
        with faults.armed("einsumsvd.result", nth=1, action="nan",
                          times=10**6):
            with pytest.raises(runtime_guard.GuardExhaustedError) as ei:
                self._grad(guard=g)
        assert ei.value.site == "vqe_grad"
        assert g.report.counters.get("guard_exhausted", 0) == 1

    def test_guarded_batched_run_recovers(self):
        """The vmapped ensemble driver escalates at evaluation granularity
        too — fault-injected members never poison a compiled cache, and
        the run's report records the recovery."""
        from repro.core.vqe import run_vqe
        obs = tfi_hamiltonian(2, 2)
        with faults.armed("einsumsvd.result", nth=1, action="nan", times=1):
            r = run_vqe(2, 2, obs, n_layers=1, max_bond=2, maxiter=2,
                        seed=0, method="adam", ensemble=2, lr=0.1,
                        guard=True)
        assert np.isfinite(r.energy)
        assert np.all(np.isfinite(r.ensemble_history))
        assert r.guard is not None
        assert r.guard.counters.get("guard_recovered", 0) >= 1


# ---------------------------------------------------------------------------
# Persistent planner path cache
# ---------------------------------------------------------------------------

class TestPersistentPathCache:
    def _warm(self):
        k = jax.random.PRNGKey(0)
        a = jax.random.normal(k, (8, 8, 4))
        b = jax.random.normal(k, (4, 8, 8))
        return einsumsvd(RandomizedSVD(), [a, b], ["abk", "kcd"],
                         "ab", "cd", 6, key=k)

    def test_roundtrip_gives_zero_misses(self, tmp_path):
        planner.clear()
        self._warm()
        f = tmp_path / "paths.json"
        n = planner.save_path_cache(str(f))
        assert n == planner.stats()["path_cache_size"] > 0
        planner.clear()
        assert planner.load_path_cache(str(f)) == n
        before = planner.stats()
        self._warm()
        delta = planner.stats_since(before)
        assert delta["path_misses"] == 0
        assert delta["path_hits"] > 0
        assert planner.stats()["path_preloaded"] == n

    def test_missing_file_is_a_silent_cold_start(self, tmp_path):
        assert planner.load_path_cache(str(tmp_path / "nope.json")) == 0

    def test_truncated_file_warns_and_cold_starts(self, tmp_path):
        planner.clear()
        self._warm()
        f = tmp_path / "paths.json"
        planner.save_path_cache(str(f))
        f.write_text(f.read_text()[: f.stat().st_size // 2])
        planner.clear()
        with pytest.warns(RuntimeWarning, match="cold start"):
            assert planner.load_path_cache(str(f)) == 0

    def test_checksum_mismatch_rejected(self, tmp_path):
        planner.clear()
        self._warm()
        f = tmp_path / "paths.json"
        planner.save_path_cache(str(f))
        payload = json.loads(f.read_text())
        payload["entries"][0][0] = "zz->z"    # tamper without re-checksumming
        f.write_text(json.dumps(payload))
        planner.clear()
        with pytest.warns(RuntimeWarning, match="checksum"):
            assert planner.load_path_cache(str(f)) == 0

    def test_unknown_format_version_rejected(self, tmp_path):
        f = tmp_path / "paths.json"
        f.write_text(json.dumps({"format": 99, "checksum": "", "entries": []}))
        with pytest.warns(RuntimeWarning):
            assert planner.load_path_cache(str(f)) == 0
