"""Full-update ITE: parity with exact ITE, accuracy vs the simple update,
planner cache behavior, and dispatch errors (ISSUE 2 tentpole)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmps as B
from repro.core import peps as P
from repro.core import planner
from repro.core import full_update as FU
from repro.core.environments import row_environments, strip_boundary
from repro.core.expectation import strip_value
from repro.core.ite import ITEResult, ite_run, ite_statevector
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import DirectUpdate, FullUpdate, QRUpdate, apply_operator


def _hit_rate(stats):
    total = stats["fused_hits"] + stats["fused_misses"]
    return stats["fused_hits"] / max(total, 1)


# ------------------------------------------------------------ environment ----

def test_strip_boundary_closes_to_strip_value():
    """Contracting left and right strip boundaries at the same cut must
    reproduce the full strip scalar (cross-check of the env machinery)."""
    state = P.random_peps(3, 4, 2, jax.random.PRNGKey(0))
    top, bottom = row_environments(state, B.BMPS(8), jax.random.PRNGKey(1))
    i = 1
    bra = [state.sites[i]]
    want = complex(strip_value(top[i], bottom[i], bra, bra))
    for cut in range(state.ncol + 1):
        left = strip_boundary(top[i], bottom[i], bra, bra, cut, from_left=True)
        right = strip_boundary(top[i], bottom[i], bra, bra, cut, from_left=False)
        got = complex(jnp.einsum("abcd,abcd->", left, right))
        assert abs(got - want) <= 1e-10 * max(abs(want), 1e-300), (cut, got, want)


def test_bond_environment_norm_consistency():
    """Closing the bond environment with the reduced tensors of the *current*
    sites must reproduce <psi|psi> (up to boundary truncation error)."""
    state = P.random_peps(3, 3, 2, jax.random.PRNGKey(2))
    upd = FullUpdate(rank=2, chi=16)
    envs = row_environments(state, FU.env_option(upd), jax.random.PRNGKey(3))
    want = complex(B.norm_squared(state, B.BMPS(16), jax.random.PRNGKey(4)))
    for s0, s1, axes_a, axes_b in [
        ((1, 0), (1, 1), (1, 2, 3, 0, 4), (1, 3, 4, 0, 2)),   # horizontal
        ((0, 1), (1, 1), (1, 2, 4, 0, 3), (2, 3, 4, 0, 1)),   # vertical
    ]:
        a = state.sites[s0[0]][s0[1]]
        b = state.sites[s1[0]][s1[1]]
        qa, ra = FU._reduced_split(a, axes_a)
        qb, rb = FU._reduced_split(b, axes_b)
        env = FU.bond_environment(state, s0, s1, qa, qb, envs)
        got = complex(planner.cached_einsum(
            "ABCDabcd,ABpk,CDqk,abpK,cdqK->",
            env, ra.conj(), rb.conj(), ra, rb))
        assert abs(got - want) <= 1e-6 * abs(want), (s0, s1, got, want)


def test_positive_fix_is_psd_projection():
    key = jax.random.PRNGKey(5)
    m = jax.random.normal(key, (16, 16), dtype=jnp.float64)
    env = (m @ m.T - 3.0 * jnp.eye(16)).reshape(2, 2, 2, 2, 2, 2, 2, 2)
    fixed = FU.positive_fix(env).reshape(16, 16)
    w = np.linalg.eigvalsh(np.asarray(fixed))
    assert w.min() >= -1e-12
    assert abs(w.max() - 1.0) < 1e-12  # normalized to unit spectral norm


def test_stale_envs_detected_and_refreshed():
    """Environments cached before a bond grew must be detected as
    shape-stale (silently broadcasting their dim-1 axes would corrupt the
    metric) and transparently refreshed by full_update_bond."""
    state = P.computational_zeros(2, 2)
    upd = FullUpdate(rank=2, chi=8)
    envs = row_environments(state, FU.env_option(upd), jax.random.PRNGKey(0))
    assert FU.envs_compatible(state, (1, 0), (1, 1), envs)
    # grow the vertical bond (0,0)-(1,0): row 1's u-dims no longer match
    grown = apply_operator(state, P._gates.CX, [0, 2], QRUpdate(rank=2))
    assert not FU.envs_compatible(grown, (1, 0), (1, 1), envs)
    FU.drain_fidelities()
    out = FU.full_update_bond(grown, P._gates.CX, (1, 0), (1, 1), upd,
                              jax.random.PRNGKey(1), envs=envs)
    fids = FU.drain_fidelities()
    assert out.sites[1][0].shape[4] == 2
    assert len(fids) == 1 and 0.99 <= fids[0] <= 1.0 + 1e-9


# -------------------------------------------------------------- accuracy ----

def test_full_update_product_state_fidelity_is_one():
    """On a bond-dim-1 state a rank-2 update loses nothing: fidelity ~ 1."""
    FU.drain_fidelities()  # isolate from earlier tests
    state = P.computational_zeros(2, 2)
    state = apply_operator(state, np.kron(P._gates.H, P._gates.H).reshape(2, 2, 2, 2),
                           [0, 1], FullUpdate(rank=2, chi=8))
    fids = FU.drain_fidelities()
    assert len(fids) == 1
    assert abs(fids[0] - 1.0) < 1e-8


@pytest.mark.parametrize("nrow,ncol", [(2, 2), (2, 3)])
def test_full_update_ite_matches_statevector(nrow, ncol):
    """2x2/2x3 TFI ground energy via full-update ITE vs exact ITE."""
    obs = tfi_hamiltonian(nrow, ncol, jz=-1.0, hx=-3.5)
    _, e_ref = ite_statevector(nrow, ncol, obs, tau=0.05, steps=80)
    res = ite_run(P.computational_zeros(nrow, ncol), obs, tau=0.05, steps=80,
                  update=FullUpdate(rank=2, chi=8), contract=B.BMPS(8),
                  measure_every=80)
    assert abs(res.energies[-1] - e_ref) < 2e-3 * abs(e_ref)
    # fidelity estimate rides along and stays physical
    assert res.fidelities is not None and len(res.fidelities) == 1
    assert 0.9 <= res.fidelities[-1] <= 1.0 + 1e-9


def test_full_update_beats_simple_update_at_fixed_bond():
    """At equal bond dimension and Trotter steps, the environment-aware
    update must reach a strictly lower energy error (2x3 TFI, D=2)."""
    obs = tfi_hamiltonian(2, 3, jz=-1.0, hx=-3.5)
    _, e_ref = ite_statevector(2, 3, obs, tau=0.05, steps=80)
    kw = dict(tau=0.05, steps=80, contract=B.BMPS(8), measure_every=80)
    res_qr = ite_run(P.computational_zeros(2, 3), obs,
                     update=QRUpdate(rank=2), **kw)
    res_fu = ite_run(P.computational_zeros(2, 3), obs,
                     update=FullUpdate(rank=2, chi=8), **kw)
    err_qr = abs(res_qr.energies[-1] - e_ref)
    err_fu = abs(res_fu.energies[-1] - e_ref)
    assert err_fu < err_qr, (err_fu, err_qr)


# ---------------------------------------------------------------- planner ----

def test_full_update_planner_cache_across_trotter_steps():
    """After the shapes stabilize, every ALS solve replays compiled code."""
    obs = tfi_hamiltonian(2, 2, jz=-1.0, hx=-3.5)
    state = P.computational_zeros(2, 2)
    upd = FullUpdate(rank=2, chi=8)
    kw = dict(tau=0.05, contract=B.BMPS(8), measure_every=100)
    warm = ite_run(state, obs, steps=2, update=upd, **kw)
    res = ite_run(warm.state, obs, steps=3, update=upd, **kw)
    assert res.planner_stats["fused_misses"] == 0
    assert _hit_rate(res.planner_stats) == 1.0


def test_fused_fn_respects_fusion_toggle():
    calls = []

    def builder():
        calls.append(1)
        return lambda x: x + 1

    planner.reset_stats()
    f1 = planner.fused_fn("test-tag", (1, 2), builder)
    f2 = planner.fused_fn("test-tag", (1, 2), builder)
    assert f1 is f2 and len(calls) == 1
    s = planner.stats()
    assert s["fused_misses"] >= 1 and s["fused_hits"] >= 1
    with planner.disabled():
        planner.fused_fn("test-tag", (1, 2), builder)
        planner.fused_fn("test-tag", (1, 2), builder)
    assert len(calls) == 3  # no caching while disabled


def test_int_einsum_matches_plain_einsum():
    a = jax.random.normal(jax.random.PRNGKey(6), (3, 4, 5))
    b = jax.random.normal(jax.random.PRNGKey(7), (5, 4, 2))
    want = jnp.einsum("abc,cbd->ad", a, b)
    got = planner.int_einsum(a, [10, 20, 30], b, [30, 20, 40], [10, 40])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


# --------------------------------------------------------------- dispatch ----

def test_unknown_update_type_raises_type_error():
    @dataclasses.dataclass(frozen=True)
    class BogusUpdate:
        rank: int = 2

    state = P.computational_zeros(2, 2)
    with pytest.raises(TypeError, match="BogusUpdate"):
        apply_operator(state, P._gates.CX, [0, 1], BogusUpdate())
    obs = tfi_hamiltonian(2, 2)
    with pytest.raises(TypeError, match="BogusUpdate"):
        ite_run(state, obs, tau=0.05, steps=1, update=BogusUpdate(),
                contract=B.BMPS(4))


def test_direct_update_still_dispatches():
    state = P.computational_zeros(2, 2)
    out = apply_operator(state, P._gates.CX, [0, 1], DirectUpdate(rank=2))
    assert out.sites[0][0].shape[4] == 2


# ------------------------------------------------------------------- slow ----

@pytest.mark.slow
def test_full_update_4x4_acceptance():
    """ISSUE 2 acceptance: 4x4 TFI at D=3, equal Trotter steps — full update
    strictly below the simple update's energy error, planner fused hit rate
    > 90% after the first step."""
    obs = tfi_hamiltonian(4, 4, jz=-1.0, hx=-3.5)
    _, e_ref = ite_statevector(4, 4, obs, tau=0.05, steps=60)
    kw = dict(tau=0.05, contract=B.BMPS(16), measure_every=30)
    res_qr = ite_run(P.computational_zeros(4, 4), obs, steps=30,
                     update=QRUpdate(rank=3), **kw)
    upd = FullUpdate(rank=3, chi=12, env_refresh_every=40)
    first = ite_run(P.computational_zeros(4, 4), obs, steps=1,
                    update=upd, **kw)
    rest = ite_run(first.state, obs, steps=29, update=upd, **kw)
    err_qr = abs(res_qr.energies[-1] - e_ref)
    err_fu = abs(rest.energies[-1] - e_ref)
    assert err_fu < err_qr, (err_fu, err_qr)
    assert err_fu < 1e-3 * abs(e_ref)
    assert _hit_rate(rest.planner_stats) > 0.90, rest.planner_stats
    assert all(0.9 <= f <= 1.0 + 1e-9 for f in rest.fidelities)


@pytest.mark.slow
def test_batched_full_update_evolution():
    """Ensemble full-update TEBD under vmap (the sharding entry point)."""
    import jax.tree_util as jtu
    from repro.core.sharding import batched_evolve_full

    protos = [P.random_peps(3, 3, 2, jax.random.PRNGKey(i), dtype=jnp.complex64)
              for i in range(2)]
    batched = jtu.tree_map(lambda *xs: jnp.stack(xs), *protos)
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    out = batched_evolve_full(batched, keys, chi_env=6)
    leaf = out.sites[1][1]
    assert leaf.shape[0] == 2
    assert bool(jnp.all(jnp.isfinite(leaf)))
