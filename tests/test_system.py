"""End-to-end behaviour tests for the paper's system.

The full pipeline the paper demonstrates: build a circuit state with
truncating PEPS updates, measure observables through cached-environment
contraction, and agree with the exact simulator within the truncation
accuracy the paper reports.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import peps as P
from repro.core import statevector as sv
from repro.core import bmps as B
from repro.core import gates as G
from repro.core.circuits import random_circuit, apply_circuit_peps, \
    apply_circuit_statevector
from repro.core.expectation import expectation
from repro.core.observable import Observable, tfi_hamiltonian
from repro.core.peps import QRUpdate
from repro.core.einsumsvd import DirectSVD, RandomizedSVD


def test_end_to_end_circuit_energy():
    """Circuit -> PEPS(QR-SVD) -> cached expectation == statevector."""
    n = 3
    circ = random_circuit(n, n, 4, seed=11)  # one iSWAP round: bond 4
    state = apply_circuit_peps(P.computational_zeros(n, n), circ,
                               QRUpdate(rank=4))
    vec = apply_circuit_statevector(sv.zeros(n * n), circ)
    obs = tfi_hamiltonian(n, n)
    got = complex(expectation(state, obs, B.BMPS(16, DirectSVD()),
                              use_cache=True))
    want = complex(sv.expectation(vec, obs.as_tuples()))
    assert abs(got - want) < 1e-6 * max(1.0, abs(want))


def test_truncation_error_is_graceful():
    """With rank below the exact bond, energies stay close (simple update)."""
    n = 3
    circ = random_circuit(n, n, 8, seed=12)  # exact bond would be 16
    state = apply_circuit_peps(P.computational_zeros(n, n), circ,
                               QRUpdate(rank=8, svd=RandomizedSVD(niter=3)))
    vec = apply_circuit_statevector(sv.zeros(n * n), circ)
    obs = Observable.Z(4)
    got = complex(expectation(state, obs, B.BMPS(32, DirectSVD())))
    want = complex(sv.expectation(vec, obs.as_tuples()))
    assert abs(got - want) < 0.4  # truncated but not nonsense


def test_norm_preserved_by_unitary_circuit():
    n = 3
    circ = random_circuit(n, n, 4, seed=13)
    state = apply_circuit_peps(P.computational_zeros(n, n), circ,
                               QRUpdate(rank=4))
    nrm = complex(B.norm_squared(state, B.BMPS(16, DirectSVD())))
    assert abs(nrm - 1.0) < 1e-8
