"""Per-architecture smoke tests: reduced config, one train + decode step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get, get_smoke
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models.model import build
from repro.optim.adamw import adamw_init


def _mesh():
    return make_host_mesh()


def _batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
        batch["positions"] = pos
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    mesh = _mesh()
    bundle = build(cfg, mesh)
    params = bundle.init(jax.random.PRNGKey(1))
    opt = adamw_init(params)
    batch = _batch(cfg)
    with use_mesh(mesh):
        step = jax.jit(bundle.train_step)
        new_params, new_opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(new_params)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    mesh = _mesh()
    bundle = build(cfg, mesh)
    params = bundle.init(jax.random.PRNGKey(2))
    b, max_seq = 2, 32
    with use_mesh(mesh):
        cache = bundle.init_cache(b, max_seq)
        if cfg.family == "encdec":
            # fill cross-attention cache with zeros (already zeros)
            pass
        token = jnp.zeros((b, 1), jnp.int32)
        positions = None
        if cfg.family == "vlm":
            positions = jnp.zeros((3, b, 1), jnp.int32)
        step = jax.jit(bundle.serve_step)
        logits, new_cache = step(params, cache, token, positions)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert int(new_cache["index"]) == 1


@pytest.mark.parametrize("arch", ["granite-8b", "qwen3-moe-30b-a3b",
                                  "qwen2-vl-72b", "mamba2-2.7b",
                                  "zamba2-2.7b"])
def test_smoke_prefill(arch):
    cfg = get_smoke(arch)
    mesh = _mesh()
    bundle = build(cfg, mesh)
    params = bundle.init(jax.random.PRNGKey(3))
    tokens = jnp.zeros((2, 16), jnp.int32)
    with use_mesh(mesh):
        logits, cache = jax.jit(bundle.prefill_step)(params, tokens)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["index"]) == 16


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-2.7b"])
def test_prefill_then_decode_consistent(arch):
    """Prefilled recurrent state must continue correctly: prefill(t0..t14)
    then decode(t15) matches prefill(t0..t15)'s logits."""
    cfg = get_smoke(arch)
    mesh = _mesh()
    bundle = build(cfg, mesh)
    params = bundle.init(jax.random.PRNGKey(4))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
    with use_mesh(mesh):
        logits_full, _ = jax.jit(bundle.prefill_step)(params, toks)
        _, cache = jax.jit(bundle.prefill_step)(params, toks[:, :15])
        if cfg.family == "hybrid":  # widen shared-attn kv cache to >=16
            pad = 16 - cache["k"].shape[3]
            cache = dict(cache)
            cache["k"] = jnp.pad(cache["k"], ((0,0),(0,0),(0,pad),(0,0),(0,0)))
            cache["v"] = jnp.pad(cache["v"], ((0,0),(0,0),(0,pad),(0,0),(0,0)))
        logits_dec, _ = jax.jit(bundle.serve_step)(params, cache,
                                                   toks[:, 15:16])
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    c = get("granite-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (36, 4096, 32, 8, 14336, 49152)
    c = get("qwen3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (36, 2560, 32, 8, 9728, 151936) and c.qk_norm
    c = get("smollm-360m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (32, 960, 15, 5, 2560, 49152)
    c = get("deepseek-coder-33b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (62, 7168, 56, 8, 19200, 32256)
    c = get("qwen3-moe-30b-a3b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k, c.d_expert_ff) \
        == (48, 2048, 128, 8, 768)
    c = get("arctic-480b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (35, 7168, 128, 2)
    assert c.moe_dense_residual
    c = get("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    c = get("qwen2-vl-72b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (80, 8192, 64, 29568)
    c = get("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == (64, 2560, 128, 50280)
    c = get("whisper-large-v3")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) \
        == (32, 1280, 20, 5120, 51866)
