"""Fault tolerance + elasticity: chaos-kill/resume training, elastic mesh
restore, multi-device semantics (subprocess with fake devices)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"))


def _run_train(args, env=None, check=True):
    cmd = [sys.executable, "-m", "repro.launch.train"] + args
    res = subprocess.run(cmd, env=env or ENV, capture_output=True, text=True)
    if check and res.returncode != 0:
        raise AssertionError(f"train failed rc={res.returncode}\n"
                             f"stdout:{res.stdout[-2000:]}\n"
                             f"stderr:{res.stderr[-2000:]}")
    return res


def _losses(log):
    return {json.loads(l)["step"]: json.loads(l)["loss"]
            for l in Path(log).read_text().splitlines()}


@pytest.mark.slow
def test_chaos_kill_and_resume_bit_identical(tmp_path):
    """Kill at step 12, resume from the step-10 checkpoint; the overlapping
    steps must reproduce the uninterrupted run's losses exactly."""
    log_a = tmp_path / "a.jsonl"
    _run_train(["--arch", "smollm-360m", "--smoke", "--steps", "16",
                "--batch", "4", "--seq", "32", "--checkpoint-every", "5",
                "--log-file", str(log_a)])

    ck = tmp_path / "ckpt"
    log_b = tmp_path / "b.jsonl"
    res = _run_train(["--arch", "smollm-360m", "--smoke", "--steps", "16",
                      "--batch", "4", "--seq", "32", "--checkpoint-every", "5",
                      "--checkpoint-dir", str(ck), "--log-file", str(log_b),
                      "--simulate-failure", "12"], check=False)
    assert res.returncode == 42, res.stderr[-1500:]
    _run_train(["--arch", "smollm-360m", "--smoke", "--steps", "16",
                "--batch", "4", "--seq", "32", "--checkpoint-every", "5",
                "--checkpoint-dir", str(ck), "--log-file", str(log_b)])

    ref, got = _losses(log_a), _losses(log_b)
    assert set(ref) == set(got)
    for step in ref:
        assert abs(ref[step] - got[step]) < 1e-4, (step, ref[step], got[step])


@pytest.mark.slow
def test_elastic_restore_changes_mesh(tmp_path):
    """Checkpoint on a 1x1 mesh, restore + continue on a 2x2 fake-device mesh
    (elastic scaling): loss continues from the same point."""
    ck = tmp_path / "ckpt"
    log_a = tmp_path / "a.jsonl"
    _run_train(["--arch", "smollm-360m", "--smoke", "--steps", "10",
                "--batch", "4", "--seq", "32", "--checkpoint-every", "10",
                "--checkpoint-dir", str(ck), "--log-file", str(log_a)])
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    log_b = tmp_path / "b.jsonl"
    _run_train(["--arch", "smollm-360m", "--smoke", "--steps", "14",
                "--batch", "4", "--seq", "32", "--mesh", "2x2",
                "--checkpoint-dir", str(ck), "--log-file", str(log_b)],
               env=env)
    a, b = _losses(log_a), _losses(log_b)
    assert min(b) == 10 and max(b) == 13
    # continuation is consistent (same data stream, restored params)
    assert all(np.isfinite(v) for v in b.values())


MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models.model import build
from repro.optim.adamw import adamw_init
from repro import configs

mesh = make_mesh((2, 4), ("data", "model"))
cfg = configs.get_smoke("qwen3-moe-30b-a3b")
bundle = build(cfg, mesh)
params = bundle.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
batch = {"tokens": tokens, "labels": tokens}
# sharded step
pshard = bundle.param_shardings()
params_s = jax.device_put(params, pshard)
opt_s = jax.device_put(opt, bundle.opt_shardings())
batch_s = {k: jax.device_put(v, bundle.batch_sharding()) for k, v in batch.items()}
step = jax.jit(bundle.train_step, in_shardings=(pshard, bundle.opt_shardings(), None))
_, _, m_s = step(params_s, opt_s, batch_s)

# single-device reference
mesh1 = make_mesh((1, 1), ("data", "model"))
bundle1 = build(cfg, mesh1)
_, _, m_1 = jax.jit(bundle1.train_step)(params, opt, batch)
ls, l1 = float(m_s["loss"]), float(m_1["loss"])
assert abs(ls - l1) < 5e-2 * max(abs(l1), 1.0), (ls, l1)
print("MULTIDEV_OK", ls, l1)
"""


@pytest.mark.slow
def test_multidevice_moe_matches_single_device(tmp_path):
    """The shard_map MoE on a real 2x4 device mesh computes (nearly) the same
    loss as the single-device path — EP routing semantics are correct."""
    script = tmp_path / "multidev.py"
    script.write_text(MULTIDEV_SNIPPET)
    res = subprocess.run([sys.executable, str(script)], env=ENV,
                         capture_output=True, text=True)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MULTIDEV_OK" in res.stdout
