"""BMPS / IBMPS / two-layer contraction tests (paper Alg. 2/3, Table II)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import peps as P
from repro.core import bmps as B
from repro.core.einsumsvd import DirectSVD, RandomizedSVD


@pytest.fixture(scope="module")
def onelayer():
    return P.random_onelayer(4, 4, 3, jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def state33():
    return P.random_peps(3, 3, 2, jax.random.PRNGKey(7))


def test_onelayer_bmps_converges(onelayer):
    exact = complex(B.contract_exact_onelayer(onelayer))
    v = complex(B.contract_onelayer(onelayer, B.BMPS(16, DirectSVD())))
    assert abs(v - exact) / abs(exact) < 1e-10


def test_onelayer_ibmps_no_extra_error(onelayer):
    """Fig. 10 claim: implicit randomized SVD adds no error over direct SVD."""
    exact = complex(B.contract_exact_onelayer(onelayer))
    for chi in (3, 6, 16):
        e_b = abs(complex(B.contract_onelayer(onelayer, B.BMPS(chi, DirectSVD()))) - exact)
        e_i = abs(complex(B.contract_onelayer(onelayer, B.BMPS(chi, RandomizedSVD(niter=4)))) - exact)
        assert e_i <= e_b * 1.5 + 1e-12 * abs(exact)


def test_twolayer_matches_statevector(state33):
    vec = P.to_statevector(state33)
    want = float(jnp.real(jnp.vdot(vec, vec)))
    got_d = complex(B.norm_squared(state33, B.BMPS(16, DirectSVD())))
    got_r = complex(B.norm_squared(state33, B.BMPS(16, RandomizedSVD())))
    assert abs(got_d - want) < 1e-10 * abs(want)
    assert abs(got_r - want) < 1e-8 * abs(want)


def test_twolayer_equals_merged_onelayer(state33):
    merged = B.merge_layers(state33.sites, state33.sites)
    v1 = complex(B.contract_exact_onelayer(merged))
    v2 = complex(B.contract_twolayer(state33.sites, state33.sites,
                                     B.BMPS(16, DirectSVD())))
    assert abs(v1 - v2) < 1e-10 * abs(v1)


def test_inner_product_hermitian(state33):
    other = P.random_peps(3, 3, 2, jax.random.PRNGKey(8))
    opt = B.BMPS(16, DirectSVD())
    ab = complex(B.inner(state33, other, opt))
    ba = complex(B.inner(other, state33, opt))
    assert abs(ab - np.conj(ba)) < 1e-10 * max(abs(ab), 1e-30)


def test_amplitude_approx_matches_exact(state33):
    bits = np.array([[0, 1, 0], [1, 0, 1], [0, 0, 1]])
    want = complex(P.amplitude_exact(state33, bits))
    got = complex(B.amplitude(state33, bits, B.BMPS(8, DirectSVD())))
    assert abs(got - want) < 1e-10 * abs(want)


def test_truncation_monotone(onelayer):
    """Property: error is (weakly) improving with chi on this network."""
    exact = complex(B.contract_exact_onelayer(onelayer))
    errs = []
    for chi in (2, 4, 8, 16):
        v = complex(B.contract_onelayer(onelayer, B.BMPS(chi, DirectSVD())))
        errs.append(abs(v - exact) / abs(exact))
    assert errs[-1] < 1e-9
    assert errs[-1] <= errs[0] + 1e-12
