"""Expectation values with/without caching vs the statevector oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import peps as P
from repro.core import statevector as sv
from repro.core import bmps as B
from repro.core.observable import Observable, tfi_hamiltonian, j1j2_hamiltonian
from repro.core.expectation import expectation, split_two_site, norm_from_envs
from repro.core.environments import row_environments
from repro.core.einsumsvd import DirectSVD, RandomizedSVD


@pytest.fixture(scope="module")
def state():
    return P.random_peps(3, 3, 2, jax.random.PRNGKey(3))


@pytest.fixture(scope="module")
def vec(state):
    return P.to_statevector(state)


OPT = B.BMPS(16, DirectSVD())


@pytest.mark.parametrize("obs_fn", [
    lambda: Observable.Z(0),
    lambda: Observable.X(4),
    lambda: Observable.ZZ(0, 1),
    lambda: Observable.ZZ(3, 4),
    lambda: Observable.ZZ(1, 4),
    lambda: Observable.XX(0, 4),   # diagonal
    lambda: Observable.YY(1, 3),   # anti-diagonal
    lambda: Observable.ZZ(4, 8),   # diagonal rows 1-2
])
def test_single_terms(state, vec, obs_fn):
    obs = obs_fn()
    want = complex(sv.expectation(vec, obs.as_tuples()))
    got = complex(expectation(state, obs, OPT, use_cache=True))
    assert abs(got - want) < 1e-10


@pytest.mark.parametrize("ham", ["tfi", "j1j2"])
@pytest.mark.parametrize("use_cache", [True, False])
def test_hamiltonians(state, vec, ham, use_cache):
    obs = tfi_hamiltonian(3, 3) if ham == "tfi" else j1j2_hamiltonian(3, 3)
    want = complex(sv.expectation(vec, obs.as_tuples()))
    got = complex(expectation(state, obs, OPT, use_cache=use_cache))
    assert abs(got - want) < 1e-9


def test_cache_equals_nocache(state):
    obs = tfi_hamiltonian(3, 3)
    a = complex(expectation(state, obs, OPT, use_cache=True))
    b = complex(expectation(state, obs, OPT, use_cache=False))
    assert abs(a - b) < 1e-10


def test_rsvd_contraction_expectation(state, vec):
    obs = tfi_hamiltonian(3, 3)
    want = complex(sv.expectation(vec, obs.as_tuples()))
    got = complex(expectation(state, obs, B.BMPS(16, RandomizedSVD()), use_cache=True))
    assert abs(got - want) < 1e-7


def test_split_two_site_exact():
    from repro.core import gates as G
    for g in (G.CX, G.ISWAP, G.two_site_gate(np.kron(G.Z, G.Z))):
        left, right = split_two_site(g)
        recon = np.einsum("xpk,yqk->xypq", left, right)
        np.testing.assert_allclose(recon, np.asarray(g).reshape(2, 2, 2, 2),
                                   atol=1e-12)


def test_norm_from_envs(state, vec):
    top, bottom = row_environments(state, OPT)
    got = complex(norm_from_envs(state, top, bottom))
    want = float(jnp.real(jnp.vdot(vec, vec)))
    assert abs(got - want) < 1e-10 * abs(want)
