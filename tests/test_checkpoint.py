"""CheckpointManager: dtype fidelity (complex! — PEPS tensors), torn-write
atomicity under fault injection, orphan sweeping, GC retention, and the
restore/load error paths."""
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import faults
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import QRUpdate, computational_zeros
from repro.core.bmps import BMPS


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    yield
    faults.clear()


def _tree():
    return {
        "c128": np.array([[1 + 2j, -3.5 - 4j]], dtype=np.complex128),
        "c64": np.array([0.5 + 0.25j], dtype=np.complex64),
        "f64": np.linspace(0, 1, 5),
        "i64": np.arange(4),
        "meta": np.array(json.dumps({"step": 7})),
    }


class TestDtypeFidelity:
    def test_complex_round_trips_bit_identically(self, tmp_path):
        """The seed widened every non-fiub kind to float32 — silently
        dropping the imaginary part of complex PEPS tensors.  Complex is
        numpy-native; it must round-trip exactly."""
        m = CheckpointManager(tmp_path)
        m.save(1, _tree(), blocking=True)
        out = m.load(1)
        for k in ("c128", "c64", "f64", "i64"):
            assert out[k].dtype == _tree()[k].dtype, k
            assert np.array_equal(out[k], _tree()[k]), k
        assert str(out["meta"][()]) == json.dumps({"step": 7})

    def test_complex_peps_state_round_trips(self, tmp_path):
        """An evolved (c128) PEPS snapshot restores with a nonzero
        imaginary part intact."""
        from repro.core.ite import ite_run
        st = computational_zeros(2, 2)
        res = ite_run(st, tfi_hamiltonian(2, 2), 0.05, 2, QRUpdate(rank=2),
                      BMPS(8), measure_every=1,
                      key=jax.random.PRNGKey(5))
        tree = {f"s{i}{j}": res.state.sites[i][j]
                for i in range(2) for j in range(2)}
        m = CheckpointManager(tmp_path)
        m.save(3, tree, blocking=True)
        out = m.load(3)
        for k, v in tree.items():
            got = out[k]
            assert got.dtype == np.complex128
            assert np.array_equal(got, np.asarray(v)), k

    def test_ml_dtypes_still_widen_and_narrow_back(self, tmp_path):
        ml_dtypes = pytest.importorskip("ml_dtypes")
        bf = np.array([1.5, -2.25, 3.0], dtype=ml_dtypes.bfloat16)
        m = CheckpointManager(tmp_path)
        m.save(1, {"w": bf}, blocking=True)
        # on disk: widened float32 (raw .npy of kind-V dtypes is unreadable)
        raw = np.load(tmp_path / "step_00000001" / "w.npy")
        assert raw.dtype == np.float32
        out = m.load(1)["w"]
        assert out.dtype == ml_dtypes.bfloat16
        assert np.array_equal(out.astype(np.float32), bf.astype(np.float32))

    def test_restore_rebuilds_the_target_tree(self, tmp_path):
        m = CheckpointManager(tmp_path)
        tree = {"a": np.arange(3.0), "b": np.array([1 + 1j], np.complex128)}
        m.save(1, tree, blocking=True)
        out = m.restore(1, {"a": np.zeros(3), "b": np.zeros(1, np.complex128)})
        assert np.array_equal(np.asarray(out["a"]), tree["a"])
        assert np.array_equal(np.asarray(out["b"]), tree["b"])


class TestAtomicity:
    def test_torn_write_never_shadows_previous_step(self, tmp_path):
        """A kill mid-write (injected: partial tmp, no publish) leaves the
        previous good step as latest."""
        m = CheckpointManager(tmp_path)
        m.save(1, _tree(), blocking=True)
        with faults.armed("checkpoint.write", action="torn"):
            m.save(2, _tree(), blocking=True)
        assert m.latest_step() == 1
        assert (tmp_path / "step_00000002.tmp").exists()
        out = m.load(1)   # previous step is fully readable
        assert np.array_equal(out["c128"], _tree()["c128"])

    def test_torn_final_manifest_is_skipped(self, tmp_path):
        """A published directory with a truncated manifest (injected:
        non-atomic publish) is invisible to all_steps/latest_step."""
        m = CheckpointManager(tmp_path)
        m.save(1, _tree(), blocking=True)
        with faults.armed("checkpoint.write", action="torn_final"):
            m.save(2, _tree(), blocking=True)
        assert (tmp_path / "step_00000002" / "manifest.json").exists()
        assert m.all_steps() == [1]

    def test_init_sweeps_orphaned_tmp_dirs(self, tmp_path):
        m = CheckpointManager(tmp_path)
        with faults.armed("checkpoint.write", action="torn"):
            m.save(5, _tree(), blocking=True)
        orphan = tmp_path / "step_00000005.tmp"
        assert orphan.exists()
        CheckpointManager(tmp_path)    # a fresh manager (new process) sweeps
        assert not orphan.exists()

    def test_async_save_then_wait_is_durable(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save(4, _tree(), blocking=False)
        m.wait()
        assert m.latest_step() == 4


class TestGCAndErrors:
    def test_gc_keeps_the_newest_n(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4, 5):
            m.save(s, _tree(), blocking=True)
        assert m.all_steps() == [4, 5]

    def test_interleaved_saves_retain_by_step_order(self, tmp_path):
        m = CheckpointManager(tmp_path, keep=3)
        for s in (10, 2, 30, 4):
            m.save(s, _tree(), blocking=True)
        assert m.all_steps() == [4, 10, 30]

    def test_missing_step_raises_a_clear_error(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save(1, _tree(), blocking=True)
        with pytest.raises(FileNotFoundError, match=r"step 99.*available"):
            m.load(99)
        with pytest.raises(FileNotFoundError, match=r"step 99"):
            m.restore(99, {"a": np.zeros(1)})

    def test_leaf_mismatch_messages(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save(1, {"a": np.zeros(3)}, blocking=True)
        with pytest.raises(KeyError, match="not in target tree"):
            m.restore(1, {"b": np.zeros(3)})
        with pytest.raises(ValueError, match="shape"):
            m.restore(1, {"a": np.zeros(4)})
        m.save(2, {"a": np.zeros(3)}, blocking=True)
        with pytest.raises(KeyError, match="missing leaves"):
            m.restore(2, {"a": np.zeros(3), "extra": np.zeros(1)})
