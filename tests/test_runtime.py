"""Runtime substrate tests: data pipeline, checkpoint manager, optimizer,
gradient compression, schedules, roofline/HLO parsing."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM
from repro.checkpoint import CheckpointManager
from repro.optim.adamw import OptConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
from repro.optim.compression import (compress_residual, dequantize,
                                     init_error_state, quantize)
from repro.launch.hlo_analysis import collective_bytes, _shape_bytes
from repro.launch.roofline import param_count, model_flops
from repro import configs


# ------------------------------------------------------------------- data --
def test_data_deterministic_restartable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = d1.batch_at(13), d2.batch_at(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].max() < 100


def test_data_host_sharding_partition():
    base = DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1)
    full = SyntheticLM(base).batch_at(3)["tokens"]
    # each host sees a batch of global/n_hosts with host-dependent content
    h0 = SyntheticLM(DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1,
                                n_hosts=2, host_id=0)).batch_at(3)["tokens"]
    h1 = SyntheticLM(DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1,
                                n_hosts=2, host_id=1)).batch_at(3)["tokens"]
    assert h0.shape == (4, 8) and h1.shape == (4, 8)
    assert not np.array_equal(h0, h1)
    assert full.shape == (8, 8)


def test_data_iterator_prefetch():
    cfg = DataConfig(vocab=32, seq_len=4, global_batch=2)
    it = SyntheticLM(cfg).iterate(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"],
                                  SyntheticLM(cfg).batch_at(5)["tokens"])


def test_data_learnable_structure():
    cfg = DataConfig(vocab=64, seq_len=128, global_batch=4)
    b = SyntheticLM(cfg).batch_at(0)
    follows = np.mean(b["tokens"][:, 1:] == (b["tokens"][:, :-1] * 7 + 3) % 64)
    assert follows > 0.5  # mostly predictable transitions


# -------------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    mgr.save(10, tree, blocking=True)
    assert mgr.latest_step() == 10
    out = mgr.restore(10, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros((8,))}
    for step in (1, 2, 3, 4):
        mgr.save(step, {"x": jnp.full((8,), float(step))})
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    out = mgr.restore(4, tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.full(8, 4.0))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.zeros((4,))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(1, {"x": jnp.zeros((5,))})


def test_checkpoint_atomicity(tmp_path):
    """A stray .tmp dir from a crashed writer is not considered a checkpoint."""
    mgr = CheckpointManager(tmp_path)
    (tmp_path / "step_00000007.tmp").mkdir()
    assert mgr.latest_step() is None
    mgr.save(3, {"x": jnp.zeros(2)}, blocking=True)
    assert mgr.latest_step() == 3


# --------------------------------------------------------------- optimizer --
def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update({"w": jnp.full(3, 100.0)}, state, params, cfg)
    assert float(metrics["grad_norm"]) > 100.0  # reported pre-clip


def test_cosine_schedule():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert abs(float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100)) - 1.0) < 1e-6
    end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100, min_frac=0.1))
    assert abs(end - 0.1) < 1e-6


# ------------------------------------------------------------- compression --
def test_quantize_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, scale = quantize(g)
    err = np.abs(np.asarray(dequantize(q, scale) - g))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_error_feedback_accumulates_to_truth():
    """Sum over steps of compressed grads ~= sum of true grads (EF property)."""
    key = jax.random.PRNGKey(1)
    gs = jax.random.normal(key, (50, 64)) * jnp.linspace(1, 3, 50)[:, None]
    err = jnp.zeros(64)
    total_sent = jnp.zeros(64)
    for i in range(50):
        q, scale, err = compress_residual(gs[i], err)
        total_sent = total_sent + dequantize(q, scale)
    true_total = jnp.sum(gs, axis=0)
    # residual error is bounded by the last quantization step, not O(T)
    assert float(jnp.max(jnp.abs(total_sent + err - true_total))) < 1e-4


def test_init_error_state_shapes():
    params = {"a": jnp.zeros((2, 3), jnp.bfloat16)}
    es = init_error_state(params)
    assert es["a"].shape == (2, 3) and es["a"].dtype == jnp.float32


# ------------------------------------------------------ HLO / roofline utils --
def test_shape_bytes():
    assert _shape_bytes("bf16[128,4096]{1,0}") == 128 * 4096 * 2
    assert _shape_bytes("f32[16]") == 64
    assert _shape_bytes("pred[2,2]") == 4


def test_collective_bytes_parses():
    hlo = """
  %ag = bf16[2,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[64]{0} all-reduce(%y), to_apply=%add
  %ars = f32[64]{0} all-reduce-start(%y), to_apply=%add
  %ard = f32[64]{0} all-reduce-done(%ars)
  %cp = s8[32,32]{1,0} collective-permute(%z)
"""
    total, per_kind, counts = collective_bytes(hlo)
    assert per_kind["all-gather"] == 2 * 128 * 2
    assert per_kind["all-reduce"] == 64 * 4 * 2   # ar + ar-start; -done skipped
    assert per_kind["collective-permute"] == 32 * 32
    assert counts["all-reduce"] == 2


def test_param_count_sane():
    n = param_count(configs.get("granite-8b"))
    assert 7e9 < n < 9.5e9
    n_active = param_count(configs.get("qwen3-moe-30b-a3b"), active_only=True)
    n_total = param_count(configs.get("qwen3-moe-30b-a3b"))
    assert n_active < n_total / 4
    n_arctic = param_count(configs.get("arctic-480b"))
    assert 4e11 < n_arctic < 5.5e11


def test_model_flops_kinds():
    cfg = configs.get("smollm-360m")
    t = model_flops(cfg, "train", 4096, 256)
    p = model_flops(cfg, "prefill", 4096, 256)
    d = model_flops(cfg, "decode", 4096, 256)
    assert t == 3 * p and d < p / 1000
