"""Property tests on model-component invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.common import (apply_mrope, apply_rope, cross_entropy,
                                 rms_norm)
from repro.models.ssm import ssd_chunked
from repro.kernels import ref


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**31 - 1), shift=st.integers(0, 50))
def test_rope_relative_position_invariance(seed, shift):
    """RoPE dot products depend only on relative positions: shifting all
    positions by a constant leaves q.k scores unchanged."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    q = jax.random.normal(k1, (1, 8, 2, 32))
    k = jax.random.normal(k2, (1, 8, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    def scores(p):
        qr = apply_rope(q, p)
        kr = apply_rope(k, p)
        return jnp.einsum("bshd,bthd->bhst", qr, kr)
    s0 = scores(pos)
    s1 = scores(pos + shift)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)


def test_mrope_reduces_to_rope_for_text():
    """With identical position streams, M-RoPE == standard RoPE."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(6), (1, 6))
    pos3 = jnp.broadcast_to(pos, (3, 1, 6))
    a = apply_rope(q, pos, theta=1e4)
    b = apply_mrope(q, pos3, sections=(3, 3, 2), theta=1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2**31 - 1))
def test_rms_norm_unit_rms(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 10
    y = rms_norm(x, jnp.ones(64))
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-2)


def test_rms_norm_scale_equivariance():
    """rms_norm(c*x) == rms_norm(x) for any positive scalar c."""
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
    a = rms_norm(x, jnp.ones(32))
    b = rms_norm(123.0 * x, jnp.ones(32))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                               atol=1e-4)


def test_cross_entropy_uniform_logits():
    logits = jnp.zeros((2, 3, 7))
    labels = jnp.zeros((2, 3), jnp.int32)
    got = float(cross_entropy(logits, labels))
    assert abs(got - np.log(7)) < 1e-5


def test_cross_entropy_perfect_prediction():
    labels = jnp.array([[1, 2]], jnp.int32)
    logits = jax.nn.one_hot(labels, 5) * 100.0
    assert float(cross_entropy(logits, labels)) < 1e-3


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([16, 32, 64]))
def test_ssd_chunk_invariance(seed, chunk):
    """The chunked SSD result must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b, h, l, p, n = 1, 2, 96, 8, 4
    x = jax.random.normal(ks[0], (b, h, l, p))
    bb = jax.random.normal(ks[1], (b, h, l, n)) * 0.5
    cc = jax.random.normal(ks[2], (b, h, l, n)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[3], (b, h, l))) * 0.1
    y1 = ssd_chunked(x, bb, cc, a, chunk=chunk)
    y2 = ssd_chunked(x, bb, cc, a, chunk=l)  # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_matches_kernel_ref():
    """jnp chunked SSD == the naive-recurrence kernel oracle."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    b, h, l, p, n = 2, 3, 64, 8, 4
    x = jax.random.normal(ks[0], (b, h, l, p))
    bb = jax.random.normal(ks[1], (b, h, l, n)) * 0.5
    cc = jax.random.normal(ks[2], (b, h, l, n)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[3], (b, h, l))) * 0.1
    got = ssd_chunked(x, bb, cc, a, chunk=16)
    want = ref.ssd(x.reshape(b * h, l, p), bb.reshape(b * h, l, n),
                   cc.reshape(b * h, l, n), a.reshape(b * h, l))
    np.testing.assert_allclose(np.asarray(got).reshape(b * h, l, p),
                               np.asarray(want), rtol=1e-3, atol=1e-3)


def test_ssd_final_state_continues_sequence():
    """return_state: running [0:64] then [64:96] from the saved state equals
    the full [0:96] run (the prefill->decode contract)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    b, h, l, p, n = 1, 2, 96, 8, 4
    x = jax.random.normal(ks[0], (b, h, l, p))
    bb = jax.random.normal(ks[1], (b, h, l, n)) * 0.5
    cc = jax.random.normal(ks[2], (b, h, l, n)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[3], (b, h, l))) * 0.1
    y_full = ssd_chunked(x, bb, cc, a, chunk=32)
    _, h64 = ssd_chunked(x[:, :, :64], bb[:, :, :64], cc[:, :, :64],
                         a[:, :, :64], chunk=32, return_state=True)
    # continue step by step from the saved state
    hs = np.asarray(h64, np.float64)
    ys = []
    for t in range(64, 96):
        hn = np.exp(np.asarray(a[:, :, t]))[..., None, None] * hs + \
            np.einsum("bhn,bhp->bhnp", np.asarray(bb[:, :, t], np.float64),
                      np.asarray(x[:, :, t], np.float64))
        ys.append(np.einsum("bhn,bhnp->bhp",
                            np.asarray(cc[:, :, t], np.float64), hn))
        hs = hn
    got_tail = np.stack(ys, axis=2)          # (b, h, 32, p)
    np.testing.assert_allclose(got_tail, np.asarray(y_full[:, :, 64:]),
                               rtol=2e-3, atol=2e-3)
