"""Per-precision error-budget tier (ISSUE 7 satellite).

Every budget asserted here comes from ONE table —
``repro.core.precision.ERROR_BUDGETS`` — which ``docs/contraction.md``
embeds verbatim (:func:`repro.core.precision.budget_table_markdown`).  The
first test asserts the doc contains exactly the rendered table, so docs and
tests cannot drift; the rest *measure* each workload against its budget:

* the **exact** lane re-pins the goldens (bit-compatible construction:
  ``BMPS(chi)`` and ``BMPS(chi, precision="exact")`` are equal options);
* the **mixed** lane measures each acceptance workload against the
  exact-path result of the *identical* contraction (same chi, engine, PRNG
  key), isolating the precision policy from the truncation error;
* the **bf16 kernel** lane forces the Pallas sites with bf16 multiplicands
  and bounds their error against the f32 dense references.
"""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmps as B
from repro.core import peps as P
from repro.core import statevector as sv
from repro.core.circuits import (apply_circuit_exact_peps,
                                 apply_circuit_statevector, random_circuit)
from repro.core.einsumsvd import DirectSVD, RandomizedSVD, einsumsvd
from repro.core.ite import ite_run
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import FullUpdate, QRUpdate
from repro.core.precision import (ERROR_BUDGETS, EXACT, MIXED,
                                  PrecisionWrapped, budget_table_markdown,
                                  error_budget, policy_of, resolve_precision,
                                  wrap_svd)

K17 = jax.random.PRNGKey(17)
DOCS = Path(__file__).resolve().parent.parent / "docs" / "contraction.md"


def _rel(a, b):
    return abs(complex(a) - complex(b)) / abs(complex(b))


# ---------------------------------------------------------------- table ----

def test_budget_table_docs_no_drift():
    """docs/contraction.md embeds exactly the rendered ERROR_BUDGETS table.

    A substring assertion on the full rendering: change a budget (or a case
    description) in code without regenerating the doc — or vice versa — and
    this fails, naming the stale side."""
    table = budget_table_markdown()
    doc = DOCS.read_text()
    assert table in doc, (
        "docs/contraction.md is out of sync with precision.ERROR_BUDGETS —"
        " paste the current budget_table_markdown() into the doc:\n" + table)


def test_budget_table_lists_every_workload():
    table = budget_table_markdown()
    for name in ERROR_BUDGETS:
        assert f"`{name}`" in table


def test_error_budget_lookup():
    assert error_budget("amplitude", "exact") == 1e-12
    assert error_budget("amplitude", MIXED) == ERROR_BUDGETS["amplitude"]["mixed"]
    with pytest.raises(KeyError, match="no budget"):
        error_budget("nonsense_workload", "exact")


# --------------------------------------------------------------- policy ----

def test_resolve_precision_rejects_unknown():
    with pytest.raises(TypeError, match=r"exact.*mixed|mixed.*exact"):
        resolve_precision("fast")
    with pytest.raises(TypeError):
        resolve_precision(32)
    assert resolve_precision("exact") is EXACT
    assert resolve_precision(MIXED) is MIXED


def test_wrap_svd_exact_is_identity():
    """The exact policy returns the bare option — bit-identical construction."""
    opt = DirectSVD()
    assert wrap_svd(opt, "exact") is opt
    assert policy_of(opt) is EXACT


def test_wrap_svd_idempotent_both_directions():
    opt = RandomizedSVD()
    mixed = wrap_svd(opt, "mixed")
    assert isinstance(mixed, PrecisionWrapped) and mixed.inner is opt
    assert policy_of(mixed) is MIXED
    # re-wrapping unwraps first: mixed->mixed keeps one layer, mixed->exact
    # returns the bare option
    assert wrap_svd(mixed, "mixed").inner is opt
    assert wrap_svd(mixed, "exact") is opt


def test_bmps_exact_option_equals_prepolicy_option():
    """``BMPS(chi)`` before and after the precision field build equal
    options — the svd is NOT wrapped under the default exact policy."""
    assert B.BMPS(8) == B.BMPS(8, precision="exact")
    assert isinstance(B.BMPS(8).svd, DirectSVD)
    assert isinstance(B.BMPS(8, precision="mixed").svd, PrecisionWrapped)


def test_distributed_bmps_threads_precision():
    from repro.core.distributed import DistributedBMPS
    opt = DistributedBMPS(8, precision="mixed")
    assert isinstance(opt.svd, PrecisionWrapped)
    with pytest.raises(TypeError):
        DistributedBMPS(8, precision="double")


def test_einsumsvd_precision_kwarg_roundtrips_dtype():
    """``einsumsvd(..., precision="mixed")`` demotes around the solve and
    promotes back: output dtypes match the exact path, values within the
    storage-demotion error."""
    a = jax.random.normal(jax.random.PRNGKey(0), (6, 5, 7), jnp.float64)
    b = jax.random.normal(jax.random.PRNGKey(1), (7, 4, 3), jnp.float64)
    args = ([a, b], ["abc", "cde"])
    ue, se, ve = einsumsvd(DirectSVD(), *args, row="ab", col="de",
                           rank=4, absorb="none", key=K17)
    um, sm, vm = einsumsvd(DirectSVD(), *args, row="ab", col="de",
                           rank=4, absorb="none", key=K17, precision="mixed")
    assert um.dtype == ue.dtype and sm.dtype == se.dtype and vm.dtype == ve.dtype
    np.testing.assert_allclose(np.asarray(sm), np.asarray(se), rtol=1e-5)


# ----------------------------------------------------------- exact lane ----

def test_exact_budget_contract_onelayer_goldens():
    """The exact lane re-pins the engine goldens at the documented budget."""
    from test_engines import GOLDEN
    tol = error_budget("contract_onelayer", "exact")
    rows = P.random_onelayer(4, 4, 3, jax.random.PRNGKey(42))
    v = B.contract_onelayer(rows, B.BMPS(8, precision="exact"), key=K17)
    assert _rel(v, GOLDEN["onelayer_direct"]) <= tol
    v = B.contract_onelayer(rows, B.BMPS.randomized(8, precision="exact"),
                            key=K17)
    assert _rel(v, GOLDEN["onelayer_rand"]) <= tol


# ----------------------------------------------------------- mixed lane ----
#
# Each workload compares precision="mixed" against the exact-path result of
# the IDENTICAL contraction (same chi, engine, PRNG key), so the measured
# number is the precision error alone, not the truncation error.

def test_mixed_budget_contract_onelayer():
    tol = error_budget("contract_onelayer", "mixed")
    rows = P.random_onelayer(4, 4, 3, jax.random.PRNGKey(42))
    e = B.contract_onelayer(rows, B.BMPS(8), key=K17)
    m = B.contract_onelayer(rows, B.BMPS(8, precision="mixed"), key=K17)
    assert _rel(m, e) <= tol, f"direct: {_rel(m, e):.3e} > {tol:.0e}"
    e = B.contract_onelayer(rows, B.BMPS.randomized(8), key=K17)
    m = B.contract_onelayer(rows, B.BMPS.randomized(8, precision="mixed"),
                            key=K17)
    assert _rel(m, e) <= tol, f"randomized: {_rel(m, e):.3e} > {tol:.0e}"


@pytest.fixture(scope="module")
def tfi44():
    obs = tfi_hamiltonian(4, 4, jz=-1.0, hx=-3.5)
    run = ite_run(P.computational_zeros(4, 4), obs, steps=10, tau=0.05,
                  update=QRUpdate(rank=3), contract=B.BMPS(16),
                  measure_every=10)
    return obs, run.state


def test_mixed_budget_contract_twolayer(tfi44):
    tol = error_budget("contract_twolayer", "mixed")
    _, state = tfi44
    e = B.norm_squared(state, B.BMPS(8), K17)
    m = B.norm_squared(state, B.BMPS(8, precision="mixed"), K17)
    assert _rel(m, e) <= tol, f"{_rel(m, e):.3e} > {tol:.0e}"


def test_mixed_budget_amplitude_rqc():
    circ = random_circuit(3, 3, 8, seed=3)
    state = apply_circuit_exact_peps(P.computational_zeros(3, 3), circ)
    bits = np.zeros((3, 3), dtype=int)
    e = B.amplitude(state, bits, B.BMPS(8), K17)
    m = B.amplitude(state, bits, B.BMPS(8, precision="mixed"), K17)
    # exact lane: the exact path reproduces the statevector amplitude
    vec = apply_circuit_statevector(sv.zeros(9), circ)
    exact = complex(vec[(0,) * 9])
    assert _rel(e, exact) <= error_budget("amplitude", "exact")
    tol = error_budget("amplitude", "mixed")
    assert _rel(m, e) <= tol, f"{_rel(m, e):.3e} > {tol:.0e}"


def test_mixed_budget_full_update_ite_step(tfi44):
    tol = error_budget("full_update_ite_step", "mixed")
    obs, _ = tfi44

    def energy(precision):
        upd = FullUpdate(rank=3, chi=8,
                         svd=wrap_svd(DirectSVD(), precision),
                         env_svd=wrap_svd(DirectSVD(), precision))
        res = ite_run(P.computational_zeros(4, 4), obs, steps=1, tau=0.05,
                      update=upd, contract=B.BMPS(8, precision=precision),
                      measure_every=1)
        return res.energies[-1]

    ee, em = energy("exact"), energy("mixed")
    err = abs(em - ee) / abs(ee)
    assert err <= tol, f"{err:.3e} > {tol:.0e}"


def test_mixed_budget_kernel_bf16_gemm():
    """Forced-Pallas bf16-multiplicand gram/tall-apply vs the f32 dense
    references, bounded by the documented kernel budget."""
    from repro.kernels.gram import gram, gram_complex
    from repro.kernels.matvec import planar_matmul
    tol = error_budget("kernel_bf16_gemm", "mixed")
    a = jax.random.normal(jax.random.PRNGKey(0), (512, 24), jnp.float32)
    bmat = jax.random.normal(jax.random.PRNGKey(1), (24, 8), jnp.float32)

    def relf(got, want):
        got, want = np.asarray(got), np.asarray(want)
        dt = np.complex128 if np.iscomplexobj(want) else np.float64
        got, want = got.astype(dt), want.astype(dt)
        return np.linalg.norm(got - want) / np.linalg.norm(want)

    assert relf(gram(a, compute="bfloat16"), a.T @ a) <= tol
    assert relf(planar_matmul(a, bmat, compute="bfloat16"), a @ bmat) <= tol
    c = (a[:256] + 1j * a[256:]).astype(jnp.complex64)
    assert relf(gram_complex(c, compute="bfloat16"),
                c.conj().T @ c) <= tol


def test_mixed_scaling_handles_unnormalized_operands():
    """The per-solve operand scaling inside PrecisionWrapped keeps badly
    scaled networks solvable: without it, tensors with ~1e-5 magnitudes
    push the demoted f32 spectrum under the Gram-QR eigenvalue clamp and
    the randomized solve collapses to ~zero.

    The reference is the identical exact solve on PRE-normalized operands
    with the scale folded back into s — NOT the unnormalized exact path,
    which on this adversarial input degenerates itself (its ~1e-20 Gram
    spectrum sits below the absolute part of the f64 eigenvalue clamp
    ``eps = 1e-13 * max(|lam|, 1)``, so every singular value it returns is
    the clamp floor sqrt(1e-13)).  Mixed-with-scaling must match the
    well-scaled solve, i.e. be *better* than unnormalized exact here."""
    a = 1e-5 * jax.random.normal(jax.random.PRNGKey(2), (40, 6, 9),
                                 jnp.float64)
    b = 1e-5 * jax.random.normal(jax.random.PRNGKey(3), (9, 6, 30),
                                 jnp.float64)
    _, s_ref, _ = einsumsvd(RandomizedSVD(), [a * 1e5, b * 1e5],
                            ["abc", "cde"], row="ab", col="de",
                            rank=4, absorb="none", key=K17)
    s_ref = np.asarray(s_ref) * 1e-10
    _, sm, _ = einsumsvd(RandomizedSVD(), [a, b], ["abc", "cde"],
                         row="ab", col="de", rank=4, absorb="none",
                         key=K17, precision="mixed")
    np.testing.assert_allclose(np.asarray(sm), s_ref, rtol=1e-4)
    # and the degenerate unnormalized exact path really is the clamp floor,
    # far from the true spectrum — documenting why it is not the reference
    _, se, _ = einsumsvd(RandomizedSVD(), [a, b], ["abc", "cde"],
                         row="ab", col="de", rank=4, absorb="none", key=K17)
    np.testing.assert_allclose(np.asarray(se), np.sqrt(1e-13), rtol=1e-2)
