"""Planner cache semantics, fused-engine equivalence, Pallas-gram dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import planner
from repro.core import orthogonalize as orth
from repro.core.einsumsvd import DirectSVD, RandomizedSVD, einsumsvd, truncation_error
from repro.core.rsvd import ImplicitOperator
from repro.core.bmps import BMPS, contract_twolayer
from repro.core.peps import random_peps


def _network(key, d1=3, d2=4, d3=5, d4=3, dtype=jnp.complex128):
    k = jax.random.split(key, 4)
    a = jax.random.normal(k[0], (d1, d2, d3))
    b = jax.random.normal(k[2], (d3, d4, d1))
    if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        a = a + 1j * jax.random.normal(k[1], (d1, d2, d3))
        b = b + 1j * jax.random.normal(k[3], (d3, d4, d1))
    return [a.astype(dtype), b.astype(dtype)], ["abc", "cde"], "ab", "de"


@pytest.fixture(autouse=True)
def _fresh_planner():
    planner.clear()
    yield
    planner.clear()


# ---------------------------------------------------------------- paths ----

def test_path_cache_hit_miss_semantics():
    tensors, subs, row, col = _network(jax.random.PRNGKey(0))
    op = ImplicitOperator(tensors, subs, row, col)
    q = jax.random.normal(jax.random.PRNGKey(1), op.col_shape + (3,)).astype(op.dtype)

    planner.reset_stats()
    op.matvecs(q)
    s1 = planner.stats()
    assert s1["path_misses"] == 1 and s1["path_hits"] == 0

    op.matvecs(q)  # same signature -> cached
    s2 = planner.stats()
    assert s2["path_misses"] == 1 and s2["path_hits"] == 1

    # different sketch width -> different shapes -> a fresh miss
    q5 = jax.random.normal(jax.random.PRNGKey(2), op.col_shape + (5,)).astype(op.dtype)
    op.matvecs(q5)
    s3 = planner.stats()
    assert s3["path_misses"] == 2

    # rmatvecs is a different expression -> its own entry
    p = jax.random.normal(jax.random.PRNGKey(3), op.row_shape + (3,)).astype(op.dtype)
    op.rmatvecs(p)
    assert planner.stats()["path_misses"] == 3


def test_path_cache_disabled_restores_seed_behavior():
    tensors, subs, row, col = _network(jax.random.PRNGKey(0))
    op = ImplicitOperator(tensors, subs, row, col)
    q = jax.random.normal(jax.random.PRNGKey(1), op.col_shape + (3,)).astype(op.dtype)
    with planner.disabled():
        op.matvecs(q)
        op.matvecs(q)
    s = planner.stats()
    assert s["path_uncached"] == 2 and s["path_misses"] == 0


def test_cached_einsum_matches_plain_einsum():
    a = jax.random.normal(jax.random.PRNGKey(0), (6, 7, 8))
    b = jax.random.normal(jax.random.PRNGKey(1), (8, 7, 5))
    want = jnp.einsum("abc,cbd->ad", a, b, optimize="optimal")
    got = planner.cached_einsum("abc,cbd->ad", a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


# ---------------------------------------------------------------- fused ----

def test_fused_cache_hit_miss_semantics():
    tensors, subs, row, col = _network(jax.random.PRNGKey(4))
    op = ImplicitOperator(tensors, subs, row, col)
    planner.reset_stats()
    planner.fused_randomized_svd(op, 4, key=jax.random.PRNGKey(0))
    assert planner.stats()["fused_misses"] == 1
    planner.fused_randomized_svd(op, 4, key=jax.random.PRNGKey(1))
    s = planner.stats()
    assert s["fused_misses"] == 1 and s["fused_hits"] == 1
    # different rank -> different solver config -> new compiled entry
    planner.fused_randomized_svd(op, 6, key=jax.random.PRNGKey(0))
    assert planner.stats()["fused_misses"] == 2


def test_fused_cache_keyed_on_gram_backend():
    """set_gram_backend must not be ignored for already-compiled signatures:
    the backend mode is a trace-time decision, so it is part of the key."""
    tensors, subs, row, col = _network(jax.random.PRNGKey(4))
    op = ImplicitOperator(tensors, subs, row, col)
    planner.reset_stats()
    prev = orth.set_gram_backend("dense")
    try:
        planner.fused_randomized_svd(op, 4, key=jax.random.PRNGKey(0))
        orth.set_gram_backend("auto")
        planner.fused_randomized_svd(op, 4, key=jax.random.PRNGKey(0))
    finally:
        orth.set_gram_backend(prev)
    s = planner.stats()
    assert s["fused_misses"] == 2 and s["fused_hits"] == 0


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.complex128])
def test_fused_matches_unfused(dtype):
    tensors, subs, row, col = _network(jax.random.PRNGKey(5), dtype=dtype)
    key = jax.random.PRNGKey(11)
    for rank in (3, 6):
        uf, sf, vf = RandomizedSVD(fused=True)(
            ImplicitOperator(tensors, subs, row, col), rank, key=key)
        uu, su, vu = RandomizedSVD(fused=False)(
            ImplicitOperator(tensors, subs, row, col), rank, key=key)
        rec_f = np.einsum("abk,k,kde->abde", np.asarray(uf), np.asarray(sf),
                          np.asarray(vf))
        rec_u = np.einsum("abk,k,kde->abde", np.asarray(uu), np.asarray(su),
                          np.asarray(vu))
        err = (np.linalg.norm(rec_f - rec_u)
               / max(np.linalg.norm(rec_u), 1e-300))
        assert err <= 1e-5, err


def test_fused_einsumsvd_against_direct_reference():
    """End-to-end: fused implicit refactorization ~= dense SVD truncation."""
    tensors, subs, row, col = _network(jax.random.PRNGKey(6))
    op = ImplicitOperator(tensors, subs, row, col)
    rank = min(op.row_size, op.col_size)
    u, s, v = einsumsvd(RandomizedSVD(niter=6, fused=True), tensors, subs,
                        row, col, rank, absorb="none",
                        key=jax.random.PRNGKey(7))
    assert float(truncation_error(op.dense(), u, s, v)) < 1e-8


def test_contract_twolayer_fused_matches_unfused():
    state = random_peps(3, 3, 2, jax.random.PRNGKey(8))
    key = jax.random.PRNGKey(9)
    val_f = contract_twolayer(state.sites, state.sites,
                              BMPS.randomized(8, fused=True), key)
    val_u = contract_twolayer(state.sites, state.sites,
                              BMPS.randomized(8, fused=False), key)
    np.testing.assert_allclose(np.asarray(val_f), np.asarray(val_u),
                               rtol=1e-5)
    misses_first = planner.stats()["fused_misses"]
    assert misses_first > 0
    # a repeated sweep presents only already-seen signatures: all hits
    contract_twolayer(state.sites, state.sites,
                      BMPS.randomized(8, fused=True), key)
    s = planner.stats()
    assert s["fused_misses"] == misses_first
    assert s["fused_hits"] >= misses_first


# ----------------------------------------------------------- gram kernel ----

def test_pallas_gram_matches_dense_qr_tall_skinny():
    """Forced-Pallas gram_qr vs dense reshape-QR on a tall-skinny operand."""
    a = jax.random.normal(jax.random.PRNGKey(10), (512, 24), jnp.float32)
    prev = orth.set_gram_backend("pallas")
    try:
        orth.reset_gram_dispatch_stats()
        q_p, r_p = orth.gram_qr(a, 1)
        assert orth.gram_dispatch_stats()["pallas_gram_calls"] == 1
    finally:
        orth.set_gram_backend(prev)
    q_d, r_d = orth.reshape_qr(a, 1)
    # Q from gram vs LAPACK QR differ by column signs/rotations; compare the
    # projector Q Q^H and the reconstruction instead.
    rec_p = np.asarray(q_p) @ np.asarray(r_p)
    np.testing.assert_allclose(rec_p, np.asarray(a), atol=5e-4)
    proj_p = np.asarray(q_p) @ np.asarray(q_p).T
    proj_d = np.asarray(q_d) @ np.asarray(q_d).T
    np.testing.assert_allclose(proj_p, proj_d, atol=5e-3)
    qtq = np.asarray(q_p).T @ np.asarray(q_p)
    np.testing.assert_allclose(qtq, np.eye(24), atol=5e-3)


def test_pallas_gram_complex64():
    key = jax.random.PRNGKey(12)
    k1, k2 = jax.random.split(key)
    a = (jax.random.normal(k1, (256, 12)) + 1j * jax.random.normal(k2, (256, 12))
         ).astype(jnp.complex64)
    prev = orth.set_gram_backend("pallas")
    try:
        q, r = orth.gram_qr(a, 1)
    finally:
        orth.set_gram_backend(prev)
    rec = np.asarray(q) @ np.asarray(r)
    np.testing.assert_allclose(rec, np.asarray(a), atol=1e-3)
    qtq = np.conj(np.asarray(q)).T @ np.asarray(q)
    np.testing.assert_allclose(qtq, np.eye(12), atol=5e-3)


def test_gram_dispatch_gate_keeps_f64_dense():
    """float64 operands must never route to the f32-accumulating kernel."""
    a = jax.random.normal(jax.random.PRNGKey(13), (4096, 8), jnp.float64)
    prev = orth.set_gram_backend("pallas")  # even when forced
    try:
        orth.reset_gram_dispatch_stats()
        orth.gram_qr(a, 1)
        s = orth.gram_dispatch_stats()
        assert s["pallas_gram_calls"] == 0 and s["dense_gram_calls"] == 1
    finally:
        orth.set_gram_backend(prev)


def test_gram_auto_mode_is_dense_on_cpu():
    a = jax.random.normal(jax.random.PRNGKey(14), (8192, 16), jnp.float32)
    assert orth.set_gram_backend("auto") in ("auto", "pallas", "dense")
    orth.reset_gram_dispatch_stats()
    orth.gram_qr(a, 1)
    s = orth.gram_dispatch_stats()
    if jax.default_backend() == "tpu":
        assert s["pallas_gram_calls"] == 1
    else:
        assert s["dense_gram_calls"] == 1
