"""ITE / VQE / RQC application drivers (paper Section VI-B/VI-D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import peps as P
from repro.core import statevector as sv
from repro.core import bmps as B
from repro.core.observable import tfi_hamiltonian, j1j2_hamiltonian
from repro.core.circuits import (random_circuit, vqe_ansatz,
                                 apply_circuit_exact_peps,
                                 apply_circuit_peps,
                                 apply_circuit_statevector)
from repro.core.ite import ite_run, ite_statevector, trotter_moments
from repro.core.peps import QRUpdate, DirectUpdate
from repro.core.einsumsvd import DirectSVD, RandomizedSVD
from repro.core.vqe import vqe_energy_peps, vqe_energy_statevector


def test_rqc_exact_evolution_matches_statevector():
    circ = random_circuit(3, 3, 8, seed=1)
    state = apply_circuit_exact_peps(P.computational_zeros(3, 3), circ)
    vec = apply_circuit_statevector(sv.zeros(9), circ)
    assert state.max_bond() == 16  # 2 iSWAP rounds: 4^2
    bits = np.zeros((3, 3), dtype=int)
    amp = complex(P.amplitude_exact(state, bits))
    assert abs(amp - complex(vec[(0,) * 9])) < 1e-12


def test_rqc_bmps_ibmps_amplitude():
    circ = random_circuit(3, 3, 8, seed=2)
    state = apply_circuit_exact_peps(P.computational_zeros(3, 3), circ)
    vec = apply_circuit_statevector(sv.zeros(9), circ)
    want = complex(vec[(0,) * 9])
    for svd in (DirectSVD(), RandomizedSVD(niter=4)):
        got = complex(B.amplitude(state, np.zeros((3, 3), int), B.BMPS(16, svd)))
        assert abs(got - want) / abs(want) < 1e-6


def test_trotter_moment_count():
    obs = tfi_hamiltonian(3, 3)
    moments = trotter_moments(obs, 0.05)
    # 12 ZZ bonds + 9 X fields
    assert len(moments) == 21


def test_ite_decreases_energy():
    obs = tfi_hamiltonian(2, 2, jz=-1.0, hx=-3.5)
    res = ite_run(P.computational_zeros(2, 2), obs, tau=0.05, steps=40,
                  update=QRUpdate(rank=4), contract=B.BMPS(8), measure_every=10)
    assert res.energies[-1] < res.energies[0]


def test_ite_converges_to_statevector_ite():
    obs = tfi_hamiltonian(2, 2, jz=-1.0, hx=-3.5)
    _, e_ref = ite_statevector(2, 2, obs, tau=0.05, steps=200)
    res = ite_run(P.computational_zeros(2, 2), obs, tau=0.05, steps=200,
                  update=QRUpdate(rank=4), contract=B.BMPS(8), measure_every=200)
    assert abs(res.energies[-1] - e_ref) < 5e-2 * abs(e_ref)


def test_vqe_energy_peps_matches_statevector():
    obs = tfi_hamiltonian(2, 2)
    rng = np.random.default_rng(0)
    thetas = rng.uniform(-0.5, 0.5, size=8)  # 2 layers x 4 qubits
    e_sv = vqe_energy_statevector(thetas, 2, 2, obs)
    e_peps = vqe_energy_peps(thetas, 2, 2, obs, QRUpdate(rank=4), B.BMPS(16))
    assert abs(e_sv - e_peps) < 1e-8 * max(1.0, abs(e_sv))


def test_vqe_ansatz_structure():
    thetas = np.zeros(18)  # 2 layers x 9 qubits
    circ = vqe_ansatz(3, 3, thetas)
    n_ry = sum(1 for g, s in circ if len(s) == 1)
    n_cx = sum(1 for g, s in circ if len(s) == 2)
    assert n_ry == 18 and n_cx == 24  # 12 nn pairs x 2 layers


def test_j1j2_ite_smoke():
    """One ITE step of the J1-J2 model (has diagonal terms -> SWAP chains)."""
    obs = j1j2_hamiltonian(2, 2)
    res = ite_run(P.computational_zeros(2, 2), obs, tau=0.02, steps=2,
                  update=QRUpdate(rank=4), contract=B.BMPS(8), measure_every=2)
    assert np.isfinite(res.energies[-1])
