"""Kernel-dispatch registry gating regressions (ISSUE 7 satellite).

Asserts the registry's decision procedure in BOTH directions — Pallas
engages exactly when eligible, the dense fallback is silent otherwise —
with the per-site counters checked on every path, plus the interpret-mode
precedence chain, the trace-time backend signature, and the planner-replay
contract (a repeat sweep with kernels enabled ticks no new fused misses).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmps as B
from repro.core import peps as P
from repro.core import planner
from repro.core.orthogonalize import gram_qr, tall_project
from repro.kernels import dispatch
from repro.kernels import zipup_block as ZB

K17 = jax.random.PRNGKey(17)

SITES = ("gram", "tall_apply", "zipup_first_onelayer",
         "zipup_first_twolayer", "pair_merge")


@pytest.fixture(autouse=True)
def _restore_dispatch_state():
    """Every test runs from (and restores) the default dispatch state."""
    prev_mode = dispatch.kernel_backend()
    prev_compute = dispatch.kernel_compute()
    prev_interp = dispatch.set_interpret_mode("autodetect")
    dispatch.set_interpret_mode(prev_interp)
    yield
    dispatch.set_kernel_backend(prev_mode)   # also clears site overrides
    dispatch.set_kernel_compute(prev_compute)
    dispatch.set_interpret_mode(prev_interp)
    dispatch.reset_dispatch_stats()


def _stats():
    return dispatch.dispatch_stats()


# ------------------------------------------------------------- registry ----

def test_all_sites_registered():
    regs = dispatch.registered_sites()
    for s in SITES:
        assert s in regs, f"site {s!r} missing from registry"


def test_counters_exist_per_site_and_surface_through_planner():
    st = planner.stats()
    for s in SITES:
        assert f"pallas_{s}_calls" in st
        assert f"dense_{s}_calls" in st


def test_set_kernel_backend_returns_prev_and_validates():
    prev = dispatch.set_kernel_backend("dense")
    assert prev in ("auto", "pallas", "dense")
    assert dispatch.set_kernel_backend("auto") == "dense"
    with pytest.raises(ValueError, match="bad kernel backend"):
        dispatch.set_kernel_backend("gpu")
    with pytest.raises(KeyError, match="unknown kernel site"):
        dispatch.set_kernel_backend("pallas", site="nonexistent_site")
    with pytest.raises(KeyError):
        dispatch.dispatch("nonexistent_site")


# --------------------------------------------------- gating, both ways ----

def test_forced_pallas_engages_eligible_dtype():
    a = jax.random.normal(jax.random.PRNGKey(0), (96, 13, 2), jnp.float32)
    dispatch.set_kernel_backend("pallas")
    dispatch.reset_dispatch_stats()
    q, r = gram_qr(a, 1)
    s = _stats()
    assert s["pallas_gram_calls"] == 1 and s["dense_gram_calls"] == 0
    assert s["pallas_tall_apply_calls"] == 1 and s["dense_tall_apply_calls"] == 0
    # and the result still factorizes: a == q . r
    rec = jnp.einsum("abk,kc->abc", q, r)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(a),
                               rtol=5e-4, atol=5e-4)


def test_forced_pallas_keeps_f64_dense_silently():
    """The dtype gate is HARD: f64/c128 never route to the f32-accumulating
    kernels, even when forced — and the fallback is silent (no warning)."""
    a = jax.random.normal(jax.random.PRNGKey(1), (4096, 8), jnp.float64)
    dispatch.set_kernel_backend("pallas")
    dispatch.reset_dispatch_stats()
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        gram_qr(a, 1)
    s = _stats()
    assert s["pallas_gram_calls"] == 0 and s["dense_gram_calls"] == 1
    assert s["pallas_tall_apply_calls"] == 0
    assert s["dense_tall_apply_calls"] == 1


def test_auto_mode_is_dense_on_cpu_even_tall_skinny():
    a = jax.random.normal(jax.random.PRNGKey(2), (8192, 16), jnp.float32)
    dispatch.set_kernel_backend("auto")
    dispatch.reset_dispatch_stats()
    gram_qr(a, 1)
    s = _stats()
    if jax.default_backend() != "tpu":
        assert s["pallas_gram_calls"] == 0 and s["dense_gram_calls"] == 1


def test_forced_dense_never_dispatches_pallas():
    a = jax.random.normal(jax.random.PRNGKey(3), (512, 24), jnp.float32)
    dispatch.set_kernel_backend("dense")
    dispatch.reset_dispatch_stats()
    gram_qr(a, 1)
    s = _stats()
    assert s["pallas_gram_calls"] == 0 and s["pallas_tall_apply_calls"] == 0
    assert s["dense_gram_calls"] == 1 and s["dense_tall_apply_calls"] == 1


def test_per_site_override_and_global_reset():
    a = jax.random.normal(jax.random.PRNGKey(4), (256, 12), jnp.float32)
    dispatch.set_kernel_backend("dense")
    prev = dispatch.set_kernel_backend("pallas", site="gram")
    assert prev == "dense"   # effective mode before the override
    assert dispatch.kernel_backend("gram") == "pallas"
    assert dispatch.kernel_backend("tall_apply") == "dense"
    dispatch.reset_dispatch_stats()
    gram_qr(a, 1)
    s = _stats()
    assert s["pallas_gram_calls"] == 1       # override engages gram only
    assert s["dense_tall_apply_calls"] == 1  # global dense holds elsewhere
    # a global set supersedes all per-site overrides
    dispatch.set_kernel_backend("auto")
    assert dispatch.kernel_backend("gram") == "auto"


# ------------------------------------------------ zip-up kernel parity ----

def test_zipup_kernels_match_dense_forced():
    """Each zip-up site's Pallas path reproduces its dense einsum."""
    k = jax.random.split(jax.random.PRNGKey(5), 6)
    s0 = jax.random.normal(k[0], (1, 5, 7), jnp.float32)
    o0 = jax.random.normal(k[1], (5, 1, 3, 6), jnp.float32)
    s0c = (jax.random.normal(k[2], (1, 4, 4, 6)) +
           1j * jax.random.normal(k[3], (1, 4, 4, 6))).astype(jnp.complex64)
    tb0 = (jax.random.normal(k[4], (2, 4, 1, 3, 5)) +
           1j * jax.random.normal(k[5], (2, 4, 1, 3, 5))).astype(jnp.complex64)
    tk0 = jnp.flip(tb0, axis=1)
    pairs = [
        ("zipup_first_onelayer", ZB.first_column_onelayer, (s0, o0)),
        ("zipup_first_twolayer", ZB.first_column_twolayer, (s0c, tb0, tk0)),
        ("pair_merge", ZB.pair_merge,
         ((jax.random.normal(k[0], (2, 1, 3, 4, 5)).astype(jnp.float32)),
          (jax.random.normal(k[1], (2, 1, 3, 4, 5)).astype(jnp.float32)))),
    ]
    for site, fn, args in pairs:
        dispatch.set_kernel_backend("dense")
        want = fn(*args)
        dispatch.set_kernel_backend("pallas")
        dispatch.reset_dispatch_stats()
        got = fn(*args)
        assert _stats()[f"pallas_{site}_calls"] == 1, site
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=site)


def test_zipup_kernels_hard_gate_c128():
    tb = (jax.random.normal(jax.random.PRNGKey(6), (2, 1, 2, 2, 2)) +
          1j * jax.random.normal(jax.random.PRNGKey(7), (2, 1, 2, 2, 2)))
    assert tb.dtype == jnp.complex128
    dispatch.set_kernel_backend("pallas")
    dispatch.reset_dispatch_stats()
    ZB.pair_merge(tb.conj(), tb)
    s = _stats()
    assert s["pallas_pair_merge_calls"] == 0
    assert s["dense_pair_merge_calls"] == 1


def test_tall_project_matches_tensordot():
    a = jax.random.normal(jax.random.PRNGKey(8), (17, 9, 11), jnp.float32)
    mat = jax.random.normal(jax.random.PRNGKey(9), (99, 4), jnp.float32)
    want = jnp.tensordot(a, mat.reshape(9, 11, 4), axes=((1, 2), (0, 1)))
    dispatch.set_kernel_backend("pallas")
    got = tall_project(a, mat, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------- interpret + config ----

def test_interpret_precedence_flag_env_autodetect(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert dispatch.interpret_default() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "compiled")
    assert dispatch.interpret_default() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert dispatch.interpret_default() is True
    # the process flag outranks the environment
    dispatch.set_interpret_mode("compiled")
    assert dispatch.interpret_default() is False
    dispatch.set_interpret_mode("interpret")
    assert dispatch.interpret_default() is True
    dispatch.set_interpret_mode("autodetect")
    assert dispatch.interpret_default() is True   # env "1" applies again
    with pytest.raises(ValueError, match="bad interpret mode"):
        dispatch.set_interpret_mode("fast")


def test_backend_signature_tracks_every_trace_time_knob(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    sigs = {dispatch.backend_signature()}

    dispatch.set_kernel_backend("pallas")
    sigs.add(dispatch.backend_signature())
    dispatch.set_kernel_backend("auto")
    dispatch.set_kernel_backend("pallas", site="gram")
    sigs.add(dispatch.backend_signature())
    dispatch.set_kernel_backend("auto")
    dispatch.set_kernel_compute("bfloat16")
    sigs.add(dispatch.backend_signature())
    dispatch.set_kernel_compute(None)
    dispatch.set_interpret_mode(
        "compiled" if jax.default_backend() != "tpu" else "interpret")
    sigs.add(dispatch.backend_signature())
    dispatch.set_interpret_mode("autodetect")
    assert len(sigs) == 5, "every knob must change the signature"
    assert dispatch.backend_signature() in sigs  # restored == first


# -------------------------------------------------------- planner replay ----

def test_planner_replay_with_kernels_enabled_no_new_misses():
    """With forced-Pallas dispatch, a repeat of an identical sweep replays
    the fused cache (zero new misses) — the dispatch signature is part of
    the key, and it is stable across the two runs."""
    rows = P.random_onelayer(4, 4, 2, jax.random.PRNGKey(5))
    rows = [[t.astype(jnp.complex64) for t in r] for r in rows]
    opt = B.BMPS.randomized(6, niter=2, oversample=4)
    dispatch.set_kernel_backend("pallas")
    v1 = B.contract_onelayer(rows, opt, key=K17)
    before = planner.stats()
    assert before["pallas_gram_calls"] > 0   # kernels actually engaged
    v2 = B.contract_onelayer(rows, opt, key=K17)
    delta = planner.stats_since(before)
    assert delta["fused_misses"] == 0, "replay must not re-trace"
    assert delta["fused_hits"] > 0
    # counters tick at trace time: a pure replay adds no dispatch calls
    assert delta["pallas_gram_calls"] == 0
    np.testing.assert_allclose(complex(v2), complex(v1), rtol=1e-5)


def test_flipping_backend_is_a_new_fused_cache_key():
    rows = P.random_onelayer(3, 3, 2, jax.random.PRNGKey(6))
    rows = [[t.astype(jnp.complex64) for t in r] for r in rows]
    opt = B.BMPS.randomized(4, niter=1, oversample=2)
    dispatch.set_kernel_backend("dense")
    B.contract_onelayer(rows, opt, key=K17)
    before = planner.stats()
    dispatch.set_kernel_backend("pallas")
    B.contract_onelayer(rows, opt, key=K17)
    delta = planner.stats_since(before)
    assert delta["fused_misses"] > 0, (
        "a backend flip must re-trace, not replay the dense executable")
