"""Core PEPS correctness: operator application vs the statevector oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import peps as P
from repro.core import statevector as sv
from repro.core import gates as G
from repro.core.peps import DirectUpdate, QRUpdate, apply_operator
from repro.core.einsumsvd import DirectSVD, RandomizedSVD

OPS = [("H", [0]), ("H", [4]), ("CX", [0, 1]), ("ISWAP", [1, 4]), ("T", [4]),
       ("CX", [4, 5]), ("SQRT_Y", [2]), ("ISWAP", [0, 3]), ("CZ", [3, 4]),
       ("SQRT_W", [5]), ("CX", [2, 5])]


def _run_circuit(update):
    state = P.computational_zeros(2, 3)
    ref = sv.zeros(6)
    for name, sites in OPS:
        g = G.gate(name)
        state = apply_operator(state, g, sites, update)
        ref = sv.apply_gate(ref, g, sites)
    return state, ref


@pytest.mark.parametrize("update,tol", [
    (DirectUpdate(rank=8), 1e-12),
    (QRUpdate(rank=8, gram=True), 1e-12),
    (QRUpdate(rank=8, gram=False), 1e-12),
    (QRUpdate(rank=8, svd=RandomizedSVD(niter=4)), 1e-8),
])
def test_update_paths_match_statevector(update, tol):
    state, ref = _run_circuit(update)
    out = P.to_statevector(state)
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_gates_unitary():
    for name in ("X", "Y", "Z", "H", "S", "T", "SQRT_X", "SQRT_Y", "SQRT_W"):
        g = G.gate(name)
        np.testing.assert_allclose(g @ g.conj().T, np.eye(2), atol=1e-14)
    for name in ("CX", "CZ", "SWAP", "ISWAP"):
        g = G.gate(name).reshape(4, 4)
        np.testing.assert_allclose(g @ g.conj().T, np.eye(4), atol=1e-14)


def test_amplitude_exact_matches_statevector():
    state, ref = _run_circuit(DirectUpdate(rank=8))
    for bits in ([[0, 1, 0], [1, 0, 1]], [[0, 0, 0], [0, 0, 0]], [[1, 1, 1], [1, 1, 1]]):
        amp = P.amplitude_exact(state, np.array(bits))
        expected = ref[tuple(np.array(bits).flatten())]
        assert abs(complex(amp) - complex(expected)) < 1e-12


@pytest.mark.parametrize("sites", [[0, 5], [2, 3], [5, 0], [1, 5], [2, 0]])
def test_swap_chain_routing(sites):
    state, ref = _run_circuit(DirectUpdate(rank=8))
    state2 = apply_operator(state, G.gate("CX"), sites, DirectUpdate(rank=32))
    ref2 = sv.apply_gate(ref, G.gate("CX"), sites)
    assert float(jnp.max(jnp.abs(P.to_statevector(state2) - ref2))) < 1e-10


def test_normalize_sites_tracks_scale():
    state, ref = _run_circuit(QRUpdate(rank=8))
    scaled = P.normalize_sites(state)
    out = P.to_statevector(scaled)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-12


def test_log_scale_in_amplitudes():
    state, ref = _run_circuit(QRUpdate(rank=8))
    scaled = P.normalize_sites(state)
    bits = np.array([[0, 1, 0], [1, 0, 1]])
    amp = P.amplitude_exact(scaled, bits)
    assert abs(complex(amp) - complex(ref[tuple(bits.flatten())])) < 1e-12


def test_random_peps_shapes():
    st = P.random_peps(3, 4, 3, jax.random.PRNGKey(0))
    assert st.sites[0][0].shape == (2, 1, 1, 3, 3)
    assert st.sites[1][1].shape == (2, 3, 3, 3, 3)
    assert st.sites[2][3].shape == (2, 3, 3, 1, 1)
    assert st.max_bond() == 3


def test_peps_is_pytree():
    st = P.random_peps(2, 2, 2, jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_leaves(st)
    assert len(leaves) == 4
    st2 = jax.tree_util.tree_map(lambda x: 2.0 * x, st)
    assert isinstance(st2, P.PEPS)
    np.testing.assert_allclose(np.asarray(st2.sites[0][0]),
                               2 * np.asarray(st.sites[0][0]))
