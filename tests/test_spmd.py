"""Compiled SPMD wavefront superstep: equivalence, handoff, cache reuse.

The contract (see docs/contraction.md): for chi-saturated rows the
``shard_map`` + ``ppermute`` superstep executes the identical einsumsvd
sequence as the host-wavefront pipeline and the single-device sweep —
``wavefront`` mode is pure scheduling — so all three match to <= 1e-10.
Bond-ramp rows (and rows/layouts the superstep cannot express) always stay
on the explicit-placement pipeline, with ``spmd.stats()`` counting the
handoff.

On one device the superstep runs as the degenerate compiled chain (n=1);
CI additionally runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (make
test-distributed) so the multi-shard wavefront with real ppermute halos is
exercised.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmps, peps, planner, spmd
from repro.core.bmps import BMPS
from repro.core.distributed import DistributedBMPS
from repro.core.environments import top_environments
from repro.core.expectation import expectation
from repro.core.observable import Observable


def _state(nrow, ncol, bond, seed=3, scale=2.0):
    s = peps.random_peps(nrow, ncol, bond, jax.random.PRNGKey(seed))
    return peps.PEPS([[t * scale for t in row] for row in s.sites])


def _rel(a, b):
    a, b = complex(a), complex(b)
    return abs(a - b) / max(abs(b), 1e-300)


def _opt(chi, mode, n_shards=4, block=None):
    return DistributedBMPS.randomized(chi, niter=2, oversample=4,
                                      n_shards=n_shards, block=block,
                                      wavefront=mode)


def _bmps(chi):
    return BMPS.randomized(chi, niter=2, oversample=4)


# --------------------------------------------------------------- modes ----

def test_wavefront_validated():
    with pytest.raises(ValueError):
        DistributedBMPS(chi=8, wavefront="hots")


GRID = [
    # nrow, ncol, bond, chi, n_shards — chi=8/D=2 saturates after one row,
    # so every lattice here has superstep-eligible interior rows
    (5, 8, 2, 8, 2),
    (5, 12, 2, 8, 4),     # multi-shard uniform split exists on >= 3 devices
    (4, 13, 2, 8, 4),     # prime ncol: no uniform split — chain or host
    (4, 10, 2, 6, 4),     # ncol not divisible by n_shards
]


@pytest.mark.parametrize("nrow,ncol,bond,chi,n_shards", GRID)
def test_norm_squared_all_modes_match(nrow, ncol, bond, chi, n_shards):
    state = _state(nrow, ncol, bond)
    key = jax.random.PRNGKey(7)
    ref = bmps.norm_squared(state, _bmps(chi), key)
    host = bmps.norm_squared(state, _opt(chi, "host", n_shards), key)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        val = bmps.norm_squared(state, _opt(chi, "spmd", n_shards), key)
    auto = bmps.norm_squared(state, _opt(chi, "auto", n_shards), key)
    assert _rel(host, ref) <= 1e-10
    assert _rel(val, ref) <= 1e-10
    assert _rel(auto, ref) <= 1e-10


def test_amplitude_spmd_matches():
    state = _state(5, 12, 2)
    key = jax.random.PRNGKey(9)
    bits = np.arange(5 * 12) % 2
    ref = bmps.amplitude(state, bits, _bmps(4), key)
    spmd.reset_stats()
    val = bmps.amplitude(state, bits, _opt(4, "spmd"), key)
    assert _rel(val, ref) <= 1e-10
    assert spmd.stats()["rows_spmd"] > 0   # one-layer kernel engaged


def test_inner_distinct_bra_ket():
    bra, ket = _state(4, 8, 2, seed=3), _state(4, 8, 2, seed=4)
    key = jax.random.PRNGKey(1)
    ref = bmps.inner(bra, ket, _bmps(8), key)
    val = bmps.inner(bra, ket, _opt(8, "spmd"), key)
    assert _rel(val, ref) <= 1e-10


def test_environments_match_with_auto():
    state = _state(5, 12, 2)
    key = jax.random.PRNGKey(4)
    ref = top_environments(state.sites, state.sites, _bmps(8), key)
    val = top_environments(state.sites, state.sites, _opt(8, "auto"), key)
    assert len(ref) == len(val)
    for env_r, env_v in zip(ref, val):
        for tr, tv in zip(env_r, env_v):
            assert tr.shape == tv.shape
            assert float(jnp.max(jnp.abs(tr - tv))) <= 1e-10 * max(
                1.0, float(jnp.max(jnp.abs(tr))))


def test_expectation_matches():
    state = _state(5, 8, 2)
    H = (Observable.ZZ(9, 10) + 0.3 * Observable.X(2)
         + Observable.ZZ(1, 9) + 0.7 * Observable.Z(12))
    key = jax.random.PRNGKey(2)
    ref = expectation(state, H, _bmps(8), key=key)
    val = expectation(state, H, _opt(8, "spmd"), key=key)
    assert _rel(val, ref) <= 1e-10


def test_acceptance_6x8_chi16_8shards():
    """ISSUE 5 acceptance: 6x8 D=2 chi=16, 8 requested shards, spmd == host
    == single-device to <= 1e-10, with auto handing off ramp rows."""
    state = _state(6, 8, 2, scale=2.2)
    key = jax.random.PRNGKey(7)
    ref = bmps.norm_squared(state, BMPS.randomized(16), key)
    host = bmps.norm_squared(
        state, DistributedBMPS.randomized(16, n_shards=8, block=1), key)
    spmd.reset_stats()
    val = bmps.norm_squared(
        state, DistributedBMPS.randomized(16, n_shards=8, wavefront="spmd"),
        key)
    st = spmd.stats()
    auto = bmps.norm_squared(
        state, DistributedBMPS.randomized(16, n_shards=8, wavefront="auto"),
        key)
    assert _rel(host, ref) <= 1e-10
    assert _rel(val, ref) <= 1e-10
    assert _rel(auto, ref) <= 1e-10
    # handoff: the bond-ramp row (0) and the last row (dangling d-legs) stay
    # on the host pipeline; the saturated interior runs in the superstep
    assert st["rows_spmd"] == 4 and st["rows_host"] == 2, st


# ------------------------------------------------------------- handoff ----

def test_ramp_rows_never_enter_superstep():
    """plan_run refuses non-stationary (bond-ramp) boundaries outright."""
    state = _state(4, 8, 2)
    dtype = state.sites[0][0].dtype
    trivial = [jnp.ones((1, 1, 1, 1), dtype=dtype) for _ in range(8)]
    run, plan = spmd.plan_run(
        spmd.TWO_LAYER, trivial, (state.sites, state.sites), 0, 8,
        _bmps(8).svd, 4, tuple(jax.devices()), "spmd")
    assert run == 0 and plan is None


def test_auto_handoff_counts():
    state = _state(6, 12, 2)
    key = jax.random.PRNGKey(7)
    spmd.reset_stats()
    bmps.norm_squared(state, _opt(8, "spmd"), key)
    st = spmd.stats()
    # rows 1..4 are chi-saturated (chi=8 = D^4/2 saturates after row 0);
    # row 0 (ramp) and row 5 (last row, d-legs dim 1) go to the host path
    assert st["rows_spmd"] == 4 and st["rows_host"] == 2, st
    assert st["superstep_calls"] == 1, st           # one batch of R=4
    # auto on a single device declines (no parallelism to buy); with >= 3
    # distinct devices it engages exactly like spmd
    spmd.reset_stats()
    bmps.norm_squared(state, _opt(8, "auto"), key)
    st = spmd.stats()
    if len(jax.devices()) >= 3:
        assert st["rows_spmd"] == 4, st
    else:
        assert st["rows_spmd"] == 0 and st["rows_host"] == 6, st


def test_spmd_mode_warns_when_never_engaged():
    # 2 rows: row 0 ramps, row 1 is the last row — nothing is saturated
    state = _state(2, 6, 2)
    key = jax.random.PRNGKey(0)
    with pytest.warns(UserWarning, match="never engaged"):
        bmps.norm_squared(state, _opt(8, "spmd"), key)
    # auto never warns
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        bmps.norm_squared(state, _opt(8, "auto"), key)


def test_bond_one_lattice_fully_uniform_columns():
    """Bond-dimension-1 PEPS: every column is shape-uniform, including the
    last one — the plan must still reserve it for the close (regression:
    jr == ncol used to leave the right chain empty and crash the build)."""
    state = _state(5, 8, 1)
    key = jax.random.PRNGKey(3)
    ref = bmps.norm_squared(state, _bmps(4), key)
    val = bmps.norm_squared(state, _opt(4, "spmd"), key)
    auto = bmps.norm_squared(state, _opt(4, "auto"), key)
    assert _rel(val, ref) <= 1e-10
    assert _rel(auto, ref) <= 1e-10


def test_spmd_layout_independent_of_host_blocking():
    """The superstep picks its own uniform split — values must not change
    with the host layout's (n_shards, block)."""
    state = _state(5, 12, 2)
    key = jax.random.PRNGKey(5)
    ref = bmps.norm_squared(state, _bmps(8), key)
    for n_shards, block in [(2, None), (4, 1), (3, 2)]:
        val = bmps.norm_squared(state, _opt(8, "spmd", n_shards, block), key)
        assert _rel(val, ref) <= 1e-10, (n_shards, block)


# ------------------------------------------------------- plan machinery ----

def test_plan_confines_specials_to_edge_blocks():
    state = _state(5, 12, 2)
    key = jax.random.PRNGKey(7)
    spmd.clear()
    bmps.norm_squared(state, _opt(8, "spmd"), key)
    plans = [p for p in spmd._PLAN_CACHE.values() if p is not None]
    assert plans
    for p in plans:
        assert p.ncol % p.n == 0 and p.w == p.ncol // p.n
        if p.n > 1:
            assert p.w >= 2
            assert 1 <= p.jl <= p.w - 1            # left ramp in block 0
            assert p.jr >= (p.n - 1) * p.w + 1     # right ramp in block n-1
            assert p.jr <= p.ncol - 1              # close is always special
        # containers dominate every true shape (storage-only padding)
        for c in range(p.ncol):
            assert all(d <= cd for d, cd in zip(p.sv_shapes[c], p.sv_cont))
    spmd.clear()


def test_superstep_program_cached_across_sweeps():
    state = _state(5, 8, 2)
    key = jax.random.PRNGKey(7)
    spmd.clear()
    bmps.norm_squared(state, _opt(8, "spmd"), key)
    st1 = spmd.stats()
    assert st1["superstep_builds"] >= 1
    bmps.norm_squared(state, _opt(8, "spmd"), key)
    st2 = spmd.stats()
    assert st2["superstep_builds"] == st1["superstep_builds"]  # replayed
    assert st2["superstep_calls"] == st1["superstep_calls"] + 1
    assert st2["plans"] == st1["plans"]                        # plan cache
    spmd.clear()


def test_planner_fused_cache_reused_across_modes():
    """After a single-device warm-up, tracing the superstep replays 100%
    cached fused refactorizations and einsum paths (the per-column
    micro-steps present the same network signatures), and a replayed
    superstep ticks nothing at all — it is one compiled call."""
    planner.clear()
    spmd.clear()
    try:
        state = _state(5, 8, 2)
        key = jax.random.PRNGKey(7)
        bmps.norm_squared(state, _bmps(8), key)            # warm
        before = planner.stats()
        bmps.norm_squared(state, _opt(8, "spmd"), key)     # trace superstep
        delta = planner.stats_since(before)
        assert delta["fused_misses"] == 0, delta
        assert delta["path_misses"] == 0, delta
        assert delta["fused_hits"] > 0, delta
        before = planner.stats()
        bmps.norm_squared(state, _opt(8, "spmd"), key)     # compiled replay
        delta = planner.stats_since(before)
        assert delta["fused_misses"] == 0, delta
        # only the host-path (ramp/last) rows tick at dispatch time now
        assert delta["path_misses"] == 0, delta
    finally:
        planner.clear()
        spmd.clear()


def test_stats_and_clear():
    spmd.clear()
    st = spmd.stats()
    assert st["rows_spmd"] == 0 and st["plan_cache_size"] == 0
    spmd.note_host_rows(3)
    assert spmd.stats()["rows_host"] == 3
    spmd.clear()
    assert spmd.stats()["rows_host"] == 0
