"""Per-kernel interpret-mode allclose sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gram import gram, gram_complex
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.tiled_matmul import tiled_matmul


def _rnd(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- matmul ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 512),
                                   (100, 70, 130), (1, 128, 5), (257, 129, 31)])
def test_tiled_matmul_sweep(shape, dtype):
    m, k, n = shape
    a = _rnd(jax.random.PRNGKey(0), (m, k), dtype)
    b = _rnd(jax.random.PRNGKey(1), (k, n), dtype)
    got = tiled_matmul(a, b, interpret=True)
    want = ref.matmul(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * k ** 0.5)


@settings(deadline=None, max_examples=12)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 1000))
def test_tiled_matmul_property(m, k, n, seed):
    a = _rnd(jax.random.PRNGKey(seed), (m, k), jnp.float32)
    b = _rnd(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
    got = tiled_matmul(a, b, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------------ gram ----
@pytest.mark.parametrize("shape", [(512, 64), (1000, 30), (64, 128), (37, 5)])
def test_gram_sweep(shape):
    a = _rnd(jax.random.PRNGKey(2), shape, jnp.float32)
    got = gram(a, bm=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gram(a)),
                               rtol=1e-4, atol=1e-3)


def test_gram_complex():
    key = jax.random.PRNGKey(3)
    a = (jax.random.normal(key, (300, 20)) +
         1j * jax.random.normal(jax.random.PRNGKey(4), (300, 20)))
    a = a.astype(jnp.complex64)
    got = gram_complex(a, interpret=True)
    want = ref.gram_complex(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-2)


def test_gram_feeds_orthogonalization():
    """The kernel's G supports the Alg. 5 eigh-based isometry construction."""
    a = _rnd(jax.random.PRNGKey(5), (512, 32), jnp.float32)
    g = np.asarray(gram(a, interpret=True), np.float64)
    lam, x = np.linalg.eigh(g)
    lam = np.maximum(lam, 1e-10)
    q = np.asarray(a, np.float64) @ (x / np.sqrt(lam))
    np.testing.assert_allclose(q.T @ q, np.eye(32), atol=1e-3)


# ------------------------------------------------------------- attention ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 128, 64),     # MHA, aligned
    (2, 8, 2, 256, 64),     # GQA 4:1
    (1, 5, 5, 96, 32),      # odd heads, unaligned seq
    (1, 8, 1, 130, 64),     # MQA, unaligned seq
])
def test_flash_attention_sweep(b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rnd(ks[0], (b, hq, s, d), dtype)
    k = _rnd(ks[1], (b, hkv, s, d), dtype)
    v = _rnd(ks[2], (b, hkv, s, d), dtype)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_noncausal_padded():
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = _rnd(ks[0], (1, 2, 100, 32), jnp.float32)
    k = _rnd(ks[1], (1, 2, 75, 32), jnp.float32)   # cross-attn, padded keys
    v = _rnd(ks[2], (1, 2, 75, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- ssd ----
@pytest.mark.parametrize("bh,l,p,n,chunk", [
    (2, 256, 64, 64, 64),
    (1, 100, 32, 16, 32),    # unaligned length
    (3, 64, 64, 128, 64),
])
def test_ssd_scan_sweep(bh, l, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    x = _rnd(ks[0], (bh, l, p), jnp.float32)
    b = _rnd(ks[1], (bh, l, n), jnp.float32) * 0.5
    c = _rnd(ks[2], (bh, l, n), jnp.float32) * 0.5
    a = -jnp.abs(_rnd(ks[3], (bh, l), jnp.float32)) * 0.1  # log-decay <= 0
    got = ssd_scan(x, b, c, a, chunk=chunk, interpret=True)
    want = ref.ssd(x, b, c, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_ssd_matches_attention_limit():
    """With a == 0 (no decay) SSD equals unnormalized linear attention."""
    bh, l, p, n = 1, 64, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    x = _rnd(ks[0], (bh, l, p), jnp.float32)
    b = _rnd(ks[1], (bh, l, n), jnp.float32)
    c = _rnd(ks[2], (bh, l, n), jnp.float32)
    a = jnp.zeros((bh, l), jnp.float32)
    got = ssd_scan(x, b, c, a, chunk=32, interpret=True)
    mask = jnp.tril(jnp.ones((l, l)))
    want = jnp.einsum("bik,bjk,ij,bjp->bip", c, b, mask, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
