"""Per-kernel interpret-mode allclose sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels import zipup_block as zb
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gram import gram, gram_complex
from repro.kernels.matvec import planar_matmul, tall_apply
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.tiled_matmul import tiled_matmul


def _rnd(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- matmul ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 512),
                                   (100, 70, 130), (1, 128, 5), (257, 129, 31)])
def test_tiled_matmul_sweep(shape, dtype):
    m, k, n = shape
    a = _rnd(jax.random.PRNGKey(0), (m, k), dtype)
    b = _rnd(jax.random.PRNGKey(1), (k, n), dtype)
    got = tiled_matmul(a, b, interpret=True)
    want = ref.matmul(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * k ** 0.5)


@settings(deadline=None, max_examples=12)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 1000))
def test_tiled_matmul_property(m, k, n, seed):
    a = _rnd(jax.random.PRNGKey(seed), (m, k), jnp.float32)
    b = _rnd(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
    got = tiled_matmul(a, b, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------------ gram ----
@pytest.mark.parametrize("shape", [(512, 64), (1000, 30), (64, 128), (37, 5)])
def test_gram_sweep(shape):
    a = _rnd(jax.random.PRNGKey(2), shape, jnp.float32)
    got = gram(a, bm=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gram(a)),
                               rtol=1e-4, atol=1e-3)


def test_gram_complex():
    key = jax.random.PRNGKey(3)
    a = (jax.random.normal(key, (300, 20)) +
         1j * jax.random.normal(jax.random.PRNGKey(4), (300, 20)))
    a = a.astype(jnp.complex64)
    got = gram_complex(a, interpret=True)
    want = ref.gram_complex(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-2)


def test_gram_feeds_orthogonalization():
    """The kernel's G supports the Alg. 5 eigh-based isometry construction."""
    a = _rnd(jax.random.PRNGKey(5), (512, 32), jnp.float32)
    g = np.asarray(gram(a, interpret=True), np.float64)
    lam, x = np.linalg.eigh(g)
    lam = np.maximum(lam, 1e-10)
    q = np.asarray(a, np.float64) @ (x / np.sqrt(lam))
    np.testing.assert_allclose(q.T @ q, np.eye(32), atol=1e-3)


# ------------------------------------------------------- tall-apply GEMM ----
@pytest.mark.parametrize("shape", [
    (512, 24, 8),     # the rSVD projection shape class
    (100, 7, 1),      # N=1: single output column (rank-1 projection)
    (37, 3, 130),     # N over the 128-lane pad boundary
    (257, 129, 127),  # every dim non-tile-multiple, N just under the pad
    (1, 5, 5),        # single row
])
def test_tall_apply_sweep(shape):
    m, k, n = shape
    a = _rnd(jax.random.PRNGKey(11), (m, k), jnp.float32)
    b = _rnd(jax.random.PRNGKey(12), (k, n), jnp.float32)
    got = tall_apply(a, b, bm=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(m=st.integers(1, 300), k=st.integers(1, 40), n=st.integers(1, 160),
       seed=st.integers(0, 1000))
def test_tall_apply_property(m, k, n, seed):
    a = _rnd(jax.random.PRNGKey(seed), (m, k), jnp.float32)
    b = _rnd(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
    got = tall_apply(a, b, bm=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)


@settings(deadline=None, max_examples=8)
@given(m=st.integers(1, 200), k=st.integers(1, 30), n=st.integers(1, 140),
       seed=st.integers(0, 1000))
def test_planar_matmul_complex_property(m, k, n, seed):
    """The complex planar path: one doubled real GEMM equals the complex
    product (exactly the c64 contraction, not an approximation)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    a = (jax.random.normal(ks[0], (m, k)) +
         1j * jax.random.normal(ks[1], (m, k))).astype(jnp.complex64)
    b = (jax.random.normal(ks[2], (k, n)) +
         1j * jax.random.normal(ks[3], (k, n))).astype(jnp.complex64)
    got = planar_matmul(a, b, bm=64, interpret=True)
    assert got.dtype == jnp.complex64
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=2e-4, atol=2e-3)


def test_planar_matmul_real_passthrough():
    a = _rnd(jax.random.PRNGKey(13), (96, 17), jnp.float32)
    b = _rnd(jax.random.PRNGKey(14), (17, 4), jnp.float32)
    got = planar_matmul(a, b, interpret=True)
    want = tall_apply(a, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tall_apply_bf16_compute_bounded():
    """bf16 multiplicands + f32 accumulation: ~3 decimal digits survive."""
    a = _rnd(jax.random.PRNGKey(15), (512, 24), jnp.float32)
    b = _rnd(jax.random.PRNGKey(16), (24, 8), jnp.float32)
    got = np.asarray(tall_apply(a, b, interpret=True, compute="bfloat16"),
                     np.float64)
    want = np.asarray(a @ b, np.float64)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert 1e-8 < rel <= 2e-2   # bf16-sized, i.e. compute= actually engaged


# ------------------------------------------------------ zip-up micro-ops ----
def _cplx(key, shape):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, shape) +
            1j * jax.random.normal(k2, shape)).astype(jnp.complex64)


@settings(deadline=None, max_examples=8)
@given(b=st.integers(1, 4), f=st.integers(1, 5), g=st.integers(1, 6),
       c=st.integers(1, 4), h=st.integers(1, 3), k=st.integers(1, 3),
       seed=st.integers(0, 1000))
def test_zipup_first_onelayer_property(b, f, g, c, h, k, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    s0 = _rnd(ks[0], (b, f, g), jnp.float32)
    o0 = _rnd(ks[1], (f, c, h, k), jnp.float32)
    got = zb._first_onelayer_pallas(s0, o0)
    want = zb._first_onelayer_dense(s0, o0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=6)
@given(b=st.integers(1, 3), f=st.integers(1, 3), g=st.integers(1, 4),
       c=st.integers(1, 3), h=st.integers(1, 2), k=st.integers(1, 2),
       p=st.integers(1, 2), seed=st.integers(0, 1000))
def test_zipup_first_twolayer_property(b, f, g, c, h, k, p, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    s0 = _cplx(ks[0], (b, f, f, g))
    tb0 = _cplx(ks[1], (p, f, c, h, k))
    tk0 = _cplx(ks[2], (p, f, c, h, k))
    got = zb._first_twolayer_pallas(s0, tb0, tk0)
    want = zb._first_twolayer_dense(s0, tb0, tk0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(deadline=None, max_examples=6)
@given(p=st.integers(1, 2), u=st.integers(1, 3), l=st.integers(1, 3),
       d=st.integers(1, 3), r=st.integers(1, 3), seed=st.integers(0, 1000))
def test_zipup_pair_merge_property(p, u, l, d, r, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    tb = _cplx(ks[0], (p, u, l, d, r))
    tk = _cplx(ks[1], (p, u, l, d, r))
    got = zb._pair_merge_pallas(tb.conj(), tk)
    want = zb._pair_merge_dense(tb.conj(), tk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_gram_complex_imag_exactly_antisymmetric():
    """The planar Gram builds imag(G) as ``g_ri - g_ri.T`` — antisymmetry
    is exact by construction (array_equal, not allclose), which is what
    keeps eigh's Hermitian assumption safe downstream."""
    a = _cplx(jax.random.PRNGKey(17), (200, 24))
    g = np.asarray(gram_complex(a, interpret=True))
    np.testing.assert_array_equal(g.imag, -g.imag.T)
    np.testing.assert_array_equal(g.real, g.real.T)


# ------------------------------------------------------------- attention ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 128, 64),     # MHA, aligned
    (2, 8, 2, 256, 64),     # GQA 4:1
    (1, 5, 5, 96, 32),      # odd heads, unaligned seq
    (1, 8, 1, 130, 64),     # MQA, unaligned seq
])
def test_flash_attention_sweep(b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rnd(ks[0], (b, hq, s, d), dtype)
    k = _rnd(ks[1], (b, hkv, s, d), dtype)
    v = _rnd(ks[2], (b, hkv, s, d), dtype)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_noncausal_padded():
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = _rnd(ks[0], (1, 2, 100, 32), jnp.float32)
    k = _rnd(ks[1], (1, 2, 75, 32), jnp.float32)   # cross-attn, padded keys
    v = _rnd(ks[2], (1, 2, 75, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- ssd ----
@pytest.mark.parametrize("bh,l,p,n,chunk", [
    (2, 256, 64, 64, 64),
    (1, 100, 32, 16, 32),    # unaligned length
    (3, 64, 64, 128, 64),
])
def test_ssd_scan_sweep(bh, l, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    x = _rnd(ks[0], (bh, l, p), jnp.float32)
    b = _rnd(ks[1], (bh, l, n), jnp.float32) * 0.5
    c = _rnd(ks[2], (bh, l, n), jnp.float32) * 0.5
    a = -jnp.abs(_rnd(ks[3], (bh, l), jnp.float32)) * 0.1  # log-decay <= 0
    got = ssd_scan(x, b, c, a, chunk=chunk, interpret=True)
    want = ref.ssd(x, b, c, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_ssd_matches_attention_limit():
    """With a == 0 (no decay) SSD equals unnormalized linear attention."""
    bh, l, p, n = 1, 64, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    x = _rnd(ks[0], (bh, l, p), jnp.float32)
    b = _rnd(ks[1], (bh, l, n), jnp.float32)
    c = _rnd(ks[2], (bh, l, n), jnp.float32)
    a = jnp.zeros((bh, l), jnp.float32)
    got = ssd_scan(x, b, c, a, chunk=32, interpret=True)
    mask = jnp.tril(jnp.ones((l, l)))
    want = jnp.einsum("bik,bjk,ij,bjp->bip", c, b, mask, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
