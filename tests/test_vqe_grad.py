"""Gradient correctness for the differentiable VQE stack (ISSUE 10).

The contract under test (docs/vqe.md): ``vqe_energy_peps`` is a pure,
traceable JAX function whose ``jax.grad`` agrees with central finite
differences to relative error <= 1e-4 across lattice sizes, contraction
bond dimensions, and boundary engines — including the degenerate-spectrum
cases (product states carry exact-zero singular values on every bond)
where the unregularized SVD/QR differentials diverge.

Also under test: the regularized linear-algebra wrappers themselves
(forward bit-identity + finite gradients at degeneracy), the vmapped
ensemble drivers' member-PRNG contract (a member's trajectory is
independent of the ensemble size), and mesh-sharded == unsharded
execution of a batched run.

Run via ``make test-vqe`` (launches with 8 virtual CPU devices so the
mesh test exercises real sharding).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.bmps import BMPS
from repro.core.einsumsvd import RandomizedSVD
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import QRUpdate
from repro.core.svd_grad import qr_reg, sqrt_reg, svd_reg
from repro.core.vqe import (run_vqe, vqe_energy_and_grad, vqe_energy_peps,
                            vqe_energy_statevector)

AD_FD_RTOL = 1e-4     # acceptance: AD vs central FD relative error
FD_STEP = 1e-5


def _fd_check(f, thetas, grad, components, rtol=AD_FD_RTOL):
    """Central finite differences on selected components vs the AD grad."""
    thetas = np.asarray(thetas, dtype=np.float64)
    for i in components:
        d = np.zeros_like(thetas)
        d[i] = FD_STEP
        fd = (float(f(thetas + d)) - float(f(thetas - d))) / (2 * FD_STEP)
        ad = float(grad[i])
        assert abs(ad - fd) <= rtol * max(abs(fd), 1e-8), (
            f"component {i}: ad={ad!r} fd={fd!r}")


def _energy_fn(nrow, ncol, obs, update, contract):
    return lambda th: vqe_energy_peps(th, nrow, ncol, obs, update, contract)


# ---------------------------------------------------------------------------
# AD vs central FD: the property sweep
# ---------------------------------------------------------------------------

# module-level (not a method): the hypothesis-compat fallback runner takes
# only the strategy kwargs
@settings(max_examples=4, deadline=None)
@given(grid=st.sampled_from([(2, 2), (2, 3)]),
       chi=st.sampled_from([6, 8]),
       engine=st.sampled_from(["zipup", "variational"]),
       seed=st.integers(0, 10**6))
def test_grad_matches_fd_property_sweep(grid, chi, engine, seed):
    nrow, ncol = grid
    obs = tfi_hamiltonian(nrow, ncol)
    update, contract = QRUpdate(rank=3), BMPS(chi, engine=engine)
    n = nrow * ncol
    th = np.random.default_rng(seed).uniform(-0.7, 0.7, n)
    e, g = vqe_energy_and_grad(th, nrow, ncol, obs, update, contract)
    assert np.isfinite(float(e))
    assert np.all(np.isfinite(np.asarray(g)))
    # energy of the compiled value_and_grad == the eager evaluation
    e_direct = float(vqe_energy_peps(th, nrow, ncol, obs, update, contract))
    assert abs(float(e) - e_direct) <= 1e-10 * max(abs(e_direct), 1.0)
    rng = np.random.default_rng(seed + 1)
    comps = rng.choice(n, size=min(2, n), replace=False)
    _fd_check(_energy_fn(nrow, ncol, obs, update, contract), th, g, comps)


class TestGradMatchesFiniteDifferences:
    def test_3x3_zipup(self):
        obs = tfi_hamiltonian(3, 3)
        update, contract = QRUpdate(rank=2), BMPS(8)
        th = np.random.default_rng(7).uniform(-0.7, 0.7, 9)
        e, g = vqe_energy_and_grad(th, 3, 3, obs, update, contract)
        assert np.all(np.isfinite(np.asarray(g)))
        _fd_check(_energy_fn(3, 3, obs, update, contract), th, g, [4])

    def test_randomized_svd_path(self):
        """RandomizedSVD differentiates through the whole regularized power
        iteration (the random sketch itself is a PRNG constant).  Stopping
        the gradient at the converged range basis instead would amputate the
        rank-growing components of the perturbation — measured as a 100%
        loss on some components — so AD must match FD here just like on the
        DirectSVD path."""
        obs = tfi_hamiltonian(2, 2)
        svd = RandomizedSVD(niter=4, oversample=8)
        update = QRUpdate(rank=3, svd=svd)
        contract = BMPS(8, svd=svd)
        th = np.random.default_rng(11).uniform(-0.7, 0.7, 4)
        e, g = vqe_energy_and_grad(th, 2, 2, obs, update, contract)
        assert np.all(np.isfinite(np.asarray(g)))
        _fd_check(_energy_fn(2, 2, obs, update, contract), th, g, [0, 2])

    def test_exact_chi_matches_statevector_gradient(self):
        """With the bond/chi budget exact for the lattice, the PEPS gradient
        IS the statevector gradient (the truncation seam differentiates
        exactly, not approximately)."""
        obs = tfi_hamiltonian(2, 2)
        update, contract = QRUpdate(rank=4), BMPS(16)
        th = np.random.default_rng(3).uniform(-0.6, 0.6, 8)
        _, g = vqe_energy_and_grad(th, 2, 2, obs, update, contract)
        g_sv = jax.grad(
            lambda t: vqe_energy_statevector(t, 2, 2, obs))(jnp.asarray(th))
        assert float(jnp.max(jnp.abs(g - g_sv))) <= 1e-8

    def test_degenerate_product_state(self):
        """thetas = 0 is the maximally degenerate case — a product state
        whose every bond carries exact-zero singular values (the
        unregularized SVD differential divides by zero).  At the exact
        degenerate point the truncation map is only *directionally*
        differentiable (rank-growing perturbations pick a branch), so the
        contract is a FINITE regularized VJP there — not exactness; the
        regularizer suppresses the ill-defined rank-growth components
        instead of returning NaN.  One ulp of smoothness away (theta =
        0.01, singular-value gaps ~1e-4 >> the broadening tol) the gradient
        is the exact statevector gradient again."""
        obs = tfi_hamiltonian(2, 2)
        update, contract = QRUpdate(rank=4), BMPS(16)
        th = np.zeros(8)
        e, g = vqe_energy_and_grad(th, 2, 2, obs, update, contract)
        assert np.isfinite(float(e))
        assert np.all(np.isfinite(np.asarray(g)))
        # The well-defined components (those that do not grow the bond
        # rank) still match the statevector gradient exactly.
        g_sv = jax.grad(
            lambda t: vqe_energy_statevector(t, 2, 2, obs))(jnp.asarray(th))
        assert float(jnp.max(jnp.abs(g - g_sv)[1:4])) <= 1e-8
        # Off the measure-zero degenerate point, exactness is restored.
        thn = np.full(8, 0.01)
        _, gn = vqe_energy_and_grad(thn, 2, 2, obs, update, contract)
        g_svn = jax.grad(
            lambda t: vqe_energy_statevector(t, 2, 2, obs))(jnp.asarray(thn))
        assert float(jnp.max(jnp.abs(gn - g_svn))) <= 1e-8

    def test_jit_and_vmap_compose(self):
        """The energy is a first-class JAX function: jit(grad(f)) and
        vmap(f) agree with the eager path."""
        obs = tfi_hamiltonian(2, 2)
        update, contract = QRUpdate(rank=2), BMPS(4)
        f = _energy_fn(2, 2, obs, update, contract)
        ths = np.random.default_rng(5).uniform(-0.5, 0.5, (3, 4))
        batched = jax.vmap(f)(jnp.asarray(ths))
        for i in range(3):
            assert abs(float(batched[i]) - float(f(ths[i]))) <= 1e-10
        g_jit = jax.jit(jax.grad(f))(jnp.asarray(ths[0]))
        g_eager = jax.grad(f)(jnp.asarray(ths[0]))
        assert float(jnp.max(jnp.abs(g_jit - g_eager))) <= 1e-12


# ---------------------------------------------------------------------------
# The regularized wrappers themselves
# ---------------------------------------------------------------------------

class TestRegularizedWrappers:
    def test_svd_reg_forward_bit_identical(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(6, 4)) + 1j * rng.normal(size=(6, 4)))
        u1, s1, v1 = svd_reg(a)
        u2, s2, v2 = jnp.linalg.svd(a, full_matrices=False)
        assert jnp.array_equal(u1, u2)
        assert jnp.array_equal(s1, s2)
        assert jnp.array_equal(v1, v2)

    def test_svd_reg_generic_matches_builtin_gradient(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.normal(size=(5, 5)))

        def loss(svd):
            def inner(x):
                u, s, vh = svd(x)
                k = 3
                rec = (u[:, :k] * s[:k]) @ vh[:k]
                return jnp.sum(rec ** 2) + jnp.sum(s * jnp.arange(5.0))
            return inner
        g1 = jax.grad(loss(svd_reg))(a)
        g2 = jax.grad(loss(lambda x: jnp.linalg.svd(
            x, full_matrices=False)))(a)
        assert float(jnp.max(jnp.abs(g1 - g2))) <= 1e-10

    def test_svd_reg_degenerate_spectrum_finite(self):
        """Exactly repeated and exactly zero singular values: the builtin
        differential divides by zero; the regularized one is finite, and
        for a gauge-invariant loss (truncated reconstruction) it matches
        the exact answer (zero at a critical point)."""
        rng = np.random.default_rng(2)
        q1, _ = np.linalg.qr(rng.normal(size=(6, 6)))
        q2, _ = np.linalg.qr(rng.normal(size=(6, 6)))
        s = np.array([2.0, 2.0, 1.0, 0.0, 0.0, 0.0])
        a = jnp.asarray(q1 @ np.diag(s) @ q2.T)

        def loss(x):
            u, sv, vh = svd_reg(x)
            k = 3
            rec = (u[:, :k] * sv[:k]) @ vh[:k]
            return jnp.sum((rec - x) ** 2)
        g = jax.grad(loss)(a)
        assert np.all(np.isfinite(np.asarray(g)))
        # truncating at the exact rank: reconstruction is exact, the loss
        # sits at a (degenerate) minimum, so the true gradient is 0
        assert float(jnp.max(jnp.abs(g))) <= 1e-8

    def test_sqrt_reg_zero_has_zero_derivative(self):
        g = jax.grad(lambda x: jnp.sum(sqrt_reg(x)))(jnp.array([0.0, 4.0]))
        assert float(g[0]) == 0.0
        assert abs(float(g[1]) - 0.25) <= 1e-12

    def test_qr_reg_forward_bit_identical_and_rankdef_bounded(self):
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.normal(size=(7, 4)))
        q1, r1 = qr_reg(a)
        res = jnp.linalg.qr(a)
        assert jnp.array_equal(q1, res[0]) and jnp.array_equal(r1, res[1])
        # numerically rank-deficient operand: gradient of the (gauge-
        # invariant) reconstruction loss stays tiny instead of ~1/sigma_min
        b = np.column_stack([rng.normal(size=7), rng.normal(size=7) * 1e-16,
                             rng.normal(size=7), np.zeros(7)])

        def loss(x):
            q, r = qr_reg(x)
            return jnp.sum((q @ r - x) ** 2)
        g = jax.grad(loss)(jnp.asarray(b))
        assert np.all(np.isfinite(np.asarray(g)))
        assert float(jnp.max(jnp.abs(g))) <= 1e-8


# ---------------------------------------------------------------------------
# Batched ensemble drivers: PRNG contract, mesh composition
# ---------------------------------------------------------------------------

OBS22 = tfi_hamiltonian(2, 2)


class TestEnsembleDrivers:
    def test_adam_member_trajectory_independent_of_ensemble_size(self):
        """Member i's PRNG streams are keyed on (seed, iteration, i) only,
        so member 0 of an ensemble-of-4 replays the ensemble-of-1 run (up
        to XLA batching reassociation, <= 1e-12)."""
        kw = dict(n_layers=1, max_bond=2, maxiter=6, seed=0, method="adam",
                  lr=0.1)
        r4 = run_vqe(2, 2, OBS22, **kw, ensemble=4)
        r1 = run_vqe(2, 2, OBS22, **kw, ensemble=1)
        assert np.max(np.abs(r4.ensemble_thetas[0]
                             - r1.ensemble_thetas[0])) <= 1e-12
        assert np.max(np.abs(r4.ensemble_history[:, 0]
                             - r1.ensemble_history[:, 0])) <= 1e-12

    def test_spsa_member_trajectory_independent_of_ensemble_size(self):
        kw = dict(n_layers=1, max_bond=2, maxiter=6, seed=1, method="spsa")
        r2 = run_vqe(2, 2, OBS22, **kw, ensemble=2)
        r4 = run_vqe(2, 2, OBS22, **kw, ensemble=4)
        assert np.max(np.abs(r4.ensemble_thetas[:2]
                             - r2.ensemble_thetas)) <= 1e-12

    def test_batched_result_exposes_best_member(self):
        r = run_vqe(2, 2, OBS22, n_layers=1, max_bond=2, maxiter=4, seed=0,
                    method="adam", ensemble=3, lr=0.1)
        assert r.ensemble_thetas.shape == (3, 4)
        assert r.ensemble_energies.shape == (3,)
        assert r.ensemble_history.shape == (4, 3)
        best = int(np.argmin(r.ensemble_energies))
        assert r.energy == pytest.approx(r.ensemble_energies[best])
        assert np.array_equal(r.thetas, r.ensemble_thetas[best])
        # history holds the per-iteration best (sequential consumers see a
        # monotone-ish scalar trace, not the member matrix)
        assert len(r.history) == 5    # maxiter proxies + final exact eval

    def test_ensemble_requires_batched_driver(self):
        with pytest.raises(ValueError, match="batched driver"):
            run_vqe(2, 2, OBS22, n_layers=1, max_bond=2, maxiter=2,
                    method="SLSQP", ensemble=4)

    @pytest.mark.skipif(jax.device_count() < 8,
                        reason="needs 8 devices (make test-vqe forces 8)")
    def test_mesh_sharded_matches_unsharded(self):
        from repro.launch.mesh import peps_mesh
        kw = dict(n_layers=1, max_bond=2, maxiter=5, seed=0, method="adam",
                  ensemble=8, lr=0.1)
        rm = run_vqe(2, 2, OBS22, **kw, mesh=peps_mesh(2, 4))
        ru = run_vqe(2, 2, OBS22, **kw)
        assert np.max(np.abs(rm.ensemble_thetas
                             - ru.ensemble_thetas)) <= 1e-10
        assert np.max(np.abs(rm.ensemble_energies
                             - ru.ensemble_energies)) <= 1e-10

    def test_ensemble_sharding_spec_shapes(self):
        from repro.core.sharding import ensemble_sharding, shard_ensemble
        from repro.launch.mesh import peps_mesh
        if jax.device_count() < 8:
            pytest.skip("needs 8 devices (make test-vqe forces 8)")
        mesh = peps_mesh(2, 4)
        # divisible by the full device count: member axis over all axes
        s = ensemble_sharding(mesh, 8, 2)
        assert s.spec == jax.sharding.PartitionSpec(("col", "batch"), None)
        # divisible by one trailing axis only
        s = ensemble_sharding(mesh, 4, 2)
        assert s.spec == jax.sharding.PartitionSpec("batch", None)
        # indivisible: replicated
        s = ensemble_sharding(mesh, 3, 2)
        assert s.spec == jax.sharding.PartitionSpec(None, None)
        tree = {"x": jnp.zeros((8, 4)), "count": jnp.zeros((8,))}
        sharded = shard_ensemble(tree, mesh, 8)
        assert len(sharded["x"].sharding.device_set) == 8
