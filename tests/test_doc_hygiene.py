"""The doc-hygiene checker itself: repo docs stay clean, rot is caught.

`tools/check_doc_links.py` runs in CI *without* the package installed, so
it must stay import-free over repo code; these tests load it by path the
same way and exercise both directions (current docs pass; planted broken
links and stale code references fail).
"""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load():
    spec = importlib.util.spec_from_file_location(
        "check_doc_links", REPO / "tools" / "check_doc_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_repo_docs_are_clean():
    assert _load().main() == 0


def test_stale_code_refs_detected(tmp_path):
    m = _load()
    doc = tmp_path / "x.md"
    doc.write_text(
        "Good: `core/spmd.py`, `bmps.zipup_block`, `repro.core.planner`,\n"
        "`tests/test_spmd.py::test_amplitude_spmd_matches`,\n"
        "`environments.strip_boundary`, `docs/contraction.md`.\n"
        "Out of scope: `jax.random.split`, `np.asarray`, `opt.chi`,\n"
        "`DistributedBMPS.for_mesh`, `0.4.37`, `state.sites`.\n"
        "Stale: `core/nonexistent.py`, `bmps.zipup_block_gone`,\n"
        "`repro.core.spdm`, `tests/test_spmd.py::test_gone`.\n"
        "Fenced code is ignored:\n```\n`core/also_gone.py`\n```\n")
    stale = m.check_code_refs(doc, m._module_index())
    assert set(stale) == {"core/nonexistent.py", "bmps.zipup_block_gone",
                          "repro.core.spdm", "tests/test_spmd.py::test_gone"}


def test_broken_links_detected(tmp_path):
    m = _load()
    doc = tmp_path / "y.md"
    doc.write_text("[ok](y.md) and [broken](missing_file.md) "
                   "and [external](https://example.com/x.md)\n")
    broken = m.check_file(doc)
    assert len(broken) == 1 and broken[0][0] == "missing_file.md"
