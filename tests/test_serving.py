"""Serving test tier (ISSUE 9): batched query serving vs per-query truth.

Three families, mirroring the module contract of ``repro.core.serving``:

* **Equivalence** — served amplitudes (prefix cache + batched final-row
  close) match per-query ``bmps.amplitude`` to <= 1e-10 across bitstrings,
  grid shapes, chi, both boundary engines and ragged batch sizes; served
  expectations match ``expectation.expectation``.
* **Concurrency** — threaded clients against >= 2 states: no lost,
  duplicated or cross-wired responses, arrival-order independence, and
  cache counters that reconcile against the query log.
* **Cache lifecycle** — re-registration invalidates (stale environments
  would be silently wrong answers), ``max_states`` LRU eviction
  re-materializes, prefix LRU eviction recomputes, and eviction never
  corrupts an in-flight batch.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import bmps as B
from repro.core import peps as P
from repro.core import planner
from repro.core.distributed import DistributedBMPS
from repro.core.einsumsvd import DirectSVD, RandomizedSVD
from repro.core.environments import onelayer_prefix_environment
from repro.core.expectation import expectation
from repro.core.observable import Observable
from repro.core.serving import DEFAULT_BUCKETS, LRUCache, ServingEngine

OPT = B.BMPS(8, DirectSVD())


@pytest.fixture(scope="module")
def state33():
    return P.random_peps(3, 3, 2, jax.random.PRNGKey(7))


@pytest.fixture(scope="module")
def state33b():
    return P.random_peps(3, 3, 2, jax.random.PRNGKey(8))


@pytest.fixture(scope="module")
def state23():
    return P.random_peps(2, 3, 2, jax.random.PRNGKey(9))


def _bits(state, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, (n, state.nrow, state.ncol))


def _direct(state, bits_batch, option=OPT):
    return np.array([complex(B.amplitude(state, b, option))
                     for b in bits_batch])


def _assert_close(served, direct, tol=1e-10):
    served = np.asarray(served)
    scale = max(1.0, float(np.abs(direct).max()))
    assert np.abs(served - direct).max() <= tol * scale


# ---------------------------------------------------------------------------
# Equivalence: served == per-query bmps.amplitude
# ---------------------------------------------------------------------------

def test_final_row_amplitudes_matches_per_query(state33):
    bits = _bits(state33, 6, seed=1)
    bits[:, :-1] = bits[0, :-1]  # shared prefix
    env = onelayer_prefix_environment(state33, bits[0, :-1], OPT)
    out = B.final_row_amplitudes(env, state33.sites[-1],
                                 bits[:, -1, :], state33.log_scale)
    _assert_close(out, _direct(state33, bits))


def test_bmps_amplitudes_mixed_prefixes(state33):
    bits = _bits(state33, 7, seed=2)  # several distinct prefixes
    out = B.amplitudes(state33, bits, OPT)
    _assert_close(out, _direct(state33, bits))


def test_served_batch_matches_per_query(state33):
    with ServingEngine(start=False) as eng:
        eng.register_state("a", state33, OPT)
        bits = _bits(state33, 9, seed=3)
        _assert_close(eng.amplitude_batch("a", bits), _direct(state33, bits))


@settings(max_examples=6, deadline=None)
@given(nrow=st.integers(2, 3), ncol=st.integers(2, 3),
       chi=st.sampled_from([2, 4, 8]), seed=st.integers(0, 10**6))
def test_property_served_equals_per_query(nrow, ncol, chi, seed):
    state = P.random_peps(nrow, ncol, 2, jax.random.PRNGKey(seed % 97))
    option = B.BMPS(chi, DirectSVD())
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (rng.integers(1, 6), nrow, ncol))
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state, option)
        _assert_close(eng.amplitude_batch("s", bits),
                      _direct(state, bits, option))


@pytest.mark.parametrize("engine", ["zipup", "variational"])
def test_served_both_engines(state33, engine):
    option = B.BMPS(4, DirectSVD(), engine=engine)
    bits = _bits(state33, 5, seed=4)
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, option)
        _assert_close(eng.amplitude_batch("s", bits),
                      _direct(state33, bits, option))


def test_served_randomized_svd(state33):
    option = B.BMPS(4, RandomizedSVD(niter=4, oversample=8))
    bits = _bits(state33, 5, seed=5)
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, option)
        _assert_close(eng.amplitude_batch("s", bits),
                      _direct(state33, bits, option))


@pytest.mark.parametrize("n", [1, 5, 150])
def test_served_ragged_batch_sizes(state33, n):
    # 1 (smallest bucket), 5 (not a bucket multiple), 150 (> largest bucket)
    with ServingEngine(start=False) as eng:
        eng.register_state("a", state33, OPT)
        bits = _bits(state33, n, seed=n)
        bits[:, :-1] = bits[0, :-1]  # one group, so chunking is exercised
        _assert_close(eng.amplitude_batch("a", bits), _direct(state33, bits))


def test_served_single_query_layouts(state33):
    with ServingEngine(start=False) as eng:
        eng.register_state("a", state33, OPT)
        bits = _bits(state33, 1, seed=6)[0]
        want = complex(B.amplitude(state33, bits, OPT))
        got_grid = complex(eng.amplitude("a", bits))
        got_flat = complex(eng.amplitude("a", bits.reshape(-1)))
        assert got_grid == got_flat
        _assert_close(np.array([got_grid]), np.array([want]))


def test_served_one_row_state():
    state = P.random_peps(1, 4, 2, jax.random.PRNGKey(12))
    bits = _bits(state, 4, seed=7)
    with ServingEngine(start=False) as eng:
        eng.register_state("row", state, OPT)
        _assert_close(eng.amplitude_batch("row", bits), _direct(state, bits))


def test_served_respects_log_scale(state33):
    scaled = P.PEPS([[t for t in row] for row in state33.sites],
                    log_scale=0.7)
    bits = _bits(state33, 3, seed=8)
    with ServingEngine(start=False) as eng:
        eng.register_state("s", scaled, OPT)
        _assert_close(eng.amplitude_batch("s", bits), _direct(scaled, bits))


def test_served_expectation_matches_direct(state33):
    obs = Observable.Z(0) + Observable.XX(0, 1) + Observable.ZZ(1, 4)
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, OPT)
        got = complex(eng.expectation("s", obs))
        want = complex(expectation(state33, obs, OPT))
        assert abs(got - want) <= 1e-12 * max(1.0, abs(want))


def test_served_expectation_custom_env_key(state33):
    obs = Observable.Z(4)
    key = jax.random.PRNGKey(33)
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, OPT, env_key=key)
        got = complex(eng.expectation("s", obs))
        want = complex(expectation(state33, obs, OPT, key=key))
        assert abs(got - want) <= 1e-12 * max(1.0, abs(want))


def test_register_rejects_distributed_option(state33):
    with ServingEngine(start=False) as eng:
        with pytest.raises(TypeError):
            eng.register_state("d", state33, DistributedBMPS(4))
        with pytest.raises(TypeError):
            eng.register_state("d", state33, "not-an-option")


def test_bmps_amplitudes_rejects_distributed(state33):
    with pytest.raises(TypeError):
        B.amplitudes(state33, _bits(state33, 2), DistributedBMPS(4))


# ---------------------------------------------------------------------------
# Concurrency: threaded clients, >= 2 states
# ---------------------------------------------------------------------------

def test_threaded_no_lost_dup_or_crosswired(state33, state33b):
    with ServingEngine(window_ms=5.0) as eng:
        eng.register_state("a", state33, OPT)
        eng.register_state("b", state33b, OPT)
        states = {"a": state33, "b": state33b}
        results = {}
        res_lock = threading.Lock()

        def client(cid):
            rng = np.random.default_rng(100 + cid)
            futs = []
            for q in range(10):
                name = ("a", "b")[rng.integers(2)]
                bits = rng.integers(0, 2, (3, 3))
                futs.append((name, bits, eng.submit_amplitude(name, bits)))
            for name, bits, fut in futs:
                v = complex(fut.result(timeout=120))
                with res_lock:
                    results[(cid, name, bits.tobytes())] = v

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        assert len(results) == 6 * 10 or len(results) >= 40  # dedup by key
        for (cid, name, raw), v in results.items():
            bits = np.frombuffer(raw, dtype=np.int64).reshape(3, 3)
            want = complex(B.amplitude(states[name], bits, OPT))
            assert abs(v - want) <= 1e-10 * max(1.0, abs(want)), \
                f"cross-wired or corrupted response for client {cid}"
        st_ = eng.stats()
        assert st_["queries_amplitude"] == 60


def test_threaded_arrival_order_independence(state33):
    bits = _bits(state33, 8, seed=11)
    with ServingEngine(window_ms=5.0) as eng:
        eng.register_state("a", state33, OPT)
        futs = [eng.submit_amplitude("a", b) for b in bits]
        first = [complex(f.result(timeout=120)) for f in futs]
        futs = [eng.submit_amplitude("a", b) for b in reversed(bits)]
        second = [complex(f.result(timeout=120)) for f in reversed(futs)]
        assert first == second


def test_threaded_mixed_kinds(state33, state33b):
    obs = Observable.Z(0)
    with ServingEngine(window_ms=5.0) as eng:
        eng.register_state("a", state33, OPT)
        eng.register_state("b", state33b, OPT)
        bits = _bits(state33, 4, seed=12)
        amp_futs = [eng.submit_amplitude("a", b) for b in bits]
        exp_futs = [eng.submit_expectation(n, obs) for n in ("a", "b")]
        _assert_close(np.array([complex(f.result(120)) for f in amp_futs]),
                      _direct(state33, bits))
        want_a = complex(expectation(state33, obs, OPT))
        want_b = complex(expectation(state33b, obs, OPT))
        assert abs(complex(exp_futs[0].result(120)) - want_a) <= 1e-12 * max(1.0, abs(want_a))
        assert abs(complex(exp_futs[1].result(120)) - want_b) <= 1e-12 * max(1.0, abs(want_b))


def test_stats_reconcile_with_query_log(state33):
    with ServingEngine(start=False) as eng:
        eng.register_state("a", state33, OPT)
        bits = _bits(state33, 4, seed=13)
        bits[:2, :-1] = bits[0, :-1]  # exactly 3 distinct prefixes
        bits[2:, :-1] = bits[2, :-1]
        prefixes = {b[:-1].tobytes() for b in bits}
        eng.amplitude_batch("a", bits)
        eng.amplitude_batch("a", bits)  # identical second round: all hits
        st_ = eng.stats()
        ps = st_["per_state"]["a"]
        assert st_["queries_amplitude"] == 8
        assert st_["batches"] == 2
        # one counted lookup per query group; first round misses every
        # distinct prefix, second round hits every one
        assert ps["prefix_misses"] == len(prefixes)
        assert ps["prefix_hits"] == len(prefixes)
        # a 3-row state absorbs one row per fresh prefix (row 0 is the base)
        assert st_["rows_absorbed"] == len(prefixes)


def test_threaded_stats_consistency(state33, state33b):
    with ServingEngine(window_ms=5.0) as eng:
        eng.register_state("a", state33, OPT)
        eng.register_state("b", state33b, OPT)
        per_state_prefixes = {"a": set(), "b": set()}
        futs = []
        rng = np.random.default_rng(14)
        for q in range(30):
            name = ("a", "b")[q % 2]
            bits = rng.integers(0, 2, (3, 3))
            per_state_prefixes[name].add(bits[:-1].tobytes())
            futs.append(eng.submit_amplitude(name, bits))
        for f in futs:
            f.result(timeout=120)
        st_ = eng.stats()
        assert st_["queries_amplitude"] == 30
        for name in ("a", "b"):
            ps = st_["per_state"][name]
            lookups = ps["prefix_hits"] + ps["prefix_misses"]
            # one counted lookup per executed query group
            assert len(per_state_prefixes[name]) <= lookups <= 15
            assert ps["prefix_misses"] == len(per_state_prefixes[name])


def test_submit_unknown_state_resolves_to_error(state33):
    with ServingEngine() as eng:
        eng.register_state("a", state33, OPT)
        fut = eng.submit_amplitude("nope", np.zeros((3, 3), dtype=int))
        with pytest.raises(KeyError):
            fut.result(timeout=120)
        # the engine survives: later queries still serve
        good = eng.submit_amplitude("a", np.zeros((3, 3), dtype=int))
        complex(good.result(timeout=120))


def test_submit_bad_shape_resolves_to_error(state33):
    with ServingEngine() as eng:
        eng.register_state("a", state33, OPT)
        fut = eng.submit_amplitude("a", np.zeros((2, 2), dtype=int))
        with pytest.raises(ValueError):
            fut.result(timeout=120)


# ---------------------------------------------------------------------------
# Cache lifecycle
# ---------------------------------------------------------------------------

def test_reregister_invalidates_prefix_envs(state33, state33b):
    bits = _bits(state33, 3, seed=15)
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, OPT)
        old = np.asarray(eng.amplitude_batch("s", bits))
        eng.register_state("s", state33b, OPT)
        new = np.asarray(eng.amplitude_batch("s", bits))
        want = _direct(state33b, bits)
        # guard: the two states genuinely disagree, so a stale cached
        # environment would be visible as a wrong answer here
        assert np.abs(old - want).max() > 1e-6
        _assert_close(new, want)


def test_reregister_bumps_version_and_counters(state33, state33b):
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, OPT)
        eng.amplitude_batch("s", _bits(state33, 2, seed=16))
        assert eng.stats()["per_state"]["s"]["version"] == 0
        eng.register_state("s", state33b, OPT)
        st_ = eng.stats()
        assert st_["per_state"]["s"]["version"] == 1
        assert st_["invalidations"] == 1
        assert st_["per_state"]["s"]["prefix_size"] == 0  # fresh cache


def test_max_states_lru_eviction_rematerializes(state33, state33b, state23):
    bits33 = _bits(state33, 2, seed=17)
    with ServingEngine(start=False, max_states=1) as eng:
        eng.register_state("a", state33, OPT)
        eng.register_state("b", state33b, OPT)
        eng.register_state("c", state23, OPT)
        first = np.asarray(eng.amplitude_batch("a", bits33))
        eng.amplitude_batch("b", bits33)  # evicts a's caches
        st_ = eng.stats()
        assert st_["state_evictions"] == 1
        assert st_["per_state"]["a"]["materialized"] is False
        assert st_["per_state"]["a"]["prefix_size"] == 0
        assert st_["per_state"]["b"]["materialized"] is True
        # "a" stays registered; the next query re-materializes, same values
        again = np.asarray(eng.amplitude_batch("a", bits33))
        assert np.array_equal(first, again)
        _assert_close(again, _direct(state33, bits33))


def test_prefix_lru_eviction_recomputes(state33):
    bits = _bits(state33, 6, seed=18)  # distinct prefixes overflow cache=2
    with ServingEngine(start=False, max_prefixes=2) as eng:
        eng.register_state("s", state33, OPT)
        first = np.asarray(eng.amplitude_batch("s", bits))
        st_ = eng.stats()["per_state"]["s"]
        assert st_["prefix_evictions"] > 0
        assert st_["prefix_size"] <= 2
        again = np.asarray(eng.amplitude_batch("s", bits))
        assert np.array_equal(first, again)
        _assert_close(again, _direct(state33, bits))


def test_eviction_never_corrupts_inflight(state33, state33b):
    """Churn registrations + state eviction while a client hammers queries."""
    bits = _bits(state33, 2, seed=19)
    want = _direct(state33, bits)
    stop = threading.Event()
    errors = []

    with ServingEngine(window_ms=0.5, max_states=1) as eng:
        eng.register_state("a", state33, OPT)
        eng.register_state("b", state33b, OPT)

        def churn():
            while not stop.is_set():
                # same tensors re-registered: values must be unaffected
                eng.register_state("a", state33, OPT)
                eng.amplitude_batch("b", bits)  # evicts a's caches

        t = threading.Thread(target=churn)
        t.start()
        try:
            for _ in range(15):
                futs = [eng.submit_amplitude("a", b) for b in bits]
                got = np.array([complex(f.result(timeout=120)) for f in futs])
                if np.abs(got - want).max() > 1e-10 * max(1.0, np.abs(want).max()):
                    errors.append(got)
        finally:
            stop.set()
            t.join()
    assert not errors


def test_unregister(state33):
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, OPT)
        eng.unregister("s")
        with pytest.raises(KeyError):
            eng.amplitude("s", np.zeros((3, 3), dtype=int))
        with pytest.raises(KeyError):
            eng.unregister("s")
        assert eng.registered() == []


# ---------------------------------------------------------------------------
# Infrastructure: bucketing, stats, fused-cache reuse, lifecycle
# ---------------------------------------------------------------------------

def test_stats_keys_present_before_any_query():
    with ServingEngine(start=False) as eng:
        st_ = eng.stats()
        for key in ("queries_amplitude", "queries_expectation", "batches",
                    "rows_absorbed", "state_evictions", "invalidations",
                    "padded_queries", "per_state", "states"):
            assert key in st_
        assert st_["states"] == 0


def test_chunk_ladder():
    eng = ServingEngine(start=False, bucket_sizes=(1, 2, 4))
    assert eng._chunks(1) == [1]
    assert eng._chunks(3) == [4]
    assert eng._chunks(4) == [4]
    assert eng._chunks(5) == [4, 1]
    assert eng._chunks(11) == [4, 4, 4]
    eng.close()
    assert DEFAULT_BUCKETS == tuple(sorted(DEFAULT_BUCKETS))


def test_padding_counter(state33):
    with ServingEngine(start=False, bucket_sizes=(4,)) as eng:
        eng.register_state("s", state33, OPT)
        bits = _bits(state33, 3, seed=20)
        bits[:, :-1] = bits[0, :-1]  # one group of 3 -> one padded 4-bucket
        out = eng.amplitude_batch("s", bits)
        assert out.shape == (3,)
        assert eng.stats()["padded_queries"] == 1
        _assert_close(out, _direct(state33, bits))


def test_fused_close_cache_reuse(state33):
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, OPT)
        bits = _bits(state33, 4, seed=21)
        bits[:, :-1] = bits[0, :-1]
        eng.amplitude_batch("s", bits)  # compiles the 4-bucket close
        before = planner.stats()
        eng.amplitude_batch("s", bits)
        delta = planner.stats_since(before)
        assert delta["fused_misses"] == 0
        assert delta["fused_hits"] >= 1


def test_obs_env_cache_counters(state33):
    obs = Observable.Z(0)
    with ServingEngine(start=False) as eng:
        eng.register_state("s", state33, OPT)
        eng.expectation("s", obs)
        eng.expectation("s", obs)
        ps = eng.stats()["per_state"]["s"]
        assert ps["obs_env_builds"] == 1
        assert ps["obs_env_hits"] == 1
        assert eng.stats()["queries_expectation"] == 2


def test_close_is_idempotent_and_blocks_submit(state33):
    eng = ServingEngine()
    eng.register_state("s", state33, OPT)
    eng.close()
    eng.close()  # idempotent
    with pytest.raises(RuntimeError):
        eng.submit_amplitude("s", np.zeros((3, 3), dtype=int))
    with pytest.raises(RuntimeError):
        eng.register_state("t", state33, OPT)


def test_pending_requests_drain_on_close(state33):
    eng = ServingEngine(window_ms=50.0)
    eng.register_state("s", state33, OPT)
    bits = _bits(state33, 6, seed=22)
    futs = [eng.submit_amplitude("s", b) for b in bits]
    eng.close()  # must drain, not drop
    got = np.array([complex(f.result(timeout=120)) for f in futs])
    _assert_close(got, _direct(state33, bits))


def test_constructor_validation():
    with pytest.raises(ValueError):
        ServingEngine(max_states=0)
    with pytest.raises(ValueError):
        ServingEngine(bucket_sizes=())
    with pytest.raises(ValueError):
        ServingEngine(bucket_sizes=(0, 2))


def test_lru_cache_unit():
    c = LRUCache(2)
    assert c.get("x") is None          # counted miss
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1             # counted hit, refreshes "a"
    c.put("c", 3)                      # evicts "b" (LRU)
    assert c.peek("b") is None         # peek: uncounted
    assert c.peek("a") == 1
    s = c.stats()
    assert (s["hits"], s["misses"], s["evictions"], s["size"]) == (1, 1, 1, 2)
    with pytest.raises(ValueError):
        LRUCache(0)
