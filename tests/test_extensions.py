"""Beyond-paper extensions: Eq. (6) Trotter-Taylor expectation, gram-final
randomized SVD, compressed cross-pod training."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import peps as P
from repro.core import statevector as sv
from repro.core import bmps as B
from repro.core.observable import tfi_hamiltonian
from repro.core.expectation import expectation, expectation_trotter
from repro.core.peps import QRUpdate
from repro.core.einsumsvd import DirectSVD

REPO = Path(__file__).resolve().parent.parent
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"))


def test_expectation_trotter_matches_eq5():
    """Paper Eq. (6): one-contraction expectation agrees with Eq. (5) up to
    O(tau)."""
    st = P.random_peps(3, 3, 2, jax.random.PRNGKey(3))
    obs = tfi_hamiltonian(3, 3)
    opt = B.BMPS(16, DirectSVD())
    e5 = complex(expectation(st, obs, opt, use_cache=True))
    e6 = complex(expectation_trotter(st, obs, opt, tau=1e-4,
                                     update=QRUpdate(rank=8)))
    assert abs(e6.real - e5.real) < 5e-2 * max(1.0, abs(e5.real))


def test_expectation_trotter_tau_bias_shrinks():
    st = P.random_peps(2, 2, 2, jax.random.PRNGKey(4))
    obs = tfi_hamiltonian(2, 2)
    opt = B.BMPS(16, DirectSVD())
    e5 = complex(expectation(st, obs, opt)).real
    errs = []
    for tau in (1e-2, 1e-3):
        e6 = complex(expectation_trotter(st, obs, opt, tau=tau,
                                         update=QRUpdate(rank=8))).real
        errs.append(abs(e6 - e5))
    assert errs[1] < errs[0] + 1e-9  # O(tau) bias


COMPRESSED_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.models.model import build
from repro.optim.adamw import adamw_init
from repro.optim.compression import init_error_state
from repro import configs

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = configs.get_smoke("smollm-360m")
bundle = build(cfg, mesh)
params = bundle.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
err = init_error_state(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
batch = {"tokens": tokens, "labels": tokens}
p1, o1, e1, m1 = jax.jit(bundle.train_step_compressed)(params, opt, err, batch)
# reference: plain (uncompressed) step on the same mesh
p2, o2, m2 = jax.jit(bundle.train_step)(params, opt, batch)
l1, l2 = float(m1["loss"]), float(m2["loss"])
assert abs(l1 - l2) < 1e-2 * max(1.0, abs(l2)), (l1, l2)
# parameters close despite int8 gradient exchange
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)))
assert d < 5e-3, d
print("COMPRESSED_OK", l1, l2, d)
"""


@pytest.mark.slow
def test_compressed_crosspod_training(tmp_path):
    """int8 EF-compressed cross-pod all-reduce: loss/params match the
    uncompressed step on a real 2x2x2 fake-device mesh."""
    script = tmp_path / "compressed.py"
    script.write_text(COMPRESSED_SNIPPET)
    res = subprocess.run([sys.executable, str(script)], env=ENV,
                         capture_output=True, text=True)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "COMPRESSED_OK" in res.stdout
