"""einsumsvd / randomized SVD / Gram orthogonalization unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.einsumsvd import DirectSVD, RandomizedSVD, einsumsvd, truncation_error
from repro.core.orthogonalize import gram_qr, reshape_qr, orthogonalize_cols
from repro.core.rsvd import ImplicitOperator, randomized_svd


def _random_network(key, d1=3, d2=4, d3=5, d4=3, dtype=jnp.complex128):
    k1, k2 = jax.random.split(key)

    def rnd(k, shape):
        if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
            ka, kb = jax.random.split(k)
            return (jax.random.normal(ka, shape) + 1j * jax.random.normal(kb, shape)).astype(dtype)
        return jax.random.normal(k, shape).astype(dtype)

    a = rnd(k1, (d1, d2, d3))
    b = rnd(k2, (d3, d4, d1))
    # network: contract over label c (=d3); operator rows 'ab', cols 'de'
    return [a, b], ["abc", "cde"], "ab", "de"


def test_implicit_operator_dense_matvec_consistency():
    tensors, subs, row, col = _random_network(jax.random.PRNGKey(0))
    op = ImplicitOperator(tensors, subs, row, col)
    dense = op.dense()
    q = jax.random.normal(jax.random.PRNGKey(1), op.col_shape + (3,))
    q = q.astype(op.dtype)
    got = op.matvecs(q)
    want = jnp.tensordot(dense, q, axes=[[2, 3], [0, 1]])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)
    p = jax.random.normal(jax.random.PRNGKey(2), op.row_shape + (3,)).astype(op.dtype)
    got_r = op.rmatvecs(p)
    mat = dense.reshape(op.row_size, op.col_size)
    want_r = (mat.conj().T @ p.reshape(op.row_size, 3)).reshape(op.col_shape + (3,))
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want_r), atol=1e-12)


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.complex128])
def test_randomized_svd_matches_direct(dtype):
    tensors, subs, row, col = _random_network(jax.random.PRNGKey(3), dtype=dtype)
    op = ImplicitOperator(tensors, subs, row, col)
    rank = min(op.row_size, op.col_size)  # full rank -> exact
    u1, s1, v1 = DirectSVD()(op, rank)
    u2, s2, v2 = RandomizedSVD(niter=6)(op, rank, key=jax.random.PRNGKey(9))
    s1, s2 = np.asarray(s1), np.asarray(s2)
    # compare significant singular values only: the gram_final variant floors
    # null-space values at the Gram eps (sqrt(1e-13)*s0) instead of ~1e-16
    sig = s1 > 1e-8 * s1[0]
    np.testing.assert_allclose(s1[sig], s2[sig], rtol=1e-8)
    assert np.all(s2[~sig] < 1e-5 * s1[0])
    assert float(truncation_error(op.dense(), u2, s2, v2)) < 1e-8


def test_truncated_rsvd_error_near_optimal():
    tensors, subs, row, col = _random_network(jax.random.PRNGKey(4))
    op = ImplicitOperator(tensors, subs, row, col)
    for rank in (2, 4, 6):
        ud, sd, vd = DirectSVD()(op, rank)
        ur, sr, vr = RandomizedSVD(niter=8, oversample=8)(op, rank,
                                                          key=jax.random.PRNGKey(1))
        e_direct = float(truncation_error(op.dense(), ud, sd, vd))
        e_rand = float(truncation_error(op.dense(), ur, sr, vr))
        # paper Fig. 10 claim: implicit rSVD adds no significant extra error
        assert e_rand <= e_direct * 1.05 + 1e-10


def test_einsumsvd_absorb_modes():
    tensors, subs, row, col = _random_network(jax.random.PRNGKey(5))
    rank = 4
    u, s, v = einsumsvd(DirectSVD(), tensors, subs, row, col, rank, absorb="none")
    l_both, r_both = einsumsvd(DirectSVD(), tensors, subs, row, col, rank, absorb="both")
    recon1 = jnp.einsum("abk,k,kde->abde", u, s, v)
    recon2 = jnp.einsum("abk,kde->abde", l_both, r_both)
    np.testing.assert_allclose(np.asarray(recon1), np.asarray(recon2), atol=1e-12)


@settings(deadline=None, max_examples=20)
@given(m=st.integers(2, 9), n=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_gram_qr_property(m, n, seed):
    """Property: gram_qr reconstructs A and produces an isometry (tall case)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    a = (jax.random.normal(k1, (m, m, n)) + 1j * jax.random.normal(k2, (m, m, n)))
    q, r = gram_qr(a, 1)
    recon = jnp.tensordot(q, r, axes=[[2], [0]])
    np.testing.assert_allclose(np.asarray(recon), np.asarray(a), atol=1e-9)
    if m * m >= n:
        qtq = jnp.tensordot(q.conj(), q, axes=[[0, 1], [0, 1]])
        np.testing.assert_allclose(np.asarray(qtq), np.eye(n), atol=1e-8)


def test_gram_qr_matches_reshape_qr_subspace():
    a = jax.random.normal(jax.random.PRNGKey(0), (7, 3, 4)).astype(jnp.float64)
    for qr in (gram_qr, reshape_qr):
        q, r = qr(a, 2)
        recon = jnp.tensordot(q, r, axes=[[1, 2], [0, 1]])
        np.testing.assert_allclose(np.asarray(recon), np.asarray(a), atol=1e-10)


def test_gram_qr_rank_deficient():
    """Wide/rank-deficient case: reconstruction must still be exact."""
    a = jnp.zeros((2, 3, 4), dtype=jnp.complex128).at[0, 1, 2].set(1.0)
    q, r = gram_qr(a, 2)
    recon = jnp.tensordot(q, r, axes=[[1, 2], [0, 1]])
    np.testing.assert_allclose(np.asarray(recon), np.asarray(a), atol=1e-9)


def test_orthogonalize_cols():
    t = jax.random.normal(jax.random.PRNGKey(1), (6, 5, 3)).astype(jnp.float64)
    q = orthogonalize_cols(t)
    qtq = jnp.tensordot(q, q, axes=[[0, 1], [0, 1]])
    np.testing.assert_allclose(np.asarray(qtq), np.eye(3), atol=1e-10)
