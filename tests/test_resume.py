"""Checkpoint + resume for the physics runs (ITE / VQE).

The contract under test (docs/robustness.md): a run killed mid-evolution,
re-invoked with the same arguments and checkpoint directory, resumes from
the latest published checkpoint and reproduces the uninterrupted run's
per-step energies bit-identically (<= 1e-12) on the overlapping steps —
including with the randomized (key-consuming) einsumsvd engine, which is
the hard case: the snapshot must preserve the PRNG key stream, the cached
environments, and the refresh counter exactly.

Fast tests kill in-process (an exception from the measurement callback);
the slow chaos tests kill a real subprocess with ``os._exit(42)`` and
resume in a second process, mirroring tests/test_fault_tolerance.py.
"""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.bmps import BMPS
from repro.core.einsumsvd import RandomizedSVD
from repro.core.ite import ite_run
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import FullUpdate, QRUpdate, computational_zeros
from repro.core.vqe import run_vqe

REPO = Path(__file__).resolve().parent.parent
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"))

OBS = tfi_hamiltonian(2, 2)
TOL = 1e-12


def _by_step(result):
    return dict(zip(result.steps, result.energies))


def _assert_overlap_identical(ref, got, tol=TOL):
    common = set(ref) & set(got)
    assert common, (sorted(ref), sorted(got))
    for s in sorted(common):
        assert abs(ref[s] - got[s]) <= tol, (s, ref[s], got[s])


class _Kill(Exception):
    pass


def _killer(at_step):
    def cb(step, e, state):
        if step >= at_step:
            raise _Kill(step)
    return cb


def _wait_for_checkpoint(ckdir, timeout=10.0):
    """The async writer may still be in flight when an in-process kill
    unwinds; published checkpoints appear shortly after.  (Read-only glob —
    constructing a CheckpointManager here would sweep the in-flight tmp.)"""
    deadline = time.time() + timeout
    while time.time() < deadline:
        if any(p.suffix != ".tmp" and (p / "manifest.json").exists()
               for p in Path(ckdir).glob("step_*")):
            return
        time.sleep(0.05)
    raise AssertionError(f"no checkpoint appeared in {ckdir}")


# ---------------------------------------------------------------------------
# In-process resume (fast)
# ---------------------------------------------------------------------------

class TestITEResume:
    def test_qr_update_resume_bit_identical(self, tmp_path):
        upd, contract = QRUpdate(rank=2), BMPS(8)
        ref = ite_run(computational_zeros(2, 2), OBS, 0.05, 6, upd, contract,
                      measure_every=2)
        with pytest.raises(_Kill):
            ite_run(computational_zeros(2, 2), OBS, 0.05, 6, upd, contract,
                    measure_every=2, callback=_killer(4),
                    checkpoint_dir=str(tmp_path), checkpoint_every=2)
        _wait_for_checkpoint(tmp_path)
        res = ite_run(computational_zeros(2, 2), OBS, 0.05, 6, upd, contract,
                      measure_every=2, checkpoint_dir=str(tmp_path),
                      checkpoint_every=2)
        assert res.resumed_from is not None
        _assert_overlap_identical(_by_step(ref), _by_step(res))
        assert set(_by_step(res)) == set(_by_step(ref))

    def test_randomized_svd_resume_preserves_key_stream(self, tmp_path):
        """The hard case: every truncation consumes PRNG splits, so any
        extra/missing split after resume diverges every later energy."""
        svd = RandomizedSVD(niter=2, oversample=4)
        upd, contract = QRUpdate(rank=2, svd=svd), BMPS(8, svd=svd)
        args = (computational_zeros(2, 2), OBS, 0.05, 6, upd, contract)
        ref = ite_run(*args, measure_every=2)
        with pytest.raises(_Kill):
            ite_run(*args, measure_every=2, callback=_killer(4),
                    checkpoint_dir=str(tmp_path), checkpoint_every=2)
        _wait_for_checkpoint(tmp_path)
        res = ite_run(*args, measure_every=2, checkpoint_dir=str(tmp_path),
                      checkpoint_every=2)
        assert res.resumed_from is not None
        _assert_overlap_identical(_by_step(ref), _by_step(res))

    def test_full_update_resume_with_envs_and_fidelity_window(self, tmp_path):
        """FullUpdate carries extra loop state — cached row environments,
        the refresh counter, the undrained fidelity window — all of which
        must survive the round trip for bit-identity."""
        upd = FullUpdate(rank=2, chi=8, env_refresh_every=3)
        contract = BMPS(8)
        args = (computational_zeros(2, 2), OBS, 0.05, 6, upd, contract)
        ref = ite_run(*args, measure_every=2)
        with pytest.raises(_Kill):
            ite_run(*args, measure_every=2, callback=_killer(4),
                    checkpoint_dir=str(tmp_path), checkpoint_every=3)
        _wait_for_checkpoint(tmp_path)
        res = ite_run(*args, measure_every=2, checkpoint_dir=str(tmp_path),
                      checkpoint_every=3)
        assert res.resumed_from is not None
        _assert_overlap_identical(_by_step(ref), _by_step(res))
        ref_f = dict(zip(ref.steps, ref.fidelities))
        got_f = dict(zip(res.steps, res.fidelities))
        _assert_overlap_identical(ref_f, got_f)

    def test_planner_stats_cover_the_whole_logical_run(self, tmp_path):
        upd, contract = QRUpdate(rank=2), BMPS(8)
        args = (computational_zeros(2, 2), OBS, 0.05, 6, upd, contract)
        with pytest.raises(_Kill):
            ite_run(*args, measure_every=2, callback=_killer(4),
                    checkpoint_dir=str(tmp_path), checkpoint_every=2)
        _wait_for_checkpoint(tmp_path)
        res = ite_run(*args, measure_every=2, checkpoint_dir=str(tmp_path),
                      checkpoint_every=2)
        ref = ite_run(*args, measure_every=2)
        # the merged counters count at least the uninterrupted run's work
        assert res.planner_stats["path_hits"] >= ref.planner_stats["path_hits"]

    def test_resume_false_starts_fresh(self, tmp_path):
        upd, contract = QRUpdate(rank=2), BMPS(8)
        args = (computational_zeros(2, 2), OBS, 0.05, 3, upd, contract)
        ite_run(*args, measure_every=1, checkpoint_dir=str(tmp_path),
                checkpoint_every=1)
        res = ite_run(*args, measure_every=1, checkpoint_dir=str(tmp_path),
                      checkpoint_every=1, resume=False)
        assert res.resumed_from is None
        assert len(res.energies) == 3


class TestVQEResume:
    def test_spsa_resume_bit_identical(self, tmp_path):
        kw = dict(n_layers=1, max_bond=2, seed=3, method="spsa")
        ref = run_vqe(2, 2, OBS, maxiter=6, **kw)
        run_vqe(2, 2, OBS, maxiter=3, **kw,
                checkpoint_dir=str(tmp_path), checkpoint_every=1)
        res = run_vqe(2, 2, OBS, maxiter=6, **kw,
                      checkpoint_dir=str(tmp_path), checkpoint_every=1)
        assert res.resumed_from is not None
        # full trajectory: history, parameters and final energy all match
        # exactly — the checkpointed Generator state continues the SPSA
        # perturbation stream where the first process left it
        assert len(ref.history) == len(res.history)
        for a, b in zip(ref.history, res.history):
            assert abs(a - b) <= TOL
        assert np.max(np.abs(ref.thetas - res.thetas)) <= TOL
        assert abs(ref.energy - res.energy) <= TOL

    def test_batched_adam_resume_bit_identical(self, tmp_path):
        """Batched (vmapped-ensemble) runs resume bit-identically WITHOUT
        an RNG snapshot: every PRNG stream is keyed on (seed, iteration,
        member), so parameters + adam moments + the iteration index replay
        the remaining trajectory exactly."""
        kw = dict(n_layers=1, max_bond=2, seed=0, method="adam", ensemble=3,
                  lr=0.1)
        ref = run_vqe(2, 2, OBS, maxiter=6, **kw)
        run_vqe(2, 2, OBS, maxiter=3, **kw,
                checkpoint_dir=str(tmp_path), checkpoint_every=3)
        res = run_vqe(2, 2, OBS, maxiter=6, **kw,
                      checkpoint_dir=str(tmp_path), checkpoint_every=3)
        assert res.resumed_from == 3
        assert np.max(np.abs(ref.ensemble_thetas
                             - res.ensemble_thetas)) <= TOL
        assert np.max(np.abs(ref.ensemble_history
                             - res.ensemble_history)) <= TOL
        assert abs(ref.energy - res.energy) <= TOL

    def test_batched_spsa_resume_bit_identical(self, tmp_path):
        kw = dict(n_layers=1, max_bond=2, seed=2, method="spsa", ensemble=2)
        ref = run_vqe(2, 2, OBS, maxiter=6, **kw)
        run_vqe(2, 2, OBS, maxiter=4, **kw,
                checkpoint_dir=str(tmp_path), checkpoint_every=2)
        res = run_vqe(2, 2, OBS, maxiter=6, **kw,
                      checkpoint_dir=str(tmp_path), checkpoint_every=2)
        assert res.resumed_from == 4
        assert np.max(np.abs(ref.ensemble_thetas
                             - res.ensemble_thetas)) <= TOL

    def test_batched_resume_rejects_sequential_checkpoint(self, tmp_path):
        """A batched run pointed at a sequential snapshot fails loudly
        instead of resuming from an incompatible state."""
        run_vqe(2, 2, OBS, n_layers=1, max_bond=2, maxiter=3, seed=3,
                method="spsa", checkpoint_dir=str(tmp_path),
                checkpoint_every=1)
        with pytest.raises(ValueError, match="not from a batched"):
            run_vqe(2, 2, OBS, n_layers=1, max_bond=2, maxiter=3, seed=3,
                    method="adam", ensemble=2,
                    checkpoint_dir=str(tmp_path), checkpoint_every=1)

    def test_slsqp_warm_restart(self, tmp_path):
        """SLSQP state lives inside scipy: the documented contract is a
        warm restart from the checkpointed x, not a bit-identical replay."""
        kw = dict(n_layers=1, max_bond=2, seed=0, method="SLSQP")
        r1 = run_vqe(2, 2, OBS, maxiter=4, **kw,
                     checkpoint_dir=str(tmp_path), checkpoint_every=2)
        assert r1.resumed_from is None
        res = run_vqe(2, 2, OBS, maxiter=4, **kw,
                      checkpoint_dir=str(tmp_path), checkpoint_every=2)
        assert res.resumed_from is not None
        assert np.isfinite(res.energy)
        assert len(res.history) > len(r1.history)  # prior history preserved
        assert res.energy <= r1.energy + 1e-9      # no regression from warm x


# ---------------------------------------------------------------------------
# Subprocess chaos-kill (slow): a REAL kill via os._exit(42), resume in a
# second process — async writer genuinely racing the kill
# ---------------------------------------------------------------------------

ITE_SCRIPT = r"""
import json, os, sys
import jax
from repro.core.bmps import BMPS
from repro.core.distributed import DistributedBMPS
from repro.core.einsumsvd import RandomizedSVD
from repro.core.ite import ite_run
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import QRUpdate, computational_zeros

log, ckpt, kill_at, dist = sys.argv[1], sys.argv[2], int(sys.argv[3]), sys.argv[4]
nrow, ncol = 2, (4 if dist == "dist" else 2)
obs = tfi_hamiltonian(nrow, ncol)
svd = RandomizedSVD(niter=2, oversample=4)
contract = (DistributedBMPS(8, svd=svd, n_shards=4) if dist == "dist"
            else BMPS(8, svd=svd))

def cb(step, e, state):
    with open(log, "a") as f:
        f.write(json.dumps({"step": step, "energy": e}) + "\n")
    if kill_at and step >= kill_at:
        os._exit(42)

res = ite_run(computational_zeros(nrow, ncol), obs, 0.05, 8,
              QRUpdate(rank=2, svd=svd), contract, measure_every=2,
              callback=cb, checkpoint_dir=(ckpt or None), checkpoint_every=2)
print("RESUMED_FROM", res.resumed_from)
"""

VQE_SCRIPT = r"""
import json, os, sys
from repro.core.observable import tfi_hamiltonian
from repro.core.vqe import run_vqe

log, ckpt, kill_at = sys.argv[1], sys.argv[2], int(sys.argv[3])
obs = tfi_hamiltonian(2, 2)

def cb(n, e, x):
    with open(log, "a") as f:
        f.write(json.dumps({"step": n, "energy": e}) + "\n")
    if kill_at and n >= kill_at:
        os._exit(42)

res = run_vqe(2, 2, obs, n_layers=1, max_bond=2, maxiter=6, seed=3,
              method="spsa", callback=cb,
              checkpoint_dir=(ckpt or None), checkpoint_every=1)
print("RESUMED_FROM", res.resumed_from)
"""


def _run_script(tmp_path, text, args, env=None, expect_rc=0):
    script = tmp_path / "chaos.py"
    script.write_text(text)
    res = subprocess.run([sys.executable, str(script)] + [str(a) for a in args],
                         env=env or ENV, capture_output=True, text=True)
    assert res.returncode == expect_rc, (
        f"rc={res.returncode}\nstdout:{res.stdout[-2000:]}\n"
        f"stderr:{res.stderr[-2000:]}")
    return res


def _log_dict(log):
    out = {}
    for line in Path(log).read_text().splitlines():
        rec = json.loads(line)
        step, e = rec["step"], rec["energy"]
        if step in out:   # re-measured after resume: must agree bit-for-bit
            assert abs(out[step] - e) <= TOL, (step, out[step], e)
        out[step] = e
    return out


def _chaos_roundtrip(tmp_path, script, kill_at, args_tail=(), env=None):
    ref_log, got_log = tmp_path / "ref.jsonl", tmp_path / "got.jsonl"
    ck = tmp_path / "ckpt"
    _run_script(tmp_path, script, [ref_log, "", 0, *args_tail], env=env)
    _run_script(tmp_path, script, [got_log, ck, kill_at, *args_tail],
                env=env, expect_rc=42)
    res = _run_script(tmp_path, script, [got_log, ck, 0, *args_tail], env=env)
    assert "RESUMED_FROM None" not in res.stdout
    ref, got = _log_dict(ref_log), _log_dict(got_log)
    assert set(ref) == set(got)
    for s in ref:
        assert abs(ref[s] - got[s]) <= TOL, (s, ref[s], got[s])


@pytest.mark.slow
def test_chaos_kill_resume_ite_subprocess(tmp_path):
    """ITE killed at the step-6 measurement (os._exit(42)); the resumed
    process reproduces every per-step energy of the uninterrupted run."""
    _chaos_roundtrip(tmp_path, ITE_SCRIPT, kill_at=6, args_tail=("single",))


@pytest.mark.slow
def test_chaos_kill_resume_ite_distributed_8dev(tmp_path):
    """Same chaos contract with the column-sharded distributed sweep on 8
    virtual devices — checkpoints are host numpy, so the snapshot is
    mesh-independent."""
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    _chaos_roundtrip(tmp_path, ITE_SCRIPT, kill_at=6, args_tail=("dist",),
                     env=env)


@pytest.mark.slow
def test_chaos_kill_resume_vqe_subprocess(tmp_path):
    """SPSA VQE killed at evaluation 7; the resumed process continues the
    perturbation stream bit-identically."""
    _chaos_roundtrip(tmp_path, VQE_SCRIPT, kill_at=7)


# ---------------------------------------------------------------------------
# Persistent planner cache across processes (slow)
# ---------------------------------------------------------------------------

WARMSTART_SCRIPT = r"""
import json, sys
import jax
from repro.core import planner
from repro.core.bmps import BMPS
from repro.core.einsumsvd import RandomizedSVD
from repro.core.ite import ite_run
from repro.core.observable import tfi_hamiltonian
from repro.core.peps import QRUpdate, computational_zeros

cache, phase = sys.argv[1], sys.argv[2]
if phase == "warm":
    n = planner.load_path_cache(cache)
    assert n > 0, "expected a preloaded cache"
svd = RandomizedSVD(niter=2, oversample=4)
ite_run(computational_zeros(2, 2), tfi_hamiltonian(2, 2), 0.05, 2,
        QRUpdate(rank=2, svd=svd), BMPS(8, svd=svd), measure_every=1)
if phase == "cold":
    planner.save_path_cache(cache)
print("STATS", json.dumps(planner.stats()))
"""


@pytest.mark.slow
def test_path_cache_warm_starts_second_process(tmp_path):
    """Acceptance: a second process preloading the persisted cache replays
    an identical workload with ZERO path-search misses."""
    cache = tmp_path / "paths.json"
    res = _run_script(tmp_path, WARMSTART_SCRIPT, [cache, "cold"])
    cold = json.loads(res.stdout.split("STATS ", 1)[1])
    assert cold["path_misses"] > 0
    res = _run_script(tmp_path, WARMSTART_SCRIPT, [cache, "warm"])
    warm = json.loads(res.stdout.split("STATS ", 1)[1])
    assert warm["path_misses"] == 0
    assert warm["path_preloaded"] > 0
    assert warm["path_hits"] > 0
