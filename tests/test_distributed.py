"""Column-sharded distributed contraction: single-device equivalence + layout.

The contract under test (see docs/distributed.md): for ANY (n_shards,
block), the distributed sweep performs the identical einsumsvd sequence as
the single-device path — blocking only decides where each call runs — so
sharded values must match single-device values to <= 1e-10 (they are
bit-identical up to matmul re-association in the final scalar closing).

The whole file runs on any device count (shards wrap round-robin onto the
available devices); CI additionally runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the halo
exchanges cross real device boundaries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmps, peps, planner
from repro.core.bmps import BMPS
from repro.core.distributed import (ColumnLayout, DistributedBMPS,
                                    gather_columns, halo_bytes_per_row,
                                    put_columns)
from repro.core.einsumsvd import DirectSVD
from repro.core.environments import top_environments
from repro.core.expectation import expectation
from repro.core.observable import Observable
from repro.launch.mesh import peps_mesh


def _state(nrow, ncol, bond, seed=3, scale=2.0):
    s = peps.random_peps(nrow, ncol, bond, jax.random.PRNGKey(seed))
    # rescale so contraction values stay O(1)-ish (random_peps normalizes
    # per-site; 2-layer values of big grids would otherwise underflow)
    return peps.PEPS([[t * scale for t in row] for row in s.sites])


def _rel(a, b):
    a, b = complex(a), complex(b)
    return abs(a - b) / max(abs(b), 1e-300)


# ------------------------------------------------------------- layout ----

def test_layout_partitions_columns():
    for ncol, n_shards, block in [(8, 4, 1), (8, 4, 2), (5, 2, 2), (7, 3, 1),
                                  (6, 8, 1), (1, 1, 1), (9, 4, 2)]:
        lay = ColumnLayout(ncol, n_shards, block)
        seen = []
        for shard, cols in lay.blocks:
            assert 0 <= shard < n_shards
            seen.extend(cols)
        assert seen == list(range(ncol))          # contiguous, in order, exact
        for c in range(ncol):
            assert lay.owner(c) == (c // block) % n_shards


def test_layout_block_cyclic_wraps():
    lay = ColumnLayout(8, 4, 1)
    assert [s for s, _ in lay.blocks] == [0, 1, 2, 3, 0, 1, 2, 3]
    lay = ColumnLayout(8, 4, 2)
    assert [s for s, _ in lay.blocks] == [0, 1, 2, 3]


def test_layout_rejects_garbage():
    with pytest.raises(ValueError):
        ColumnLayout(0, 1, 1)
    with pytest.raises(ValueError):
        ColumnLayout(4, 1, 0)


def test_resolve_defaults_clamp_to_ncol():
    opt = DistributedBMPS(chi=8, n_shards=64)
    lay, devs = opt.resolve(ncol=5)
    assert lay.n_shards == 5 and lay.ncol == 5
    assert len(devs) == len(jax.devices())


def test_put_columns_places_on_owners():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    state = _state(2, 4, 2)
    lay, devs = DistributedBMPS(chi=4, n_shards=2, block=1).resolve(4)
    grid = put_columns(state.sites, lay, devs)
    for row in grid:
        for c, t in enumerate(row):
            (dev,) = t.devices()
            assert dev == devs[lay.owner(c) % len(devs)]


def test_for_mesh_selects_batch_column():
    n = len(jax.devices())
    if n < 4:
        pytest.skip("needs >= 4 devices")
    mesh = peps_mesh(n // 2, 2)
    opt = DistributedBMPS.for_mesh(mesh, chi=8, batch_index=1)
    assert len(opt.devices) == n // 2
    ids = {d.id for d in opt.devices}
    other = {d.id for d in DistributedBMPS.for_mesh(mesh, chi=8).devices}
    assert ids.isdisjoint(other)                  # distinct batch slices


# ------------------------------------------- sharded == single-device ----

GRID = [
    # nrow, ncol, bond, chi, n_shards, block
    (3, 4, 2, 8, 2, None),      # even split, pure block layout
    (3, 5, 2, 8, 2, 2),         # ncol not divisible by n_shards
    (4, 6, 2, 8, 4, 1),         # block-cyclic, width 1
    (2, 3, 2, 4, 3, None),      # one column per shard
    (3, 4, 2, 6, 8, 1),         # more shards than devices (wraps)
]


@pytest.mark.parametrize("nrow,ncol,bond,chi,n_shards,block", GRID)
def test_norm_squared_matches_single_device(nrow, ncol, bond, chi, n_shards,
                                            block):
    state = _state(nrow, ncol, bond)
    key = jax.random.PRNGKey(7)
    ref = bmps.norm_squared(state, BMPS.randomized(chi), key)
    opt = DistributedBMPS.randomized(chi, n_shards=n_shards, block=block)
    val = bmps.norm_squared(state, opt, key)
    assert _rel(val, ref) <= 1e-10


@pytest.mark.parametrize("nrow,ncol,bond,chi,n_shards,block", GRID[:3])
def test_amplitude_matches_single_device(nrow, ncol, bond, chi, n_shards,
                                         block):
    state = _state(nrow, ncol, bond)
    key = jax.random.PRNGKey(9)
    bits = np.arange(nrow * ncol) % 2
    ref = bmps.amplitude(state, bits, BMPS.randomized(chi), key)
    opt = DistributedBMPS.randomized(chi, n_shards=n_shards, block=block)
    val = bmps.amplitude(state, bits, opt, key)
    assert _rel(val, ref) <= 1e-10


def test_inner_matches_single_device():
    bra = _state(3, 4, 2, seed=3)
    ket = _state(3, 4, 2, seed=4)
    key = jax.random.PRNGKey(1)
    ref = bmps.inner(bra, ket, BMPS.randomized(8), key)
    val = bmps.inner(bra, ket, DistributedBMPS.randomized(8, n_shards=4), key)
    assert _rel(val, ref) <= 1e-10


def test_direct_svd_engine_also_matches():
    state = _state(3, 5, 2)
    key = jax.random.PRNGKey(0)
    ref = bmps.norm_squared(state, BMPS(8, DirectSVD()), key)
    val = bmps.norm_squared(state, DistributedBMPS(8, DirectSVD(),
                                                   n_shards=3, block=1), key)
    assert _rel(val, ref) <= 1e-10


def test_environments_match_single_device():
    state = _state(3, 5, 2)
    key = jax.random.PRNGKey(4)
    ref = top_environments(state.sites, state.sites, BMPS.randomized(8), key)
    opt = DistributedBMPS.randomized(8, n_shards=2, block=2)
    val = top_environments(state.sites, state.sites, opt, key)
    assert len(ref) == len(val)
    for env_r, env_v in zip(ref, val):
        for tr, tv in zip(env_r, env_v):
            assert tr.shape == tv.shape
            assert float(jnp.max(jnp.abs(tr - tv))) <= 1e-10 * max(
                1.0, float(jnp.max(jnp.abs(tr))))


def test_expectation_matches_single_device():
    state = _state(3, 4, 2)
    H = (Observable.ZZ(5, 6) + 0.3 * Observable.X(2)
         + Observable.ZZ(1, 5) + 0.7 * Observable.Z(9))
    key = jax.random.PRNGKey(2)
    ref = expectation(state, H, BMPS.randomized(8), key=key)
    opt = DistributedBMPS.randomized(8, n_shards=4, block=1)
    val = expectation(state, H, opt, key=key)
    assert _rel(val, ref) <= 1e-10


def test_full_update_env_contract_matches():
    from repro.core import gates as G
    from repro.core.peps import FullUpdate, apply_operator
    state = _state(3, 4, 2)
    k = jax.random.PRNGKey(5)
    ref_upd = FullUpdate(rank=2, chi=6)
    dist_upd = FullUpdate(rank=2, chi=6,
                          env_contract=DistributedBMPS(6, n_shards=4, block=1))
    s_ref = apply_operator(state, G.gate("CX"), [5, 6], ref_upd, key=k)
    s_val = apply_operator(state, G.gate("CX"), [5, 6], dist_upd, key=k)
    for row_r, row_v in zip(s_ref.sites, s_val.sites):
        for tr, tv in zip(row_r, row_v):
            assert float(jnp.max(jnp.abs(tr - tv))) <= 1e-10


# ----------------------------------------------- acceptance + planner ----

def test_acceptance_6x8_chi16_8shards():
    """ISSUE 4 acceptance: 6x8 D=2 chi=16 PEPS, 8 column shards, <= 1e-10."""
    state = _state(6, 8, 2, scale=2.2)
    key = jax.random.PRNGKey(7)
    ref = bmps.norm_squared(state, BMPS.randomized(16), key)
    opt = DistributedBMPS.randomized(16, n_shards=8, block=1)
    val = bmps.norm_squared(state, opt, key)
    assert _rel(val, ref) <= 1e-10


def test_planner_cache_reused_across_shards():
    """Sharding must not fragment the planner caches: after a single-device
    warm-up, a sharded sweep of the same lattice replays 100% cached fused
    refactorizations and 100% cached einsum paths — the per-site signatures
    (which contain the halo/carry dims) are blocking-invariant."""
    planner.clear()
    try:
        state = _state(4, 6, 2)
        key = jax.random.PRNGKey(7)
        bmps.norm_squared(state, BMPS.randomized(8), key)        # warm
        before = planner.stats()
        opt = DistributedBMPS.randomized(8, n_shards=4, block=1)
        bmps.norm_squared(state, opt, key)
        delta = planner.stats_since(before)
        assert delta["fused_misses"] == 0, delta
        assert delta["path_misses"] == 0, delta
        assert delta["fused_hits"] > 0
        # and re-blocking doesn't either
        opt2 = DistributedBMPS.randomized(8, n_shards=2, block=2)
        bmps.norm_squared(state, opt2, key)
        delta2 = planner.stats_since(before)
        assert delta2["fused_misses"] == 0, delta2
    finally:
        planner.clear()


def test_halo_bytes_per_row_scales_with_edges():
    state = _state(4, 8, 2)
    one = halo_bytes_per_row(state, DistributedBMPS(16, n_shards=2, block=4))
    many = halo_bytes_per_row(state, DistributedBMPS(16, n_shards=8, block=1))
    assert one > 0 and many == 7 * one            # 7 edges vs 1 edge


def test_halo_bytes_per_row_counts_only_cross_shard_edges():
    state = _state(4, 8, 2)
    # 8 width-1 blocks all on one shard: block edges exist, bytes don't move
    assert halo_bytes_per_row(state, DistributedBMPS(16, n_shards=1,
                                                     block=1)) == 0
    # degenerate lattices must not crash
    assert halo_bytes_per_row(_state(3, 1, 2), DistributedBMPS(16)) == 0


def test_gather_columns_lands_on_default_device():
    state = _state(2, 3, 2)
    lay, devs = DistributedBMPS(chi=4, n_shards=3).resolve(3)
    grid = put_columns(state.sites, lay, devs)
    pulled = gather_columns(grid[0])
    d0 = jax.local_devices()[0]
    for t in pulled:
        assert t.devices() == {d0}
