"""Optional-dependency guard for ``hypothesis`` (ISSUE 1 satellite).

``hypothesis`` is a *test-only, optional* dependency (declared in
``requirements-dev.txt`` / ``pyproject.toml``).  Importing it at module scope
used to hard-error collection of three test modules on environments without
it.  This shim degrades gracefully instead:

* with hypothesis installed, it re-exports the real ``given`` / ``settings``
  / ``strategies`` untouched;
* without it, property tests run against a small, deterministic sample drawn
  from a seeded RNG — strictly weaker than hypothesis's shrinking search, but
  far better than skipping the module (and collection never errors).

Modules that use *other* hypothesis features than the ones shimmed here
should call :func:`require_hypothesis` (a ``pytest.importorskip`` wrapper)
instead.
"""
from __future__ import annotations

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_MAX_EXAMPLES = 8  # cap: deterministic sweeps stay fast
    _FALLBACK_SEED = 0x2006_1523  # arXiv:2006.15234

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def given(**strategies):
        def decorate(fn):
            def runner():
                # settings() may sit above OR below given(); check both the
                # wrapper and the wrapped function for the stamped cap.
                n = getattr(runner, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples",
                                    _FALLBACK_MAX_EXAMPLES))
                rng = random.Random(_FALLBACK_SEED)
                for _ in range(n):
                    fn(**{name: s.sample(rng)
                          for name, s in strategies.items()})

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return decorate

    def settings(*, max_examples=None, **_ignored):
        def decorate(fn):
            if max_examples is not None:
                fn._compat_max_examples = min(max_examples,
                                              _FALLBACK_MAX_EXAMPLES)
            return fn

        return decorate


def require_hypothesis():
    """``pytest.importorskip`` guard for tests needing real hypothesis."""
    return pytest.importorskip("hypothesis")
