"""Boundary-engine layer: refactor equivalence, variational accuracy, dispatch.

Three contracts (see repro/core/engines/__init__.py and ISSUE 6):

1. **Refactor identity** — zip-up routed through the engine layer is
   bit-identical to the pre-refactor inline code.  The golden values pinned
   below were captured on the pre-refactor tree (same networks, same PRNG
   keys) and must keep matching to <= 1e-12, including the distributed path,
   and replaying a contraction after warm-up must tick zero planner-cache
   misses (identical signatures).
2. **Variational accuracy** — the ALS-fitted boundary is exact when chi
   covers the exact bond dimension (matches ``contract_exact_onelayer`` /
   dense contraction to 1e-8) and beats zip-up at truncating chi.
3. **Dispatch** — engine/option errors are ``TypeError``/``ValueError`` that
   name the registered alternatives (the PR 2 convention); the SPMD
   wavefront rejects non-block engines at construction.

The SPMD marshalling test (no device-0 staging in ``spmd.absorb_rows``)
needs >= 2 devices and skips otherwise; ``make test-engines`` runs this
file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bmps, peps, planner, spmd
from repro.core.bmps import BMPS
from repro.core.distributed import DistributedBMPS
from repro.core.engines import (BoundaryEngine, get_engine,
                                registered_engines)
from repro.core.engines.variational import VariationalEngine
from repro.core.engines.zipup import ZipUpEngine
from repro.core.environments import top_environments


def _rel(a, b):
    a, b = complex(a), complex(b)
    return abs(a - b) / max(abs(b), 1e-300)


def _state(nrow, ncol, bond, seed, scale=2.0):
    s = peps.random_peps(nrow, ncol, bond, jax.random.PRNGKey(seed))
    return peps.PEPS([[t * scale for t in row] for row in s.sites])


K17 = jax.random.PRNGKey(17)


# ------------------------------------------------------------ registry ----

def test_registry_and_resolution():
    engines = registered_engines()
    assert set(engines) >= {"zipup", "variational"}
    assert isinstance(get_engine("zipup"), ZipUpEngine)
    assert isinstance(get_engine("variational"), VariationalEngine)
    assert get_engine("zipup").supports_blocks
    assert not get_engine("variational").supports_blocks
    # instances pass through (non-default hyper-parameters)
    eng = VariationalEngine(sweeps=4)
    assert get_engine(eng) is eng
    assert isinstance(get_engine(eng), BoundaryEngine)


def test_unknown_engine_typeerror_lists_registered():
    with pytest.raises(TypeError, match=r"zipup.*variational|variational.*zipup"):
        get_engine("zip-up")
    with pytest.raises(TypeError, match="registered engines"):
        get_engine(42)
    # option construction fails fast, single-device and distributed
    with pytest.raises(TypeError, match="registered engines"):
        BMPS(8, engine="nope")
    with pytest.raises(TypeError, match="registered engines"):
        DistributedBMPS(8, engine="nope")


def test_unknown_option_typeerror_lists_engines():
    rows = peps.random_onelayer(2, 2, 2, jax.random.PRNGKey(0))
    with pytest.raises(TypeError, match=r"BMPS.*zipup|zipup.*BMPS"):
        bmps.contract_onelayer(rows, object())


def test_spmd_wavefront_rejects_nonblock_engine():
    for mode in ("spmd", "auto"):
        with pytest.raises(ValueError, match="supports_blocks"):
            DistributedBMPS(8, wavefront=mode, engine="variational")
    # block engine + any wavefront is fine
    DistributedBMPS(8, wavefront="spmd", engine="zipup")


# ------------------------------------- refactor identity (golden values) ----
#
# Captured on the pre-refactor tree (zip-up inline in bmps.py), complex128.
# The engine extraction must keep these bit-stable; the 1e-12 tolerance only
# allows for BLAS-level nondeterminism.

GOLDEN = {
    "onelayer_direct": -0.00012873286629361584 - 5.3833319046630055e-05j,
    "onelayer_rand": -0.00012873286629361724 - 5.383331904662955e-05j,
    "norm33_direct": 0.15101467776759644 + 2.5153490401663703e-17j,
    "norm44_rand": 0.0011335785265292415 - 4.772519825718621e-19j,
    "inner33_rand": 0.0001706439255891352 + 0.002521652104873013j,
    "amp44_rand": -5.77323121874269e-05 - 0.00010454796604215042j,
    "norm44_dist": 0.0011335785265292415 - 4.772519825718621e-19j,
}


def test_zipup_golden_onelayer():
    rows = peps.random_onelayer(4, 4, 3, jax.random.PRNGKey(42))
    v = bmps.contract_onelayer(rows, BMPS(8), key=K17)
    assert _rel(v, GOLDEN["onelayer_direct"]) <= 1e-12
    v = bmps.contract_onelayer(rows, BMPS.randomized(8), key=K17)
    assert _rel(v, GOLDEN["onelayer_rand"]) <= 1e-12


def test_zipup_golden_twolayer():
    s33 = peps.random_peps(3, 3, 2, jax.random.PRNGKey(7))
    v = bmps.norm_squared(s33, BMPS(8), key=K17)
    assert _rel(v, GOLDEN["norm33_direct"]) <= 1e-12
    s44 = peps.random_peps(4, 4, 2, jax.random.PRNGKey(12))
    v = bmps.norm_squared(s44, BMPS.randomized(10), key=K17)
    assert _rel(v, GOLDEN["norm44_rand"]) <= 1e-12
    ket = peps.random_peps(3, 3, 2, jax.random.PRNGKey(8))
    v = bmps.inner(s33, ket, BMPS.randomized(10), key=K17)
    assert _rel(v, GOLDEN["inner33_rand"]) <= 1e-12
    bits = np.arange(16) % 2
    v = bmps.amplitude(s44, bits, BMPS.randomized(10), key=K17)
    assert _rel(v, GOLDEN["amp44_rand"]) <= 1e-12


def test_zipup_golden_distributed():
    s44 = peps.random_peps(4, 4, 2, jax.random.PRNGKey(12))
    opt = DistributedBMPS.randomized(10, n_shards=2, block=1)
    v = bmps.norm_squared(s44, opt, key=K17)
    assert _rel(v, GOLDEN["norm44_dist"]) <= 1e-12


def test_engine_layer_replay_ticks_nothing():
    """Warm the planner through the re-exported pre-refactor entry points,
    then contract through the engine layer: identical signatures mean the
    replay adds zero path/fused misses."""
    rows = peps.random_onelayer(4, 4, 2, jax.random.PRNGKey(5))
    opt = BMPS.randomized(6, niter=2, oversample=4)
    # pre-refactor call style: explicit row sweep via the re-exported names
    keys = bmps._keys(K17, 4)
    svec = [t.reshape(t.shape[1:]) for t in rows[0]]
    for i in range(1, 4):
        svec = bmps._zipup_row(svec, rows[i], opt.chi, opt.svd, keys[i])
    warm = bmps._mps_to_scalar(svec)
    before = planner.stats()
    v = bmps.contract_onelayer(rows, opt, key=K17)
    delta = planner.stats_since(before)
    assert delta["path_misses"] == 0 and delta["fused_misses"] == 0
    assert complex(v) == complex(warm)


# ----------------------------------------------- variational accuracy ----

def test_variational_exact_at_full_chi_onelayer():
    # chi >= exact boundary bond => the fit reproduces the exact contraction
    for (nrow, ncol, bond, chi, seed) in [(3, 3, 3, 27, 1), (4, 4, 2, 16, 2)]:
        rows = peps.random_onelayer(nrow, ncol, bond, jax.random.PRNGKey(seed))
        exact = bmps.contract_exact_onelayer(rows)
        v = bmps.contract_onelayer(rows, BMPS(chi, engine="variational"),
                                   key=jax.random.PRNGKey(3))
        assert _rel(v, exact) <= 1e-8


def test_variational_exact_at_full_chi_twolayer():
    st = _state(3, 3, 2, seed=7, scale=1.0)
    merged = bmps.merge_layers(st.sites, st.sites)
    dense = complex(bmps.contract_exact_onelayer(merged)) * \
        float(jnp.exp(2.0 * st.log_scale))
    v = bmps.norm_squared(st, BMPS(40, engine="variational"), key=K17)
    assert _rel(v, dense) <= 1e-8


def test_variational_beats_zipup_at_truncating_chi():
    rows = peps.random_onelayer(4, 4, 3, jax.random.PRNGKey(42))
    exact = bmps.contract_exact_onelayer(rows)
    key = K17
    zip_err = _rel(bmps.contract_onelayer(rows, BMPS(8), key), exact)
    var_err = _rel(bmps.contract_onelayer(
        rows, BMPS(8, engine="variational"), key), exact)
    assert var_err < zip_err


def test_variational_cache_hit_rate():
    st = _state(4, 4, 2, seed=9)
    opt = BMPS.randomized(6, niter=2, oversample=4, engine="variational")
    bmps.norm_squared(st, opt, key=K17)            # warm-up
    before = planner.stats()
    bmps.norm_squared(st, opt, key=K17)            # replay
    delta = planner.stats_since(before)
    assert delta["path_misses"] == 0 and delta["fused_misses"] == 0
    hits = delta["path_hits"] + delta["fused_hits"]
    assert hits > 50                               # > 99% hit rate


def test_variational_engine_instance_option():
    rows = peps.random_onelayer(3, 3, 2, jax.random.PRNGKey(4))
    exact = bmps.contract_exact_onelayer(rows)
    v = bmps.contract_onelayer(rows, BMPS(4, engine=VariationalEngine(sweeps=3)),
                               key=K17)
    assert _rel(v, exact) < 1.0                    # smoke: runs + sane


def test_environments_respect_engine():
    st = _state(3, 3, 2, seed=7, scale=1.0)
    merged = bmps.merge_layers(st.sites, st.sites)
    dense = complex(bmps.contract_exact_onelayer(merged))
    envs = top_environments(st.sites, st.sites,
                            BMPS(40, engine="variational"), key=K17)
    assert len(envs) == st.nrow + 1
    closed = bmps._twolayer_final_scalar(envs[st.nrow])
    assert _rel(closed, dense) <= 1e-8


# ----------------------------------------------- distributed dispatch ----

def test_distributed_variational_matches_single_device():
    st = _state(4, 4, 2, seed=3)
    key = jax.random.PRNGKey(7)
    single = bmps.norm_squared(st, BMPS(8, engine="variational"), key)
    for n_shards, block in [(2, 1), (2, 2), (3, 1)]:
        opt = DistributedBMPS(8, n_shards=n_shards, block=block,
                              engine="variational")
        v = bmps.norm_squared(st, opt, key)
        assert _rel(v, single) <= 1e-10


def test_distributed_variational_environments():
    st = _state(3, 4, 2, seed=6)
    key = jax.random.PRNGKey(11)
    ref = top_environments(st.sites, st.sites,
                           BMPS(8, engine="variational"), key)
    envs = top_environments(st.sites, st.sites,
                            DistributedBMPS(8, n_shards=2, block=1,
                                            engine="variational"), key)
    assert len(envs) == len(ref)
    for lv_a, lv_b in zip(ref, envs):
        for a, b in zip(lv_a, lv_b):
            assert float(jnp.max(jnp.abs(a - b))) <= 1e-12


# ------------------------------------------------- SPMD marshalling ----

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (make test-engines forces 8)")
def test_spmd_entry_marshalling_no_dev0_staging(monkeypatch):
    """ROADMAP PR 5 follow-up: ``spmd.absorb_rows`` must not build the
    stacked superstep operands on device 0 and then redistribute — each
    shard's chunk is committed straight to its owner and the global array is
    assembled with ``make_array_from_single_device_arrays``.  Asserted by
    recording every ``jax.device_put`` during a superstep-engaging sweep:
    no call may use a Sharding target (the old redistribution), and the
    single-device targets must cover every mesh device."""
    st = _state(5, 8, 2, seed=3)
    chi, key = 8, jax.random.PRNGKey(7)
    opt = DistributedBMPS.randomized(chi, niter=2, oversample=4, n_shards=2,
                                     wavefront="auto")
    ref = bmps.norm_squared(st, BMPS.randomized(chi, niter=2, oversample=4),
                            key)

    calls = []
    real_put = jax.device_put

    def recording_put(x, device=None, **kw):
        calls.append(device)
        return real_put(x, device, **kw)

    monkeypatch.setattr(jax, "device_put", recording_put)
    before = dict(spmd.stats())
    val = bmps.norm_squared(st, opt, key)
    monkeypatch.undo()

    assert spmd.stats()["superstep_calls"] > before["superstep_calls"], \
        "sweep never engaged the SPMD superstep — marshalling not exercised"
    shardings = [d for d in calls
                 if d is not None and not isinstance(d, jax.Device)]
    assert not shardings, \
        f"absorb_rows staged+redistributed via Sharding targets: {shardings}"
    targets = {d for d in calls if isinstance(d, jax.Device)}
    assert len(targets) >= 2, "operands were not spread across devices"
    assert _rel(val, ref) <= 1e-10
