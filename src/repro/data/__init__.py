"""Data pipeline."""
from repro.data.pipeline import SyntheticLM, DataConfig, shard_batch  # noqa: F401
