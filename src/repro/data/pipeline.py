"""Deterministic synthetic token pipeline with host sharding and prefetch.

Production posture: every host materializes only its own shard of the
global batch (``host_slice``), batches are a pure function of (seed, step)
— so a restarted/elastically-resized job regenerates bit-identical data for
any step without coordination — and a background thread prefetches.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Zipfian token stream with structure (next-token = f(prev) mostly),
    so losses actually decrease during the example training runs."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.host_batch = cfg.global_batch // cfg.n_hosts
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host): the elastic-restart contract."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        b, s = self.host_batch, cfg.seq_len
        fresh = rng.choice(cfg.vocab, size=(b, s + 1), p=self._probs)
        # inject learnable structure: 75% of positions follow t+1 = (t*7+3)%V
        follow = rng.random((b, s)) < 0.75
        base = np.empty((b, s + 1), dtype=np.int64)
        base[:, 0] = fresh[:, 0]
        for t in range(s):  # sequential so the chain is self-consistent
            nxt = (base[:, t] * 7 + 3) % cfg.vocab
            base[:, t + 1] = np.where(follow[:, t], nxt, fresh[:, t + 1])
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def iterate(self, start_step: int = 0, prefetch: int = 2
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Background-thread prefetching iterator."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def shard_batch(batch: Dict[str, np.ndarray], mesh, batch_sharding):
    """Place a host batch onto the mesh with the batch sharding."""
    import jax
    return {k: jax.device_put(v, batch_sharding) for k, v in batch.items()}
