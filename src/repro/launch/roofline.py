"""Three-term roofline model from the compiled dry-run (assignment §ROOFLINE).

  compute    = HLO_FLOPs / (chips x 197 TF/s bf16)
  memory     = HLO_bytes / (chips x 819 GB/s HBM)
  collective = collective_bytes / (chips x 50 GB/s ICI link)

``cost_analysis()`` on an SPMD executable reports PER-DEVICE flops/bytes
(validated empirically in EXPERIMENTS.md §Dry-run), so global HLO_FLOPs =
per-device x chips and each term divides back by chips — i.e. the terms are
computed directly from the per-device numbers.  MODEL_FLOPS uses the 6*N*D
(train) / 2*N*B (decode) convention with N_active for MoE.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch import hlo_analysis
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.common import Config


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    per_device_flops: float
    per_device_bytes: float
    collective_bytes: float          # per device (from the SPMD program)
    collective_detail: Dict[str, int]
    collective_counts: Dict[str, int]
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0
    useful_ratio: float = 0.0        # MODEL_FLOPS / (HLO_FLOPs x chips)
    step_s: float = 0.0              # max of the three terms
    roofline_frac: float = 0.0       # compute_s / step_s ("% of roofline")
    arg_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0

    def finish(self):
        self.compute_s = self.per_device_flops / PEAK_FLOPS_BF16
        self.memory_s = self.per_device_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.step_s = max(terms.values())
        if self.model_flops and self.per_device_flops:
            self.useful_ratio = self.model_flops / (self.per_device_flops *
                                                    self.chips)
        ideal = self.model_flops / (PEAK_FLOPS_BF16 * self.chips) \
            if self.model_flops else self.compute_s
        self.roofline_frac = ideal / self.step_s if self.step_s else 0.0
        return self

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def param_count(cfg: Config, active_only: bool = False) -> float:
    """Parameter count from the config (dense or active-expert subset)."""
    d, v = cfg.d_model, cfg.vocab
    n = v * d * 2  # embed + head
    if cfg.family in ("dense", "moe", "vlm"):
        attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head \
            + cfg.n_heads * cfg.d_head * d
        mlp = 3 * d * cfg.d_ff if cfg.d_ff else 0
        moe = 0.0
        if cfg.family == "moe":
            e = cfg.top_k if active_only else cfg.n_experts
            moe = e * 3 * d * cfg.d_expert_ff + d * cfg.n_experts
        n += cfg.n_layers * (attn + mlp + moe + 2 * d)
    elif cfg.family == "ssm":
        per = _ssm_params(cfg)
        n += cfg.n_layers * per
    elif cfg.family == "hybrid":
        per = _ssm_params(cfg)
        n_groups = cfg.n_layers // cfg.hybrid_group
        mamba_layers = n_groups * (cfg.hybrid_group - 1)
        attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head \
            + cfg.n_heads * cfg.d_head * d + 3 * d * cfg.d_ff
        n += mamba_layers * per + attn  # shared block counted once
    elif cfg.family == "encdec":
        attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head \
            + cfg.n_heads * cfg.d_head * d
        mlp = 3 * d * cfg.d_ff
        n += cfg.n_enc_layers * (attn + mlp) + cfg.n_layers * (2 * attn + mlp)
    return float(n)


def _ssm_params(cfg: Config) -> float:
    d, din = cfg.d_model, cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    return (2 * d * din + 2 * d * gn + d * cfg.ssm_heads + din * d +
            cfg.conv_width * (din + 2 * gn))


def model_flops(cfg: Config, shape_kind: str, seq: int, gbatch: int) -> float:
    """6*N*D for training, 2*N*tokens for decode/prefill (N_active for MoE)."""
    n_active = param_count(cfg, active_only=True)
    tokens = seq * gbatch
    if shape_kind == "train":
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * gbatch      # decode: one token per sequence


def build_roofline(arch: str, shape: str, mesh_name: str, chips: int,
                   cost: Dict, hlo_text: str, cfg: Config, kind: str,
                   seq: int, gbatch: int, mem=None) -> Roofline:
    coll_total, coll_detail, coll_counts = hlo_analysis.collective_bytes(hlo_text)
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        per_device_flops=float(cost.get("flops", 0.0)),
        per_device_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=float(coll_total),
        collective_detail=coll_detail,
        collective_counts=coll_counts,
        model_flops=model_flops(cfg, kind, seq, gbatch),
    )
    if mem is not None:
        r.arg_bytes_per_device = float(mem.argument_size_in_bytes)
        r.temp_bytes_per_device = float(mem.temp_size_in_bytes)
    return r.finish()
