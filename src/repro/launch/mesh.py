"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(data, model); the multi-pod mesh adds a leading ``pod`` axis:
(2, 16, 16) = 512 chips.  The ``pod`` axis is pure data parallelism with
one (optionally compressed) cross-pod gradient all-reduce per step.
"""
from __future__ import annotations

import jax

# jax.sharding.AxisType (and the axis_types= kwarg of jax.make_mesh) only
# exist in newer JAX releases; older versions build the same Auto-typed mesh
# with no kwarg at all.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _axis_type_kwargs(n_axes: int) -> dict:
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """General mesh helper with Auto axis types (tests, elastic restarts)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """1x1 mesh on the local device (smoke tests / examples)."""
    return make_mesh((1, 1), ("data", "model"))


def peps_mesh(n_col_shards: int, batch: int = 1):
    """Mesh for intra-state distributed PEPS contraction: ``('col', 'batch')``.

    ``col`` is the column-shard axis consumed by
    :meth:`repro.core.distributed.DistributedBMPS.for_mesh`; ``batch`` (when
    > 1) slices the remaining devices across independent ensemble members,
    one column of the device grid per member.  Requires
    ``n_col_shards * batch`` available devices — on CPU, launch with e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    return make_mesh((n_col_shards, batch), ("col", "batch"))


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Newer JAX spells this ``jax.set_mesh(mesh)``; on older releases the
    ``Mesh`` object itself is the context manager.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


# TPU v5e-class hardware constants used by the roofline (assignment §ROOFLINE)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
