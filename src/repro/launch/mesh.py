"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(data, model); the multi-pod mesh adds a leading ``pod`` axis:
(2, 16, 16) = 512 chips.  The ``pod`` axis is pure data parallelism with
one (optionally compressed) cross-pod gradient all-reduce per step.

This module also hosts the version-compat :func:`shard_map` wrapper (shared
by the LM stack in :mod:`repro.models` and the SPMD contraction superstep in
:mod:`repro.core.spmd`) — it lives here because ``launch.mesh`` depends only
on jax, so both sides can import it without a cycle.
"""
from __future__ import annotations

import jax

# jax.sharding.AxisType (and the axis_types= kwarg of jax.make_mesh) only
# exist in newer JAX releases; older versions build the same Auto-typed mesh
# with no kwarg at all.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _axis_type_kwargs(n_axes: int) -> dict:
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """General mesh helper with Auto axis types (tests, elastic restarts)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """1x1 mesh on the local device (smoke tests / examples)."""
    return make_mesh((1, 1), ("data", "model"))


def peps_mesh(n_col_shards: int, batch: int = 1):
    """Mesh for intra-state distributed PEPS contraction: ``('col', 'batch')``.

    ``col`` is the column-shard axis consumed by
    :meth:`repro.core.distributed.DistributedBMPS.for_mesh`; ``batch`` (when
    > 1) slices the remaining devices across independent ensemble members,
    one column of the device grid per member.  Requires
    ``n_col_shards * batch`` available devices — on CPU, launch with e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

    The same mesh drives batched VQE: ``run_vqe(..., ensemble=k,
    mesh=peps_mesh(cols, batch))`` shards the vmapped member axis over the
    mesh's devices (:func:`repro.core.sharding.ensemble_sharding` splits it
    over every axis when ``k`` is divisible by the device count), so many
    circuits advance on many devices in one compiled program — see
    ``docs/vqe.md``.
    """
    return make_mesh((n_col_shards, batch), ("col", "batch"))


def col_mesh(devices):
    """1-D ``('col',)`` mesh over an explicit device list.

    Used by :mod:`repro.core.spmd` to run the compiled wavefront superstep
    over the devices the explicit-placement pipeline already owns.  Devices
    must be distinct — a ``Mesh`` cannot repeat a device — so the superstep
    plans its own equal-width split over the *distinct* device prefix
    rather than reusing a round-robin-wrapped host layout (blocking is
    value-invariant, so a different split changes nothing but placement).
    """
    import numpy as np
    arr = np.empty(len(devices), dtype=object)
    for i, d in enumerate(devices):
        arr[i] = d
    return jax.sharding.Mesh(arr, ("col",))


def shard_map(fn, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """Version-compat ``shard_map`` (new ``jax.shard_map`` keyword API).

    Older JAX only has ``jax.experimental.shard_map.shard_map`` whose
    ``auto=`` is the complement of ``axis_names`` and whose replication
    check is spelled ``check_rep``.
    """
    jsm = getattr(jax, "shard_map", None)
    if jsm is not None:
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jsm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   **kwargs)
    from jax.experimental.shard_map import shard_map as legacy_shard_map
    # Legacy partial-auto lowering is fragile (XLA aborts on
    # IsManualSubgroup for common bodies), so go manual over ALL axes:
    # numerically identical, at the cost of compute replicated over the
    # would-be-auto axes — acceptable on the small compat meshes.
    if axis_names is not None and frozenset(axis_names) != frozenset(
            mesh.axis_names):
        import warnings
        auto = sorted(frozenset(mesh.axis_names) - frozenset(axis_names))
        warnings.warn(
            f"legacy JAX shard_map fallback: going manual over ALL of "
            f"{mesh.axis_names} (requested manual={sorted(axis_names)}); "
            f"compute will be REPLICATED over {auto} — fine on small "
            f"compat meshes, a blowup on production meshes.",
            stacklevel=2)
    return legacy_shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=check_vma,
                            auto=frozenset())


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Newer JAX spells this ``jax.set_mesh(mesh)``; on older releases the
    ``Mesh`` object itself is the context manager.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


# TPU v5e-class hardware constants used by the roofline (assignment §ROOFLINE)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
