"""Parse collective-communication bytes out of compiled (post-SPMD) HLO.

``cost_analysis()`` does not expose collective bytes, so we scan the
optimized HLO text for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops and sum their operand sizes (assignment
§ROOFLINE).  Shapes are parsed from the standard HLO type syntax, e.g.
``bf16[128,4096]{1,0}``.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[2,128]{1,0} all-gather(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int], Dict[str, int]]:
    """Total bytes + per-kind bytes + per-kind op counts from HLO text.

    Counts each collective's *output* size once (the `-done` of async pairs
    is skipped so started collectives are not double counted)."""
    per_kind_bytes: Dict[str, int] = defaultdict(int)
    per_kind_count: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: counted at -start
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_inner, single, kind = m.groups()
        if single is not None:
            nbytes = _shape_bytes(single)
        else:
            nbytes = sum(_shape_bytes(p) for p in tuple_inner.split(",")
                         if "[" in p)
        per_kind_bytes[kind] += nbytes
        per_kind_count[kind] += 1
    total = sum(per_kind_bytes.values())
    return total, dict(per_kind_bytes), dict(per_kind_count)


def reshape_transpose_count(hlo_text: str) -> int:
    """Crude layout-churn indicator: number of (non-bitcast) transposes."""
    return sum(1 for l in hlo_text.splitlines()
               if re.search(r"=\s*\S+\s+transpose\(", l))
