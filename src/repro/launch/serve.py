"""PEPS query server: batched amplitude/observable serving CLI.

Stands up a :class:`repro.core.serving.ServingEngine` over one or more hot
RQC-evolved PEPS states, fires threaded clients at it (the offline-serving
shape: a thread-safe queue, a micro-batching dispatcher, per-state
environment prefix caches), and reports latency percentiles, throughput,
and the speedup over per-query cold contraction.

Usage (CPU-sized defaults):

    PYTHONPATH=src python -m repro.launch.serve \
        --grid 4 --layers 8 --chi 8 --states 2 \
        --clients 4 --queries 32 --hot-prefixes 4 --obs-every 8

Each client thread submits ``--queries`` requests against randomly chosen
registered states.  Amplitude bitstrings draw their row prefix from a
small per-state pool of ``--hot-prefixes`` hot prefixes (the serving
cache's intended regime — think sampling sweeps over a slice) with
uniformly random final rows; every ``--obs-every``-th request is an
observable query instead.  See docs/serving.md for the cache contract and
``benchmarks/bench_serving.py`` for the pinned throughput baseline.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core import bmps as B
from repro.core.circuits import apply_circuit_exact_peps, random_circuit
from repro.core.einsumsvd import DirectSVD, RandomizedSVD
from repro.core.observable import Observable
from repro.core.peps import computational_zeros
from repro.core.serving import ServingEngine


def _percentiles(lat_s):
    lat = np.sort(np.asarray(lat_s)) * 1e3
    if lat.size == 0:
        return "n/a"
    pick = lambda q: lat[min(len(lat) - 1, int(q * len(lat)))]
    return (f"p50={pick(0.50):.2f}ms p95={pick(0.95):.2f}ms "
            f"p99={pick(0.99):.2f}ms")


def build_states(n_states: int, grid: int, layers: int, seed: int = 7):
    """RQC-evolve ``n_states`` hot PEPS states (exact evolution, bond 4^(layers/4))."""
    states = []
    for s in range(n_states):
        circ = random_circuit(grid, grid, layers, seed=seed + s)
        states.append(apply_circuit_exact_peps(
            computational_zeros(grid, grid), circ))
    return states


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", type=int, default=4, help="grid side (NxN PEPS)")
    ap.add_argument("--layers", type=int, default=8, help="RQC layers")
    ap.add_argument("--chi", type=int, default=8, help="contraction bond dim")
    ap.add_argument("--svd", choices=("direct", "randomized"),
                    default="direct")
    ap.add_argument("--engine", choices=("zipup", "variational"),
                    default="zipup")
    ap.add_argument("--states", type=int, default=2,
                    help="number of hot states to register")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client threads")
    ap.add_argument("--queries", type=int, default=32,
                    help="queries per client")
    ap.add_argument("--hot-prefixes", type=int, default=4,
                    help="per-state pool of hot row prefixes")
    ap.add_argument("--obs-every", type=int, default=8,
                    help="every k-th request is an observable query (0 = none)")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="dispatcher micro-batching window")
    ap.add_argument("--max-states", type=int, default=4,
                    help="states with materialized caches (LRU)")
    ap.add_argument("--baseline-queries", type=int, default=8,
                    help="cold per-query contractions for the speedup line")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    svd = (DirectSVD() if args.svd == "direct" else
           RandomizedSVD(niter=4, oversample=8))
    option = B.BMPS(args.chi, svd, engine=args.engine)

    t0 = time.perf_counter()
    states = build_states(args.states, args.grid, args.layers)
    print(f"[serve] {args.states} x {args.grid}x{args.grid} RQC states "
          f"(bond {states[0].max_bond()}) evolved in "
          f"{time.perf_counter()-t0:.1f}s")

    engine = ServingEngine(max_states=args.max_states,
                           window_ms=args.window_ms)
    names = [f"rqc{s}" for s in range(len(states))]
    for name, st in zip(names, states):
        engine.register_state(name, st, option)

    # hot prefix pools: rows 0..n-2, per state
    rng = np.random.default_rng(args.seed)
    prefix_pool = {
        name: rng.integers(0, 2, (args.hot_prefixes, args.grid - 1, args.grid))
        for name in names}
    obs = Observable.Z(0) + Observable.ZZ(0, 1)

    # warm the prefix caches + compiled buckets once so the measured run
    # reflects steady-state serving (cold-start cost is reported separately).
    t0 = time.perf_counter()
    for name in names:
        warm = np.concatenate(
            [np.concatenate([prefix_pool[name],
                             rng.integers(0, 2, (args.hot_prefixes, 1,
                                                 args.grid))], axis=1)],
            axis=0)
        engine.amplitude_batch(name, warm)
    print(f"[serve] warmup (prefix envs + compiled closes): "
          f"{time.perf_counter()-t0:.1f}s")

    amp_lat, obs_lat = [], []
    lat_lock = threading.Lock()

    def client(cid: int):
        crng = np.random.default_rng(1000 + cid)
        pending = []
        for q in range(args.queries):
            name = names[crng.integers(len(names))]
            if args.obs_every and (q + 1) % args.obs_every == 0:
                t = time.perf_counter()
                pending.append(("obs", t, engine.submit_expectation(name, obs)))
            else:
                prefix = prefix_pool[name][crng.integers(args.hot_prefixes)]
                final = crng.integers(0, 2, (1, args.grid))
                bits = np.concatenate([prefix, final], axis=0)
                t = time.perf_counter()
                pending.append(("amp", t, engine.submit_amplitude(name, bits)))
        for kind, t, fut in pending:
            fut.result(timeout=600)
            with lat_lock:
                (amp_lat if kind == "amp" else obs_lat).append(
                    time.perf_counter() - t)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(args.clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    total = len(amp_lat) + len(obs_lat)

    print(f"[serve] {total} queries from {args.clients} clients in "
          f"{wall:.2f}s -> {total / wall:.1f} q/s")
    print(f"[serve] amplitude latency ({len(amp_lat)}): "
          f"{_percentiles(amp_lat)}")
    if obs_lat:
        print(f"[serve] observable latency ({len(obs_lat)}): "
              f"{_percentiles(obs_lat)}")

    # cold per-query baseline: full boundary sweep per amplitude
    nb = args.baseline_queries
    if nb > 0:
        bits = np.concatenate(
            [np.broadcast_to(prefix_pool[names[0]][0],
                             (nb, args.grid - 1, args.grid)),
             rng.integers(0, 2, (nb, 1, args.grid))], axis=1)
        t0 = time.perf_counter()
        for b in bits:
            B.amplitude(states[0], b, option).block_until_ready()
        cold = (time.perf_counter() - t0) / nb
        t0 = time.perf_counter()
        engine.amplitude_batch(names[0], bits).block_until_ready()
        served = (time.perf_counter() - t0) / nb
        print(f"[serve] per-query: cold contraction {cold*1e3:.2f}ms vs "
              f"served (warm cache, batched) {served*1e3:.2f}ms "
              f"-> x{cold / max(served, 1e-12):.1f}")

    st = engine.stats()
    flat = {k: v for k, v in st.items() if k != "per_state"}
    print(f"[serve] stats: {flat}")
    for name, ps in st["per_state"].items():
        print(f"[serve]   {name}: {ps}")
    engine.close()
    return st


if __name__ == "__main__":
    main()
