"""Batched decode loop (serving example).

Prefills a batch of prompts, then decodes greedily with the cached
serve_step.  Sized for CPU with the smoke configs; on the production mesh
the same code path is what dryrun.py lowers for the decode shapes.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_mesh, use_mesh
from repro.models.model import build


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if cfg.family in ("encdec",):
        raise SystemExit("use whisper decode via tests; serve.py targets LMs")
    bundle = build(cfg, mesh)
    params = bundle.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_seq = args.prompt_len + args.gen
    with use_mesh(mesh):
        t0 = time.time()
        if cfg.family in ("ssm", "hybrid"):
            # SSM decode: feed the prompt token by token (no KV prefill)
            cache = bundle.init_cache(args.batch, max_seq)
            step = jax.jit(bundle.serve_step, donate_argnums=(1,))
            logits = None
            for i in range(args.prompt_len):
                logits, cache = step(params, cache, prompts[:, i:i + 1])
        else:
            logits, cache = jax.jit(bundle.prefill_step)(params, prompts)
            # widen cache to max_seq
            pad = max_seq - args.prompt_len
            cache = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                         if k in ("k", "v") else v) for k, v in cache.items()}
            step = jax.jit(bundle.serve_step, donate_argnums=(1,))
        t_prefill = time.time() - t0

        tokens = [jnp.argmax(logits, axis=-1)[:, None]]
        t0 = time.time()
        for i in range(args.gen - 1):
            positions = None
            if cfg.family == "vlm":
                positions = jnp.broadcast_to(cache["index"],
                                             (3, args.batch, 1)).astype(jnp.int32)
            logits, cache = step(params, cache, tokens[-1], positions)
            tokens.append(jnp.argmax(logits, axis=-1)[:, None])
        t_decode = time.time() - t0

    out = jnp.concatenate(tokens, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill={t_prefill*1e3:.1f}ms "
          f"decode={t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok")
    print("[serve] generated:", np.asarray(out)[:, :10], "...")
    return out


if __name__ == "__main__":
    main()
