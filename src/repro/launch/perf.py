import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (assignment §PERFORMANCE HILLCLIMBING).

Runs named (cell x variant) experiments through the dry-run machinery and
appends hypothesis -> change -> before/after records to results/perf_log.json.

    python -m repro.launch.perf --cell zamba-train --variant ssd_chunk_64
    python -m repro.launch.perf --list
"""
import argparse
import json
from pathlib import Path

import jax.numpy as jnp

# (cell, variant) -> (arch, shape, cfg_overrides, rules_overrides, hypothesis)
EXPERIMENTS = {
    "zamba-train": {
        "_arch": ("zamba2-2.7b", "train_4k"),
        "baseline": ({}, {}, "paper-faithful baseline (SSD chunk 128, f32 "
                             "intra-chunk, nothing_saveable remat)"),
        "ssd_chunk_64": ({"ssm_chunk": 64}, {},
                         "memory term is dominated by the (B,H,NC,C,C) SSD "
                         "decay/score tensors ~ L*C per series; halving the "
                         "chunk to 64 halves those bytes at ~unchanged GEMM "
                         "flops -> memory term down ~25-40%"),
        "ssd_chunk_32": ({"ssm_chunk": 32}, {},
                         "continue the chunk sweep: L*C shrinks another 2x, "
                         "but intra-chunk GEMMs lose MXU efficiency below "
                         "~64 — expect diminishing returns"),
        "ssd_chunk_256": ({"ssm_chunk": 256}, {},
                          "reverse direction: bigger chunks amortize the "
                          "state recurrence but quadruple the L*C bytes -> "
                          "expect memory term UP (control experiment)"),
        "head_sharded_ssd": ({}, {},
                             "REVISED after chunk sweep refuted: napkin "
                             "vs mamba2 shows SSD intermediates are "
                             "replicated over 'model' (the group->head "
                             "repeat severs propagation). Explicit "
                             "head-axis constraints shard them 16-way -> "
                             "memory term down ~10x"),
        "head_sharded_chunk64": ({"ssm_chunk": 64}, {},
                                 "re-test the chunk hypothesis with "
                                 "sharding fixed: now L*C bytes should "
                                 "actually show up"),
    },
    "arctic-decode": {
        "_arch": ("arctic-480b", "decode_32k"),
        "baseline": ({}, {}, "paper-faithful-substrate baseline: ZeRO-3 "
                             "expert weights gathered over 'data' per layer"),
        "expert_tp": ({"moe_impl": "expert_tp"}, {},
                      "decode moves 35 layers x ~1.7GB of gathered expert "
                      "weights for only 128 tokens; keeping the expert ffn "
                      "axis stationary ('data'-sharded) and moving the "
                      "~2MB token set instead should cut the collective "
                      "term ~10x"),
        "expert_tp_bf16": ({"moe_impl": "expert_tp",
                            "moe_psum_dtype": "bf16"}, {},
                           "on top of expert_tp, halve the combine psum "
                           "payload (f32 -> bf16)"),
    },
    "qwen3moe-decode": {
        "_arch": ("qwen3-moe-30b-a3b", "decode_32k"),
        "baseline": ({}, {}, "second MoE decode cell (128e top-8, small "
                             "768-wide experts)"),
        "expert_tp": ({"moe_impl": "expert_tp"}, {},
                      "transfer of the arctic finding: weights-stationary "
                      "routing should cut the collective term here too"),
    },
    "granite-train": {
        "_arch": ("granite-8b", "train_4k"),
        "baseline": ({}, {}, "dense train reference"),
        "remat_dots": ({"remat_policy": "dots"}, {},
                       "nothing_saveable recomputes every matmul in the "
                       "backward: saving dot outputs trades ~1GiB/layer of "
                       "residuals for ~2x fewer forward FLOPs/bytes in the "
                       "backward -> memory term down"),
        "attn_chunk_4096": ({"attn_chunk": 4096}, {},
                            "fewer online-softmax passes: running acc/max "
                            "re-read per chunk; 2 chunks -> 1 at 4k train "
                            "seq halves those intermediate bytes"),
        "bf16_rmsnorm": ({}, {},
                         "HLO shows XLA hoisting a WHOLE-STACK bf16->f32 "
                         "convert of the saved residuals out of the "
                         "backward loop (38.7GB materialize + 2 converts) "
                         "because rms_norm's first op casts x to f32. "
                         "bf16-native rms_norm (f32 accumulation via dot) "
                         "kills the convert -> temp -38GiB, memory term "
                         "down ~30-50%"),
    },
}


def run_lm_variant(arch, shape, overrides, rules, label):
    from repro.launch.dryrun import run_lm_cell
    cfg_overrides = dict(overrides)
    remat_policy = cfg_overrides.pop("remat_policy", None)
    if remat_policy:
        cfg_overrides["remat_policy"] = remat_policy
    return run_lm_cell(arch, shape, multi_pod=False, rules=rules or None,
                       cfg_overrides=cfg_overrides, verbose=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=False)
    ap.add_argument("--variant", required=False)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="results/perf_log.json")
    args = ap.parse_args()

    if args.list:
        for cell, d in EXPERIMENTS.items():
            print(cell, "->", [k for k in d if k != "_arch"])
        return

    cell = EXPERIMENTS[args.cell]
    arch, shape = cell["_arch"]
    overrides, rules, hypothesis = cell[args.variant]
    print(f"### {args.cell}/{args.variant}")
    print(f"hypothesis: {hypothesis}")
    rec = run_lm_variant(arch, shape, overrides, rules, args.variant)
    rec.update({"cell": args.cell, "variant": args.variant,
                "hypothesis": hypothesis})
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    log = json.loads(out.read_text()) if out.exists() else []
    log.append(rec)
    out.write_text(json.dumps(log, indent=1, default=str))


if __name__ == "__main__":
    main()
