"""Launch layer: production mesh, dry-run, roofline, training/serving loops."""
