"""Fault-tolerant training loop (launcher).

Production behaviours exercised here, sized to run on 1 CPU device:

* auto-resume from the newest valid checkpoint (crash/preemption recovery);
* atomic async checkpoints every ``--checkpoint-every`` steps;
* deterministic data as f(seed, step) — restart-safe without data state;
* per-step watchdog timing with straggler logging;
* gradient-accumulation microbatching;
* ``--simulate-failure N`` kills the process at step N (chaos testing: the
  restarted run must continue bit-identically — asserted in tests);
* elastic restarts: restore reshards onto whatever mesh this run uses.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 50 --checkpoint-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models.model import build
from repro.optim.adamw import OptConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1", help="e.g. 1x1, 2x4, 2x2x2")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--log-file", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failure", type=int, default=None,
                    help="os._exit at this step (chaos test)")
    ap.add_argument("--slow-step-factor", type=float, default=3.0,
                    help="watchdog: warn when a step exceeds factor x median")
    args = ap.parse_args(argv)

    shape = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(shape):] if len(shape) == 3 else \
        ("data", "model")[:len(shape)]
    mesh = make_mesh(shape, axes)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    bundle = build(cfg, mesh, opt_cfg=OptConfig(lr=args.lr))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
    start_step = 0
    params = opt_state = None
    if ckpt is not None and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        print(f"[train] resuming from checkpoint step {start_step}")
        tree = {"params": bundle.abstract_params(),
                "opt": bundle.abstract_opt_state()}
        shardings = {"params": bundle.param_shardings(),
                     "opt": bundle.opt_shardings()}
        restored = ckpt.restore(start_step, tree, shardings)
        params, opt_state = restored["params"], restored["opt"]
    if params is None:
        params = bundle.init(jax.random.PRNGKey(args.seed))
        opt_state = adamw_init(params)

    step_fn = jax.jit(
        functools.partial(bundle.train_step, microbatches=args.microbatches),
        donate_argnums=(0, 1))

    log_f = open(args.log_file, "a") if args.log_file else None
    bspec = bundle.batch_sharding()
    durations = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {k: jax.device_put(v, bspec)
                 for k, v in data.batch_at(step).items()}
        if cfg.family == "vlm":
            import jax.numpy as jnp
            b, s = batch["tokens"].shape
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, b, s))
        if cfg.family == "encdec":
            import jax.numpy as jnp
            rng = np.random.default_rng(step)
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.enc_frames, cfg.d_model), dtype=np.float32))
        try:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
        except Exception as e:  # noqa: BLE001 — step-level fault tolerance
            print(f"[train] step {step} FAILED ({e}); checkpoint + abort")
            if ckpt is not None:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          blocking=True)
            raise
        dt = time.time() - t0
        durations.append(dt)
        med = float(np.median(durations[-20:]))
        if len(durations) > 5 and dt > args.slow_step_factor * med:
            print(f"[train] WATCHDOG: step {step} took {dt:.2f}s "
                  f"({dt/med:.1f}x median) — straggler suspected")
        rec = {"step": step, "loss": loss, "sec": round(dt, 4)}
        print(f"[train] {json.dumps(rec)}")
        if log_f:
            log_f.write(json.dumps(rec) + "\n")
            log_f.flush()
        next_step = step + 1
        if ckpt is not None and next_step % args.checkpoint_every == 0:
            ckpt.save(next_step, {"params": params, "opt": opt_state})
        if args.simulate_failure is not None and next_step == args.simulate_failure:
            print(f"[train] simulating hard failure at step {next_step}")
            if ckpt is not None:
                ckpt.wait()
            os._exit(42)

    if ckpt is not None:
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  blocking=True)
    print("[train] done")
    return params


if __name__ == "__main__":
    main()
