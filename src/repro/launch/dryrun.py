import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment MULTI-POD DRY-RUN steps 0-4).

Lowers and compiles every (architecture x input shape) cell on the
single-pod 16x16 mesh and the multi-pod 2x16x16 mesh, prints
memory_analysis / cost_analysis, and records the roofline terms.

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first init.

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun.json
    python -m repro.launch.dryrun --arch peps-rqc --shape contract
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_roofline
from repro.models.model import SHAPES, build
from repro.optim.adamw import OptConfig

LM_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
PEPS_SHAPES = ["evolve", "contract"]


def _mesh_name(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def _skip_record(arch, shape, mesh_name, reason):
    return {"arch": arch, "shape": shape, "mesh": mesh_name,
            "status": "skipped", "reason": reason}


def _scan_trips(cfg) -> int:
    """Trip count of the layer-level scans (all families keep them equal)."""
    if cfg.family == "hybrid":
        return cfg.hybrid_group - 1
    return cfg.n_layers


def _unroll_factor(trips: int) -> int:
    for k in (2, 3, 5, 7):
        if trips % k == 0:
            return k
    return trips  # prime: full unroll of the (short) scan


def _lower_cell(bundle, cfg, io, kind):
    params = bundle.abstract_params()
    pshard = bundle.param_shardings()
    if kind == "train":
        opt = bundle.abstract_opt_state()
        oshard = bundle.opt_shardings()
        fn = jax.jit(bundle.train_step,
                     in_shardings=(pshard, oshard, io["batch_shardings"]),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn.lower(params, opt, io["batch"])
    if kind == "prefill":
        if cfg.family == "encdec":
            fn = jax.jit(bundle.encode_step,
                         in_shardings=(pshard, io["frames_sharding"]))
            return fn.lower(params, io["frames"])
        fn = jax.jit(bundle.prefill_step,
                     in_shardings=(pshard, io["tokens_sharding"]))
        return fn.lower(params, io["tokens"])
    cshard = io["cache_shardings"]
    args = [params, io["cache"], io["token"]]
    in_sh = [pshard, cshard, io["token_sharding"]]
    if "positions" in io:
        args.append(io["positions"])
        in_sh.append(None)
    fn = jax.jit(bundle.serve_step,
                 in_shardings=tuple(in_sh),
                 out_shardings=(None, cshard),
                 donate_argnums=(1,))
    return fn.lower(*args)


def run_lm_cell(arch: str, shape: str, multi_pod: bool, rules=None,
                verbose: bool = True, cfg_overrides=None) -> dict:
    import dataclasses as _dc
    from repro.launch.hlo_analysis import collective_bytes
    base_cfg = _dc.replace(configs.get(arch), attn_unroll=True,
                           **(cfg_overrides or {}))
    mesh_name = _mesh_name(multi_pod)
    seq, gbatch, kind = SHAPES[shape]
    if shape == "long_500k" and not base_cfg.sub_quadratic:
        return _skip_record(arch, shape, mesh_name,
                            "full attention: O(L) KV at 500k infeasible "
                            "(DESIGN.md SS4)")
    if kind == "decode" and base_cfg.family == "encdec" and shape == "long_500k":
        return _skip_record(arch, shape, mesh_name, "whisper 448-token decoder")

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    # --- compile 1: the deployable scan program (memory analysis + proof) ---
    cfg = _dc.replace(base_cfg, layer_unroll=1)
    bundle = build(cfg, mesh, rules=rules, opt_cfg=OptConfig())
    io = bundle.input_specs(shape)
    lowered = _lower_cell(bundle, cfg, io, kind)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost1 = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo1 = compiled.as_text()
    coll1, _, _ = collective_bytes(hlo1)

    # --- unroll-probe compiles — cost_analysis counts a scan body once, so
    # comparing unroll factors isolates the per-layer cost; extrapolate. -----
    def _probe(**knobs):
        cfg_k = _dc.replace(base_cfg, **knobs)
        bundle_k = build(cfg_k, mesh, rules=rules, opt_cfg=OptConfig())
        io_k = bundle_k.input_specs(shape)
        compiled_k = _lower_cell(bundle_k, cfg_k, io_k, kind).compile()
        ck = compiled_k.cost_analysis()
        hk = compiled_k.as_text()
        cb, det, cnt = collective_bytes(hk)
        return {"flops": float(ck.get("flops", 0.0)),
                "bytes": float(ck.get("bytes accessed", 0.0)),
                "coll": float(cb), "detail": det, "counts": cnt}

    f11 = {"flops": float(cost1.get("flops", 0.0)),
           "bytes": float(cost1.get("bytes accessed", 0.0)),
           "coll": float(coll1)}

    if cfg.family == "hybrid":
        # nested scans: groups (G) x mamba-per-group (per) + shared block
        n_groups = cfg.n_layers // cfg.hybrid_group
        per = cfg.hybrid_group - 1
        kg = _unroll_factor(n_groups)
        kl = _unroll_factor(per)
        f_g = _probe(group_unroll=kg)
        f_l = _probe(layer_unroll=kl)

        def correct(key):
            sm = (f_g[key] - f11[key]) / (kg - 1)       # shared + 1 mamba
            mamba = (f_l[key] - f11[key]) / (kl - 1)    # 1 mamba
            return f11[key] + (n_groups - 1) * sm +                 n_groups * (per - 1) * mamba

        cost = {"flops": correct("flops"), "bytes accessed": correct("bytes")}
        coll_corr = correct("coll")
        detail_k, counts_k = f_l["detail"], f_l["counts"]
    else:
        trips = _scan_trips(cfg)
        k = _unroll_factor(trips)
        f_k = _probe(layer_unroll=k)

        def correct(key):
            body = (f_k[key] - f11[key]) / (k - 1)
            return f11[key] + (trips - 1) * body

        cost = {"flops": correct("flops"), "bytes accessed": correct("bytes")}
        coll_corr = correct("coll")
        detail_k, counts_k = f_k["detail"], f_k["counts"]

    # scale the per-kind detail proportionally for reporting
    scale = coll_corr / max(sum(detail_k.values()), 1.0)
    detail = {kk: int(v * scale) for kk, v in detail_k.items()}

    roof = build_roofline(arch, shape, mesh_name, chips, cost,
                          "", cfg, kind, seq, gbatch, mem)
    roof.collective_bytes = float(coll_corr)
    roof.collective_detail = detail
    roof.collective_counts = counts_k
    roof.finish()

    # --- compile 3 (train only): deployable microbatched step — the
    # memory_analysis that must fit HBM (activations scale 1/m). -----------
    deploy_temp = None
    microbatches = 8 if kind == "train" else 1
    if kind == "train" and gbatch % microbatches == 0:
        import functools as _ft
        bundle_mb = build(cfg, mesh, rules=rules, opt_cfg=OptConfig())
        step_mb = _ft.partial(bundle_mb.train_step, microbatches=microbatches)
        pshard = bundle_mb.param_shardings()
        oshard = bundle_mb.opt_shardings()
        fn = jax.jit(step_mb,
                     in_shardings=(pshard, oshard, io["batch_shardings"]),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        mem_mb = fn.lower(bundle_mb.abstract_params(),
                          bundle_mb.abstract_opt_state(),
                          io["batch"]).compile().memory_analysis()
        deploy_temp = float(mem_mb.temp_size_in_bytes)
    rec = roof.row()
    rec.update({
        "status": "ok", "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "output_bytes_per_device": float(mem.output_size_in_bytes),
        "alias_bytes_per_device": float(mem.alias_size_in_bytes),
        "deploy_temp_bytes_per_device": deploy_temp,
        "microbatches": microbatches if deploy_temp is not None else None,
    })
    if verbose:
        print(f"[{arch} x {shape} @ {mesh_name}] OK "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB per device"
              + (f" | deploy(mb={microbatches}): "
                 f"temp={deploy_temp/2**30:.2f}GiB" if deploy_temp else ""))
        print(f"  cost_analysis: flops/dev={rec['per_device_flops']:.3e} "
              f"bytes/dev={rec['per_device_bytes']:.3e}")
        print(f"  collectives/dev: {rec['collective_bytes']:.3e} B "
              f"{rec['collective_counts']}")
        print(f"  roofline: compute={rec['compute_s']*1e3:.2f}ms "
              f"memory={rec['memory_s']*1e3:.2f}ms "
              f"collective={rec['collective_s']*1e3:.2f}ms "
              f"-> bottleneck={rec['bottleneck']} "
              f"frac={rec['roofline_frac']:.3f}")
    return rec


def run_peps_cell(shape: str, multi_pod: bool, verbose: bool = True,
                  gram_final: bool = False, constrain_carry: bool = False,
                  mode: str = "cyclops") -> dict:
    # repro.core enables jax x64 on import (complex128 PEPS); restore the
    # flag afterwards so later LM cells keep int32/bf16 semantics.
    x64_before = jax.config.jax_enable_x64
    from repro.core.sharding import (PEPSConfig, abstract_ensemble,
                                     batched_contract, batched_evolve,
                                     peps_shardings)
    pcfg = PEPSConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = _mesh_name(multi_pod)
    chips = mesh.devices.size
    states = abstract_ensemble(pcfg)
    sshard = peps_shardings(states, mesh, batched=True, mode=mode)
    keys = jax.ShapeDtypeStruct((pcfg.ensemble, 2), jnp.uint32)
    t0 = time.time()
    if shape == "evolve":
        fn = jax.jit(batched_evolve, in_shardings=(sshard, None),
                     out_shardings=sshard)
        lowered = fn.lower(states, keys)
    else:
        from repro.core.sharding import batched_contract as bc, \
            carry_model_constraint
        cc = carry_model_constraint(mesh) if constrain_carry else None
        fn = jax.jit(lambda s, k: bc(s, pcfg.chi, k, gram_final, cc),
                     in_shardings=(sshard, None))
        lowered = fn.lower(states, keys)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()

    from repro.launch.hlo_analysis import collective_bytes
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    coll, detail, counts = collective_bytes(hlo)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    terms = {"compute": flops / PEAK_FLOPS_BF16, "memory": nbytes / HBM_BW,
             "collective": coll / ICI_BW}
    rec = {
        "arch": "peps-rqc", "shape": shape, "mesh": mesh_name, "chips": chips,
        "status": "ok", "per_device_flops": flops, "per_device_bytes": nbytes,
        "collective_bytes": float(coll), "collective_detail": detail,
        "collective_counts": counts,
        "compute_s": terms["compute"], "memory_s": terms["memory"],
        "collective_s": terms["collective"],
        "bottleneck": max(terms, key=terms.get),
        "step_s": max(terms.values()),
        "roofline_frac": terms["compute"] / max(terms.values()),
        "arg_bytes_per_device": float(mem.argument_size_in_bytes),
        "temp_bytes_per_device": float(mem.temp_size_in_bytes),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "model_flops": 0.0, "useful_ratio": 0.0,
    }
    jax.config.update("jax_enable_x64", x64_before)
    if verbose:
        print(f"[peps-rqc x {shape} @ {mesh_name}] OK "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        print(f"  args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB per device")
        print(f"  roofline: compute={terms['compute']*1e3:.2f}ms "
              f"memory={terms['memory']*1e3:.2f}ms "
              f"collective={terms['collective']*1e3:.2f}ms "
              f"-> {rec['bottleneck']}")
    return rec


def run_cell(arch: str, shape: str, multi_pod: bool, verbose=True) -> dict:
    try:
        if arch == "peps-rqc":
            return run_peps_cell(shape, multi_pod, verbose)
        return run_lm_cell(arch, shape, multi_pod, verbose=verbose)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        traceback.print_exc()
        return {"arch": arch, "shape": shape, "mesh": _mesh_name(multi_pod),
                "status": "error", "error": f"{type(e).__name__}: {e}"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (or peps-rqc)")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())

    def done(a, s, m):
        return any(r["arch"] == a and r["shape"] == s and r["mesh"] == m
                   and r.get("status") in ("ok", "skipped") for r in results)

    def save():
        out_path.write_text(json.dumps(results, indent=1, default=str))

    if args.all:
        cells = [(a, s) for a in configs.ARCHS for s in LM_SHAPES]
        cells += [("peps-rqc", s) for s in PEPS_SHAPES]
        meshes = [False] if args.single_pod_only else [False, True]
        for multi_pod in meshes:
            for arch, shape in cells:
                if done(arch, shape, _mesh_name(multi_pod)):
                    continue
                rec = run_cell(arch, shape, multi_pod)
                results = [r for r in results if not (
                    r["arch"] == arch and r["shape"] == shape and
                    r["mesh"] == rec["mesh"])]
                results.append(rec)
                save()
        n_ok = sum(1 for r in results if r.get("status") == "ok")
        n_skip = sum(1 for r in results if r.get("status") == "skipped")
        n_err = sum(1 for r in results if r.get("status") == "error")
        print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_skip} skipped, {n_err} errors "
              f"(of {len(results)} cells)")
        raise SystemExit(1 if n_err else 0)

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = run_cell(args.arch, args.shape, args.multi_pod)
    results = [r for r in results if not (
        r["arch"] == rec["arch"] and r["shape"] == rec["shape"] and
        r["mesh"] == rec["mesh"])]
    results.append(rec)
    save()
    raise SystemExit(0 if rec.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
