"""Async, atomic, mesh-independent checkpointing with elastic resharding.

* **Mesh-independent**: leaves are saved as host numpy arrays keyed by their
  tree path, so a checkpoint written on a (16,16) mesh restores onto (2,16,16)
  or a single CPU device (elastic scaling / local debugging).
* **Atomic**: written to ``step_XXXX.tmp`` then ``os.replace``d; a crashed
  writer never corrupts the latest checkpoint.  Orphaned ``*.tmp`` dirs left
  by a process killed mid-write are swept on manager init, and ``all_steps``
  ignores any published directory whose manifest is unreadable — a torn
  write can never shadow the previous good step (see
  ``tests/test_checkpoint.py`` and the ``checkpoint.write`` fault site).
* **Async**: the device->host transfer happens synchronously (cheap), the
  disk write happens on a background thread; ``wait()`` joins before exit.
* **Self-validating**: a manifest with per-leaf shapes/dtypes + step is
  stored; ``restore`` verifies it and re-device_puts with the *target*
  shardings.

Dtypes: numpy-native kinds — floats, ints, unsigned, bool, **complex**
(PEPS tensors are c64/c128!), and unicode (JSON-in-a-leaf metadata) — are
stored as-is and round-trip bit-identically.  Only the ml_dtypes extension
types (bf16, fp8: numpy kind ``'V'``, whose raw ``.npy`` files load back as
void scalars) are widened to float32 on disk and narrowed back on restore.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import faults

#: numpy dtype kinds stored natively (everything .npy round-trips exactly):
#: float, int, unsigned, bool, complex, unicode.  Kind 'V' (ml_dtypes bf16/
#: fp8 register as void structs) must be widened — np.save writes them but
#: np.load returns raw void scalars.
_NATIVE_KINDS = "fiubcU"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        # Sweep orphaned tmp dirs from a previous process killed mid-write.
        # Only *.tmp is touched: published steps are never eligible.
        for p in self.dir.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot to host memory now; write to disk asynchronously."""
        self.wait()
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}

        def write():
            fault = faults.should_fire("checkpoint.write")
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            manifest = {"step": step, "leaves": {}}
            for key, arr in host.items():
                fname = key.replace("/", "__") + ".npy"
                stored = arr
                if arr.dtype.kind not in _NATIVE_KINDS:  # ml_dtypes bf16/fp8
                    stored = arr.astype(np.float32)
                np.save(tmp / fname, stored)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
                if fault is not None and fault.action == "torn":
                    # injected kill mid-write: partial tmp, never published
                    return
            body = json.dumps(manifest)
            if fault is not None and fault.action == "torn_final":
                # injected kill mid-publish on a non-atomic filesystem: the
                # final dir exists but its manifest is truncated garbage
                (tmp / "manifest.json").write_text(body[: len(body) // 2])
                os.replace(tmp, final)
                return
            (tmp / "manifest.json").write_text(body)
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                json.loads((p / "manifest.json").read_text())
            except (OSError, ValueError):
                continue   # torn publish: never shadows a good step
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _manifest(self, step: int) -> dict:
        final = self.dir / f"step_{step:08d}"
        try:
            return json.loads((final / "manifest.json").read_text())
        except FileNotFoundError:
            raise FileNotFoundError(
                f"no checkpoint for step {step} in {self.dir} "
                f"(available steps: {self.all_steps() or 'none'})") from None

    def load(self, step: int) -> Dict[str, np.ndarray]:
        """Load a checkpoint as a flat ``{tree-path: np.ndarray}`` dict.

        Target-free restore: callers that know their own tree layout (the
        ITE/VQE resume paths, which must also recover non-leaf state like
        ``PEPS.log_scale``) decode the flat dict directly.  Dtypes are
        narrowed back per the manifest (bf16 leaves were widened on disk)."""
        final = self.dir / f"step_{step:08d}"
        manifest = self._manifest(step)
        out = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(final / meta["file"])
            if str(arr.dtype) != meta["dtype"]:    # stored widened (bf16->f32)
                import ml_dtypes  # noqa: F401 — registers jax dtypes w/ numpy
                arr = arr.astype(np.dtype(meta["dtype"]))
            out[key] = arr
        return out

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree`` (ShapeDtypeStructs or
        arrays), placing leaves with ``shardings`` (elastic resharding)."""
        flat = self.load(step)
        flat_target = _flatten(target_tree)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, arr in flat.items():
            if key not in flat_target:
                raise KeyError(f"checkpoint leaf {key} not in target tree")
            want = flat_target[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {want.shape}")
            sh = flat_shard.get(key)
            out[key] = (jax.device_put(arr, sh) if sh is not None
                        else jax.numpy.asarray(arr))
        missing = set(flat_target) - set(out)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
        # rebuild the tree
        leaves_paths = jax.tree_util.tree_flatten_with_path(target_tree)
        keys_in_order = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path) for path, _ in leaves_paths[0]]
        return jax.tree_util.tree_unflatten(
            leaves_paths[1], [out[k] for k in keys_in_order])
