"""Async, atomic, mesh-independent checkpointing with elastic resharding.

* **Mesh-independent**: leaves are saved as host numpy arrays keyed by their
  tree path, so a checkpoint written on a (16,16) mesh restores onto (2,16,16)
  or a single CPU device (elastic scaling / local debugging).
* **Atomic**: written to ``step_XXXX.tmp`` then ``os.replace``d; a crashed
  writer never corrupts the latest checkpoint.
* **Async**: the device->host transfer happens synchronously (cheap), the
  disk write happens on a background thread; ``wait()`` joins before exit.
* **Self-validating**: a manifest with per-leaf shapes/dtypes + step is
  stored; ``restore`` verifies it and re-device_puts with the *target*
  shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot to host memory now; write to disk asynchronously."""
        self.wait()
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            manifest = {"step": step, "leaves": {}}
            for key, arr in host.items():
                fname = key.replace("/", "__") + ".npy"
                stored = arr
                if arr.dtype.kind not in "fiub":   # ml_dtypes (bf16, fp8, ...)
                    stored = arr.astype(np.float32)
                np.save(tmp / fname, stored)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree`` (ShapeDtypeStructs or
        arrays), placing leaves with ``shardings`` (elastic resharding)."""
        final = self.dir / f"step_{step:08d}"
        manifest = json.loads((final / "manifest.json").read_text())
        flat_target = _flatten(target_tree)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, meta in manifest["leaves"].items():
            if key not in flat_target:
                raise KeyError(f"checkpoint leaf {key} not in target tree")
            arr = np.load(final / meta["file"])
            want = flat_target[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {want.shape}")
            if str(arr.dtype) != meta["dtype"]:    # stored widened (bf16->f32)
                import ml_dtypes  # noqa: F401 — registers jax dtypes w/ numpy
                arr = arr.astype(np.dtype(meta["dtype"]))
            sh = flat_shard.get(key)
            out[key] = (jax.device_put(arr, sh) if sh is not None
                        else jax.numpy.asarray(arr))
        missing = set(flat_target) - set(out)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
        # rebuild the tree
        leaves_paths = jax.tree_util.tree_flatten_with_path(target_tree)
        keys_in_order = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path) for path, _ in leaves_paths[0]]
        return jax.tree_util.tree_unflatten(
            leaves_paths[1], [out[k] for k in keys_in_order])
