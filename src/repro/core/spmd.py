"""Compiled SPMD wavefront superstep for chi-saturated rows (docs/contraction.md).

:mod:`repro.core.distributed` runs the boundary-MPS zip-up as an
explicit-placement pipeline: the host issues one ``zipup_block*`` call per
(row, block) and JAX's async dispatch overlaps them into a wavefront.  That
is the only *general* option — the truncated zip-up is shape-polymorphic
while bonds ramp ``1 -> chi`` — but for the **chi-saturated steady state**
(interior rows whose boundary shapes are a fixed point of the absorption)
every shard's per-column work is shape-uniform, and the whole wavefront can
move into ONE compiled SPMD program: this module builds that program with
``shard_map``, exchanging halos with ``lax.ppermute`` instead of host-driven
``device_put``.

The superstep preserves the library's distribution contract: the identical
sequence of einsumsvd calls with identical operands and PRNG keys as the
single-device sweep (and therefore as the explicit-placement pipeline).  It
is pure re-scheduling; values match to rounding, enforced at 1e-10 in
``tests/test_spmd.py``.

How a sequential zip-up becomes SPMD
------------------------------------
Three ideas, in order of load-bearing-ness:

1. **Per-column micro-steps on the existing kernels.**  ``zipup_block`` /
   ``zipup_block_twolayer`` called with a single-column block ARE the
   per-column einsumsvd steps (``first=True`` = the column-0 carry init,
   ``last=True`` with an empty block = the closing reshape).  The superstep
   is assembled from exactly these calls, so planner signatures — and the
   arithmetic — are the same as every other execution mode.

2. **Wavefront over supersteps.**  With ``n`` shards of ``w`` columns each
   and ``R`` saturated rows, superstep ``t`` has shard ``s`` absorbing its
   block of row ``t - s`` (sub-steps ``j = 0..w-1``, one svd each).  Two
   ``ppermute`` collectives per superstep move the halos:

   * *forward* (end of superstep): the zip-up carry ``V`` goes ``s -> s+1``
     — shard ``s+1`` consumes it for the same row one superstep later;
   * *backward* (after sub-step 0): the einsumsvd at a block's first column
     emits the boundary tensor of the *previous* block's last column (the
     zip-up's one-column output lag); it goes ``s -> s-1`` mid-superstep,
     arriving before the receiver's sub-step ``w-1`` consumes that slot.
     This intra-superstep hop is why blocks need ``w >= 2``: with ``w = 1``
     the emission would be produced and consumed in the same sub-step.

   All ``R + n - 1`` supersteps run inside one ``lax.fori_loop`` — the
   wavefront schedule is compiled, not host-issued.

3. **Uniform containers, true-shape slices.**  An SPMD region needs
   shape-uniform per-shard arrays, but the lattice edges keep small bonds
   forever (the bond ``k`` columns from an edge saturates at
   ``min(chi, r^2k)``, not chi).  Zero-padding *operands* would change the
   randomized-SVD sketches and break equivalence — so padding here is
   **storage only**: boundary tensors live zero-padded in a uniform
   container stack, and every einsumsvd reads statically-sliced true-shape
   tensors out of it.  The ramp columns' svds (static shapes known at trace
   time) are included in the trace alongside the uniform ones; every shard
   executes them, only the edge shards keep the results (``jnp.where`` on
   ``axis_index``) — the price of shape uniformity, amortized as
   ``O(ramp/w)`` redundant work.

Applicability (what "chi-saturated" means operationally)
--------------------------------------------------------
A run of rows is handed to the superstep iff, per :func:`plan_run`:

* **stationary** — absorbing the row maps the boundary shapes to
  themselves (checked by ``jax.eval_shape`` on the micro-steps, so the
  check can never disagree with the real kernels);
* **layout-uniform** — ``ncol`` splits into ``n`` equal blocks of
  ``w >= 2`` columns on ``n`` *distinct* devices, with the non-uniform
  (ramp/edge) columns confined to the first and last block: the uniform
  svd-column run ``[jl, jr)`` must satisfy ``jl <= w - 1`` and
  ``jr >= (n-1) w + 1``.  The superstep picks the largest such ``n``
  dividing ``ncol`` (it need not equal the host pipeline's shard count —
  blocking invariance means any split computes the same values);
* **uniform rows batch** — consecutive rows with identical PEPS column
  shapes extend the batch ``R``.

Bond-ramp rows (early rows, where shapes are NOT stationary) always stay on
the explicit-placement pipeline; ``DistributedBMPS(wavefront="auto"|"spmd")``
does this handoff per row and :func:`stats` counts both sides.

Planner-cache interaction
-------------------------
The superstep program is cached per (kernel, plan, R, collect, devices,
backend) — see :func:`stats`.  *Inside* the trace, each micro-step reaches
:func:`repro.core.planner.fused_randomized_svd` with the same network
signature as the host path, so after any warm-up sweep the trace replays
100% cached fused solvers (ticked at trace time; a replayed superstep ticks
nothing — it is one compiled call).  Plan shape-analysis runs under
``planner.disabled()`` and touches no cache.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import planner
from repro.core.bmps import _keys, zipup_block, zipup_block_twolayer
from repro.launch.mesh import col_mesh, shard_map

_AXIS = "col"

_PLAN_CACHE: dict = {}
_FN_CACHE: dict = {}
_MISSING = object()

_STATS = {
    "plans": 0,              # plan analyses run (cache misses)
    "superstep_builds": 0,   # compiled superstep programs built
    "superstep_calls": 0,    # superstep invocations (compiled replays)
    "rows_spmd": 0,          # rows absorbed inside the SPMD superstep
    "rows_host": 0,          # rows absorbed by the explicit-placement path
}                            # (rows_* tick only in "spmd"/"auto" sweeps)


def stats() -> dict:
    """Copy of the superstep counters (plus cache sizes)."""
    out = dict(_STATS)
    out["plan_cache_size"] = len(_PLAN_CACHE)
    out["fn_cache_size"] = len(_FN_CACHE)
    return out


def reset_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


def clear() -> None:
    """Drop plan + compiled-program caches and counters."""
    _PLAN_CACHE.clear()
    _FN_CACHE.clear()
    reset_stats()


def note_host_rows(n: int) -> None:
    """Record rows a "spmd"/"auto" sweep handed to the host pipeline."""
    _STATS["rows_host"] += n


# ---------------------------------------------------------------------------
# Per-column micro-steps, layered on the shard-local bmps kernels
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Kernel:
    """One zip-up micro-step = a ``zipup_block*`` call with a 1-column block.

    ``init`` absorbs column 0 into the carry (no svd), ``step`` runs exactly
    one einsumsvd (emitting the PREVIOUS column's boundary tensor — the
    zip-up lag), ``close`` folds the final carry into the last tensor.
    Because these are the block kernels themselves, the einsumsvd
    subscripts, operand shapes and key consumption are identical to the
    single-device and host-wavefront sweeps by construction.
    """
    name: str
    nsites: int  # site operands per column: 1 one-layer, 2 two-layer

    def _block(self, v, svs, site_cols, chi, svd, keys, first, last):
        if self.nsites == 1:
            return zipup_block(v, svs, site_cols[0], chi, svd, keys,
                               first=first, last=last)
        return zipup_block_twolayer(v, svs, site_cols[0], site_cols[1],
                                    chi, svd, keys, first=first, last=last)

    def init(self, sv0, sites0, chi, svd, key):
        _, v = self._block(None, [sv0], [[t] for t in sites0], chi, svd,
                           [key], True, False)
        return v

    def step(self, v, svj, sitesj, chi, svd, key):
        out, v2 = self._block(v, [svj], [[t] for t in sitesj], chi, svd,
                              [key], False, False)
        return out[0], v2

    def close(self, v, chi, svd):
        out, _ = self._block(v, [], [[] for _ in range(self.nsites)],
                             chi, svd, [], False, True)
        return out[0]


ONE_LAYER = _Kernel("onelayer", 1)
TWO_LAYER = _Kernel("twolayer", 2)


# ---------------------------------------------------------------------------
# Shape plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RowPlan:
    """Static shape program of one saturated-row absorption.

    ``sv_shapes[c]`` / ``site_shapes[c]`` are the TRUE per-column operand
    shapes; ``sv_cont`` / ``site_cont[k]`` the uniform storage containers
    (elementwise max).  ``[jl, jr)`` is the uniform svd-column run; columns
    outside it are the ramp/edge specials executed on the first/last shard.
    ``n = 1`` is the degenerate single-shard plan: the whole row chained in
    one compiled program (no collectives), used by ``wavefront="spmd"`` when
    no uniform multi-shard split exists.
    """
    ncol: int
    n: int
    w: int
    jl: int
    jr: int
    sv_shapes: Tuple[Tuple[int, ...], ...]
    site_shapes: Tuple[Tuple[Tuple[int, ...], ...], ...]
    sv_u: Tuple[int, ...]
    site_u: Tuple[Tuple[int, ...], ...]
    v_u: Tuple[int, ...]
    sv_cont: Tuple[int, ...]
    site_cont: Tuple[Tuple[int, ...], ...]
    dtype: str


def _cut(x, shape):
    """Statically slice the true-shape tensor out of a padded container."""
    if tuple(x.shape) == tuple(shape):
        return x
    return lax.slice(x, (0,) * x.ndim, tuple(shape))


def _grow(x, shape):
    """Zero-pad a tensor into its container slot (storage only — every
    consumer slices back to the true shape before computing)."""
    if tuple(x.shape) == tuple(shape):
        return x
    return jnp.pad(x, [(0, c - d) for d, c in zip(x.shape, shape)])


def _eval_row(kernel, chi, svd, sv_shapes, site_shapes, dtype):
    """Shape program of one row absorption via ``jax.eval_shape``.

    Returns ``(emits, vins)``: ``emits[c]`` the output boundary-tensor shape
    for slot ``c``; ``vins[c]`` the carry shape ENTERING column ``c``'s svd
    (``vins[1]`` = the init output, ``vins[ncol]`` = the close input).
    Runs under ``planner.disabled()`` so analysis touches no cache."""
    ncol = len(sv_shapes)
    kst = jax.ShapeDtypeStruct((2,), np.uint32)
    S = lambda sh: jax.ShapeDtypeStruct(tuple(sh), dtype)
    emits: List = [None] * ncol
    vins: List = [None] * (ncol + 1)
    with planner.disabled():
        vins[1] = jax.eval_shape(
            lambda sv, st, k: kernel.init(sv, list(st), chi, svd, k),
            S(sv_shapes[0]), tuple(S(s) for s in site_shapes[0]), kst)
        for c in range(1, ncol):
            e, v = jax.eval_shape(
                lambda v_, sv, st, k: kernel.step(v_, sv, list(st), chi, svd, k),
                vins[c], S(sv_shapes[c]),
                tuple(S(s) for s in site_shapes[c]), kst)
            emits[c - 1] = tuple(e.shape)
            vins[c + 1] = v
        fin = jax.eval_shape(lambda v_: kernel.close(v_, chi, svd), vins[ncol])
        emits[ncol - 1] = tuple(fin.shape)
    return emits, [None] + [tuple(v.shape) for v in vins[1:]]


def _uniform_run(flags: Sequence[bool]) -> Tuple[int, int]:
    """Longest contiguous True run as ``[jl, jr)`` (``(0, 0)`` if none)."""
    best = (0, 0)
    start = None
    for i, f in enumerate(list(flags) + [False]):
        if f and start is None:
            start = i
        elif not f and start is not None:
            if i - start > best[1] - best[0]:
                best = (start, i)
            start = None
    return best


def _distinct_devices(devices):
    seen, out = set(), []
    for d in devices:
        if d.id not in seen:
            seen.add(d.id)
            out.append(d)
    return tuple(out)


def _make_plan(kernel, chi, svd, sv_shapes, site_shapes, dtype, n_shards,
               ndev, allow_single) -> Optional[RowPlan]:
    ncol = len(sv_shapes)
    if ncol < 2:
        return None
    emits, vins = _eval_row(kernel, chi, svd, sv_shapes, site_shapes, dtype)
    if any(tuple(emits[c]) != tuple(sv_shapes[c]) for c in range(ncol)):
        return None  # not a shape fixed point: a bond-ramp row
    mid = ncol // 2
    sv_u, site_u, v_u = sv_shapes[mid], site_shapes[mid], vins[mid]
    flags = [False] + [
        sv_shapes[c] == sv_u and site_shapes[c] == site_u
        and vins[c] == v_u and vins[c + 1] == v_u
        and sv_shapes[c - 1] == sv_u
        for c in range(1, ncol)]
    jl, jr = _uniform_run(flags)
    # the close always runs as special work on the last shard (it emits the
    # final boundary tensor from the carry), so the wave program needs at
    # least one special right column: keep column ncol-1 out of the uniform
    # run even when its shapes happen to match the interior (e.g. bond 1)
    jr = min(jr, ncol - 1)
    layout = None
    for n in range(min(n_shards, ndev, ncol // 2), 1, -1):
        if ncol % n:
            continue
        w = ncol // n
        # specials confined to the edge blocks; the block-boundary slots and
        # the sub-step-0 emissions crossing shard edges must be uniform
        if jr > jl and jl <= w - 1 and jr >= (n - 1) * w + 1:
            layout = (n, w)
            break
    if layout is None:
        if not allow_single:
            return None
        layout = (1, ncol)
    sv_cont = tuple(max(s[i] for s in sv_shapes)
                    for i in range(len(sv_shapes[0])))
    site_cont = tuple(
        tuple(max(site_shapes[c][k][i] for c in range(ncol))
              for i in range(len(site_shapes[0][k])))
        for k in range(len(site_shapes[0])))
    return RowPlan(ncol=ncol, n=layout[0], w=layout[1], jl=jl, jr=jr,
                   sv_shapes=tuple(sv_shapes), site_shapes=tuple(site_shapes),
                   sv_u=tuple(sv_u), site_u=tuple(site_u), v_u=tuple(v_u),
                   sv_cont=sv_cont, site_cont=site_cont,
                   dtype=np.dtype(dtype).name)


def plan_run(kernel, svec_cols, grids, start, chi, svd, n_shards, devices,
             mode) -> Tuple[int, Optional[RowPlan]]:
    """Longest run of rows from ``start`` the superstep can absorb.

    ``grids`` is a tuple of site grids (1 one-layer; 2 two-layer bra/ket).
    Returns ``(0, None)`` when row ``start`` is not applicable (ramp row,
    no uniform layout, wrapped devices); otherwise ``(R, plan)`` where rows
    ``start..start+R-1`` share the plan's shapes."""
    nrow = len(grids[0])
    ncol = len(svec_cols)
    sv_shapes = tuple(tuple(t.shape) for t in svec_cols)

    def row_sig(i):
        return tuple(tuple(tuple(g[i][c].shape) for g in grids)
                     for c in range(ncol))

    sig0 = row_sig(start)
    uniq = _distinct_devices(devices)
    allow_single = (mode == "spmd")
    key = (kernel.name, sv_shapes, sig0, str(np.dtype(svec_cols[0].dtype)),
           chi, svd, min(n_shards, len(uniq)), allow_single)
    plan = _PLAN_CACHE.get(key, _MISSING)
    if plan is _MISSING:
        _STATS["plans"] += 1
        plan = _make_plan(kernel, chi, svd, sv_shapes, sig0,
                          svec_cols[0].dtype, n_shards, len(uniq),
                          allow_single)
        _PLAN_CACHE[key] = plan
    if plan is None:
        return 0, None
    run = 1
    while start + run < nrow and row_sig(start + run) == sig0:
        run += 1
    return run, plan


# ---------------------------------------------------------------------------
# Superstep program builders
# ---------------------------------------------------------------------------

def _build_chain(kernel, chi, svd, plan: RowPlan, R: int, collect: bool):
    """Degenerate n=1 program: R whole-row absorptions in one fori_loop.

    No collectives — this is the single-device sweep compiled end to end
    (identical arithmetic, zero per-site dispatch overhead)."""
    ncol = plan.ncol

    def run(svg, keys_g, *sites_g):
        def superstep(t, state):
            sv, out = state
            srow = [lax.dynamic_index_in_dim(g, t, 0, False) for g in sites_g]
            krow = lax.dynamic_index_in_dim(keys_g, t, 0, False)

            def site_at(c):
                return [_cut(g[c], plan.site_shapes[c][k])
                        for k, g in enumerate(srow)]

            v = kernel.init(_cut(sv[0], plan.sv_shapes[0]), site_at(0),
                            chi, svd, krow[0])
            for c in range(1, ncol):
                e, v = kernel.step(v, _cut(sv[c], plan.sv_shapes[c]),
                                   site_at(c), chi, svd, krow[c])
                sv = sv.at[c - 1].set(_grow(e, plan.sv_cont))
            fin = kernel.close(v, chi, svd)
            sv = sv.at[ncol - 1].set(_grow(fin, plan.sv_cont))
            if collect:
                out = lax.dynamic_update_index_in_dim(out, sv, t, 0)
            return sv, out

        out0 = (jnp.zeros((R, ncol) + plan.sv_cont, svg.dtype) if collect
                else jnp.zeros((), svg.dtype))
        sv, out = lax.fori_loop(0, R, superstep, (svg, out0))
        return (sv, out) if collect else (sv,)

    return jax.jit(run)


def _build_wave(kernel, chi, svd, plan: RowPlan, R: int, collect: bool,
                devices):
    """The n>=2 shard_map wavefront superstep (module docstring, idea 2)."""
    n, w, ncol = plan.n, plan.w, plan.ncol
    jl, jr = plan.jl, plan.jr
    jrl = jr - (n - 1) * w           # local index of the first right special
    T = R + n - 1
    perm_fwd = [(i, i + 1) for i in range(n - 1)]
    perm_bwd = [(i, i - 1) for i in range(1, n)]
    mesh = col_mesh(devices)

    def body(svg, keys_g, *sites_g):
        # per-shard views: svg (w, *sv_cont), keys (R, w, 2),
        # sites_g[k] (R, w, *site_cont[k])
        s = lax.axis_index(_AXIS)

        def superstep(t, state):
            sv, vin, out = state
            r = t - s
            valid = jnp.logical_and(r >= 0, r < R)
            rc = jnp.clip(r, 0, R - 1)
            srow = [lax.dynamic_index_in_dim(g, rc, 0, False)
                    for g in sites_g]
            krow = lax.dynamic_index_in_dim(keys_g, rc, 0, False)

            def site_true(j, c):
                # column c's true-shape operands, read from local slot j
                return [_cut(g[j], plan.site_shapes[c][k])
                        for k, g in enumerate(srow)]

            def site_uni(j):
                return [_cut(g[j], plan.site_u[k])
                        for k, g in enumerate(srow)]

            # left-chain register: the column-0 carry init (real on shard 0)
            lv = kernel.init(_cut(sv[0], plan.sv_shapes[0]), site_true(0, 0),
                             chi, svd, krow[0])

            # sub-step 0: uniform svd at local column 0.  Emits the LEFT
            # neighbor's last slot (the zip-up lag) — the backward halo.
            emit_u, v = kernel.step(vin, _cut(sv[0], plan.sv_u), site_uni(0),
                                    chi, svd, krow[0])
            back = lax.ppermute(_grow(emit_u, plan.sv_cont), _AXIS, perm_bwd)
            nbv = jnp.logical_and(
                s < n - 1,
                jnp.logical_and(t - s - 1 >= 0, t - s - 1 < R))
            sv = sv.at[w - 1].set(jnp.where(nbv, back, sv[w - 1]))
            if collect:
                # that halo is slot w-1 of the boundary AFTER row t-s-1:
                # patch the level written (stale) one superstep ago
                rb = jnp.clip(t - s - 1, 0, R - 1)
                cur = lax.dynamic_index_in_dim(out, rb, 0, False)
                cur = cur.at[w - 1].set(jnp.where(nbv, back, cur[w - 1]))
                out = lax.dynamic_update_index_in_dim(out, cur, rb, 0)

            rv = None
            for j in range(1, w):
                if j == jl:
                    # the ramp chain converged to the uniform carry shape:
                    # shard 0 rejoins the uniform path
                    v = jnp.where(s == 0, lv, v)
                if j == jrl:
                    rv = v  # carry entering the right specials (real on n-1)
                emit_u, v = kernel.step(v, _cut(sv[j], plan.sv_u),
                                        site_uni(j), chi, svd, krow[j])
                emit_c = _grow(emit_u, plan.sv_cont)
                if j < jl:
                    le, lv = kernel.step(lv, _cut(sv[j], plan.sv_shapes[j]),
                                         site_true(j, j), chi, svd, krow[j])
                    emit_c = jnp.where(s == 0, _grow(le, plan.sv_cont),
                                       emit_c)
                if j >= jrl:
                    c = (n - 1) * w + j
                    re_, rv = kernel.step(rv, _cut(sv[j], plan.sv_shapes[c]),
                                          site_true(j, c), chi, svd, krow[j])
                    emit_c = jnp.where(s == n - 1, _grow(re_, plan.sv_cont),
                                       emit_c)
                sv = sv.at[j - 1].set(jnp.where(valid, emit_c, sv[j - 1]))
            fin = kernel.close(rv, chi, svd)
            sv = sv.at[w - 1].set(jnp.where(
                jnp.logical_and(valid, s == n - 1),
                _grow(fin, plan.sv_cont), sv[w - 1]))
            if collect:
                cur = lax.dynamic_index_in_dim(out, rc, 0, False)
                out = lax.dynamic_update_index_in_dim(
                    out, jnp.where(valid, sv, cur), rc, 0)
            # forward halo: the carry moves to the next shard for the same
            # row's next block (shard n-1's send has no target and drops)
            vout = lax.ppermute(v, _AXIS, perm_fwd)
            return sv, vout, out

        dt = svg.dtype
        out0 = (jnp.zeros((R, w) + plan.sv_cont, dt) if collect
                else jnp.zeros((), dt))
        sv, _, out = lax.fori_loop(
            0, T, superstep, (svg, jnp.zeros(plan.v_u, dt), out0))
        return (sv, out) if collect else (sv,)

    nsites = kernel.nsites
    in_specs = (P(_AXIS), P(None, _AXIS)) + (P(None, _AXIS),) * nsites
    out_specs = (P(_AXIS), P(None, _AXIS)) if collect else (P(_AXIS),)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs))


def _get_fn(kernel, chi, svd, plan: RowPlan, R: int, collect: bool, devices):
    key = (kernel.name, chi, svd, plan, R, collect,
           tuple(d.id for d in devices), jax.default_backend())
    fn = _FN_CACHE.get(key)
    if fn is None:
        _STATS["superstep_builds"] += 1
        fn = (_build_chain(kernel, chi, svd, plan, R, collect)
              if plan.n == 1 else
              _build_wave(kernel, chi, svd, plan, R, collect, devices))
        _FN_CACHE[key] = fn
    return fn


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def absorb_rows(kernel, svec_cols, grid_slices, chi, svd, plan: RowPlan,
                row_keys, devices, collect: bool = False):
    """Absorb ``len(row_keys)`` saturated rows in one compiled superstep.

    ``grid_slices`` is a tuple of per-row site-grid slices (pass the SAME
    list object twice for <psi|psi> so the bra/ket stack is built once).
    Returns ``(new_svec_cols, levels)`` where ``levels`` (``collect=True``)
    is one boundary per absorbed row, true-shaped, on the default device —
    matching :func:`repro.core.distributed.gather_columns` conventions."""
    R = len(row_keys)
    ncol = plan.ncol
    mdevs = _distinct_devices(devices)[:plan.n]
    sites_g: List = []
    if plan.n == 1:
        dev0 = mdevs[0]
        svg = jnp.stack([_grow(jax.device_put(t, dev0), plan.sv_cont)
                         for t in svec_cols])
        keys_g = jnp.stack([_keys(jax.device_put(k, dev0), ncol)
                            for k in row_keys])
        for k, g in enumerate(grid_slices):
            if k and g is grid_slices[0]:
                sites_g.append(sites_g[0])
                continue
            sites_g.append(jnp.stack([
                jnp.stack([_grow(jax.device_put(g[i][c], dev0),
                                 plan.site_cont[k]) for c in range(ncol)])
                for i in range(R)]))
    else:
        # Marshal each shard's column chunk DIRECTLY on its owner device and
        # assemble the global arrays with make_array_from_single_device_arrays
        # — nothing stages through device 0 (each operand moves at most once,
        # from wherever the halo pipeline left it to its superstep owner).
        from jax.sharding import NamedSharding
        mesh = col_mesh(mdevs)
        n, w = plan.n, plan.w

        def assemble(gshape, spec, locals_):
            return jax.make_array_from_single_device_arrays(
                gshape, NamedSharding(mesh, spec), locals_)

        svg = assemble((ncol,) + plan.sv_cont, P(_AXIS), [
            jnp.stack([_grow(jax.device_put(svec_cols[c], mdevs[s]),
                             plan.sv_cont) for c in range(s * w, (s + 1) * w)])
            for s in range(n)])
        # per-row column keys: the split is computed once (deterministic on
        # any device) and each shard receives only its chunk
        keys_rows = [_keys(k, ncol) for k in row_keys]
        keys_g = assemble((R,) + keys_rows[0].shape, P(None, _AXIS), [
            jax.device_put(jnp.stack([kr[s * w:(s + 1) * w]
                                      for kr in keys_rows]), mdevs[s])
            for s in range(n)])
        for k, g in enumerate(grid_slices):
            if k and g is grid_slices[0]:
                sites_g.append(sites_g[0])
                continue
            sites_g.append(assemble((R, ncol) + plan.site_cont[k],
                                    P(None, _AXIS), [
                jnp.stack([
                    jnp.stack([_grow(jax.device_put(g[i][c], mdevs[s]),
                                     plan.site_cont[k])
                               for c in range(s * w, (s + 1) * w)])
                    for i in range(R)])
                for s in range(n)]))
    fn = _get_fn(kernel, chi, svd, plan, R, collect, mdevs)
    res = fn(svg, keys_g, *sites_g)
    _STATS["superstep_calls"] += 1
    _STATS["rows_spmd"] += R
    sv_out = res[0]
    new_cols = [_cut(sv_out[c], plan.sv_shapes[c]) for c in range(ncol)]
    levels = None
    if collect:
        env_out = res[1]
        d0 = jax.local_devices()[0]
        levels = [[jax.device_put(_cut(env_out[r, c], plan.sv_shapes[c]), d0)
                   for c in range(ncol)] for r in range(R)]
    return new_cols, levels
