"""Intra-state distributed boundary contraction (paper Section V).

One large PEPS is sharded **column-block-cyclically** across a set of JAX
devices and the boundary-MPS zip-up runs as a pipelined sweep over the
column blocks: per row absorption, only *halo* tensors — the zip-up carry V
moving right, and one boundary-MPS tensor moving back left per block edge —
travel between neighboring shards.  Everything else (the PEPS columns, the
boundary MPS, the einsumsvd work) stays shard-resident.

This is the intra-state complement of :mod:`repro.core.sharding`, which
parallelizes *ensembles* of independent states: here a single state too
large (in chi or lattice size) for one device is spread over the mesh built
by :func:`repro.launch.mesh.peps_mesh`.

Layout
------
Columns are grouped into contiguous blocks of width ``block`` and blocks
are dealt to the ``n_shards`` shards round-robin (block-cyclic), shard ``s``
owning blocks ``s, s + n_shards, s + 2*n_shards, ...``::

    ncol=8, n_shards=4, block=1          ncol=8, n_shards=4, block=2

    col:    0  1  2  3  4  5  6  7      col:    0  1  2  3  4  5  6  7
    shard:  0  1  2  3  0  1  2  3      shard:  0  0  1  1  2  2  3  3

The default ``block=None`` gives one contiguous block per shard (pure block
layout).  Smaller blocks cycle shards more often — more halo hops, but a
finer-grained pipeline (see docs/distributed.md for the trade-off).

Halo-exchange protocol (per row absorption)
-------------------------------------------
The zip-up of one PEPS row is sequential in the carry V, so a row absorption
is executed block by block, and per block edge exactly two tensors cross
shard boundaries:

1. *forward*: the carry ``V`` (axes ``(a, e1, e2, b, c1, c2)`` two-layer) is
   copied from the block's shard to the next block's shard;
2. *backward*: the einsumsvd at the next block's first column emits the
   boundary-MPS tensor of the *previous* block's last column, which is
   copied back to its owner so every shard keeps exactly its own columns.

JAX dispatch is asynchronous, so while shard ``s+1`` chews on row ``i``,
shard ``s`` — whose columns for row ``i`` are already absorbed — can start
row ``i+1`` as soon as its carry arrives: the sweep pipelines into a
wavefront across rows without any explicit scheduling.

Execution modes (``wavefront=``)
--------------------------------
The pipeline above is the ``"host"`` mode: the wavefront is scheduled from
the host with explicit device placement.  It is the only mode that can run
*bond-ramp* rows — the truncated zip-up is shape-polymorphic while boundary
bonds ramp ``1 -> chi``, and an SPMD region cannot express shards with
different operand shapes without zero-padding, which would change the
randomized-SVD sketches and break the single-device equivalence this module
guarantees.  For **chi-saturated rows** (boundary shapes a fixed point of
the absorption) the shapes ARE uniform away from the lattice edges, and
``wavefront="spmd"`` / ``"auto"`` hand such rows to the compiled
``shard_map`` + ``lax.ppermute`` superstep of :mod:`repro.core.spmd` — same
einsumsvd sequence, wavefront scheduling moved from the host into one
compiled program.  ``"auto"`` detects saturation per row and otherwise
stays on this pipeline; see docs/contraction.md for the mode decision
table.

Planner-cache contract
----------------------
The shard-local kernels are the *same* per-site einsumsvd subnetworks as
the single-device sweep, so their planner signatures — which already
contain the shard-local operand shapes (the block's column tensors) and the
halo dims (the carry V's axes) — are blocking-invariant: every shard
replays the one compiled refactorization per interior-site shape class that
the single-device sweep built (`tests/test_distributed.py` asserts a 100%
fused-cache hit rate for a sharded sweep after a single-device warm-up).
JAX then specializes that one traced executable per device placement
internally.

Equivalence guarantee
---------------------
For any ``(n_shards, block)``, the distributed sweep performs the identical
sequence of einsumsvd calls with identical operands and PRNG keys as the
single-device ``contract_*`` path — blocking only decides *where* each call
runs.  Sharded ``norm_squared`` / ``amplitude`` / ``expectation`` therefore
match single-device values to rounding (<= 1e-10 enforced in tests).

Usage: construct a :class:`DistributedBMPS` and pass it anywhere a
:class:`~repro.core.bmps.BMPS` is accepted::

    XLA_FLAGS=--xla_force_host_platform_device_count=8  # CPU validation

    mesh = peps_mesh(n_col_shards=8)
    opt = DistributedBMPS.for_mesh(mesh, chi=16)
    norm_squared(state, opt)        # == norm_squared(state, BMPS(16)) to 1e-10
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bmps import _keys, zipup_block, zipup_block_twolayer
from repro.core.einsumsvd import DirectSVD, RandomizedSVD
from repro.core.engines import get_engine


# ---------------------------------------------------------------------------
# Column layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColumnLayout:
    """Block-cyclic assignment of ``ncol`` columns to ``n_shards`` shards."""
    ncol: int
    n_shards: int
    block: int

    def __post_init__(self):
        if self.ncol < 1 or self.n_shards < 1 or self.block < 1:
            raise ValueError(f"bad layout {self!r}")

    @property
    def n_blocks(self) -> int:
        return -(-self.ncol // self.block)

    def block_columns(self, b: int) -> range:
        return range(b * self.block, min((b + 1) * self.block, self.ncol))

    @property
    def blocks(self) -> List[Tuple[int, range]]:
        """``[(shard, columns), ...]`` in left-to-right sweep order."""
        return [(b % self.n_shards, self.block_columns(b))
                for b in range(self.n_blocks)]

    def owner(self, col: int) -> int:
        """Shard owning ``col`` (and its boundary-MPS tensor)."""
        return (col // self.block) % self.n_shards


# ---------------------------------------------------------------------------
# Contraction option
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistributedBMPS:
    """Contraction option: column-sharded boundary-MPS, mirroring ``BMPS``.

    ``chi``/``svd`` mean exactly what they do on :class:`BMPS`.  ``n_shards``
    defaults to the number of available devices; ``block`` to one contiguous
    block per shard.  ``devices`` pins the shard->device map (defaults to
    ``jax.devices()``; shards beyond ``len(devices)`` wrap round-robin, so
    any layout also runs — bit-identically — on a single device).

    ``wavefront`` selects how row absorptions are scheduled:

    * ``"host"`` (default) — the explicit-placement pipeline above, the
      only scheduler that handles shape-polymorphic (bond-ramp) rows;
    * ``"spmd"`` — chi-saturated rows run in the compiled ``shard_map`` +
      ``ppermute`` superstep of :mod:`repro.core.spmd`, which plans its own
      equal-width column split over the distinct devices (it may differ
      from this option's block-cyclic layout — blocking never changes
      values).  Rows it cannot express (bond-ramp rows, no uniform split)
      fall back to the host pipeline, degenerate single-shard rows still
      compile as one fused chain, and a sweep that never engaged the
      superstep warns;
    * ``"auto"`` — like ``"spmd"`` but engages only when the superstep
      actually buys parallelism (>= 2 uniform shards on distinct devices),
      and never warns.

    All three modes execute the identical einsumsvd sequence — mode choice
    is pure scheduling and never changes values beyond rounding.

    ``engine`` mirrors :class:`~repro.core.bmps.BMPS`: any registered
    boundary engine (name or instance).  Engines without block structure
    (``supports_blocks=False``, e.g. ``"variational"``) cannot be scheduled
    shard-locally — the halo pipeline runs their row absorptions row-local
    on the default device, sandwiched between the sharded layout, and the
    SPMD wavefront rejects them at construction (the superstep *is* the
    block contract compiled; see docs/contraction.md).

    ``precision`` mirrors :class:`~repro.core.bmps.BMPS`: ``"exact"``
    (default) or ``"mixed"`` — the svd option is wrapped at construction,
    so the halo pipeline and the SPMD superstep inherit the policy
    unchanged (mode choice and sharding never interact with it).
    """
    chi: int
    svd: object = DirectSVD()
    n_shards: Optional[int] = None
    block: Optional[int] = None
    devices: Tuple = ()
    wavefront: str = "host"
    engine: object = "zipup"
    precision: object = "exact"

    def __post_init__(self):
        if self.wavefront not in ("host", "spmd", "auto"):
            raise ValueError(
                f"wavefront must be 'host', 'spmd' or 'auto', "
                f"got {self.wavefront!r}")
        eng = get_engine(self.engine)  # fail fast on unknown engines
        from repro.core.precision import resolve_precision, wrap_svd
        policy = resolve_precision(self.precision)
        object.__setattr__(self, "svd", wrap_svd(self.svd, policy))
        if self.wavefront != "host" and not eng.supports_blocks:
            raise ValueError(
                f"wavefront={self.wavefront!r} requires a block-capable "
                f"boundary engine (the compiled SPMD superstep schedules "
                f"shard-local column blocks), but engine {eng.name!r} has "
                f"supports_blocks=False — use wavefront='host', which runs "
                f"such engines row-local.")

    @classmethod
    def randomized(cls, chi: int, niter: int = 4, oversample: int = 8,
                   fused: bool = True, **kw) -> "DistributedBMPS":
        """Distributed IBMPS / two-layer IBMPS (mirror of BMPS.randomized)."""
        return cls(chi, svd=RandomizedSVD(niter=niter, oversample=oversample,
                                          fused=fused), **kw)

    @classmethod
    def for_mesh(cls, mesh, chi: int, batch_index: int = 0,
                 **kw) -> "DistributedBMPS":
        """Shard over the 'col' axis of a :func:`~repro.launch.mesh.peps_mesh`.

        With a batched mesh ``('col', 'batch')``, ``batch_index`` selects the
        column of devices this state contracts on (one ensemble member per
        batch slice)."""
        names = list(mesh.axis_names)
        if "col" in names:
            devs = np.moveaxis(np.asarray(mesh.devices), names.index("col"), 0)
            devs = devs.reshape(devs.shape[0], -1)
            devs = devs[:, batch_index % devs.shape[1]]
        else:
            devs = np.asarray(mesh.devices).reshape(-1)
        return cls(chi, devices=tuple(devs.tolist()),
                   n_shards=kw.pop("n_shards", len(devs)), **kw)

    def resolve(self, ncol: int) -> Tuple[ColumnLayout, Tuple]:
        """Concrete (layout, devices) for an ``ncol``-column lattice."""
        devices = tuple(self.devices) if self.devices else tuple(jax.devices())
        n = self.n_shards if self.n_shards is not None else len(devices)
        n = max(1, min(n, ncol))
        block = self.block if self.block is not None else -(-ncol // n)
        return ColumnLayout(ncol, n, block), devices


# ---------------------------------------------------------------------------
# Placement helpers
# ---------------------------------------------------------------------------

def _shard_device(layout: ColumnLayout, devices, shard: int):
    return devices[shard % len(devices)]

def _owner_device(layout: ColumnLayout, devices, col: int):
    return _shard_device(layout, devices, layout.owner(col))


def put_columns(rows: Sequence[Sequence[jnp.ndarray]], layout: ColumnLayout,
                devices) -> List[List[jnp.ndarray]]:
    """Commit every column of a tensor grid to its owner shard's device.

    ``device_put`` is a no-op for tensors already resident, so re-sharding
    an already-placed grid is free."""
    return [[jax.device_put(t, _owner_device(layout, devices, c))
             for c, t in enumerate(row)] for row in rows]


def gather_columns(cols: Sequence[jnp.ndarray], device=None) -> List[jnp.ndarray]:
    """Pull a list of per-column tensors onto one device (default: device 0).

    Used to hand sharded environments to the host-local strip contractions
    of :mod:`repro.core.expectation` / :mod:`repro.core.full_update`."""
    if device is None:
        device = jax.local_devices()[0]
    return [jax.device_put(t, device) for t in cols]


# ---------------------------------------------------------------------------
# Distributed row absorption (the halo-exchange step)
# ---------------------------------------------------------------------------

def _absorb_row(svec_cols, layout: ColumnLayout, devices, kernel,
                make_args, keys) -> List[jnp.ndarray]:
    """Run one zip-up row absorption block by block across the shards.

    ``kernel`` is one of the shard-local kernels of :mod:`repro.core.bmps`;
    ``make_args(cols)`` supplies its per-block network operands (already
    committed to the owner).  Implements the halo protocol documented in the
    module docstring: the carry moves forward one shard per block edge; the
    first boundary tensor a block emits moves back to the previous shard.
    """
    ncol = layout.ncol
    blocks = layout.blocks
    out_cols: List[Optional[jnp.ndarray]] = [None] * ncol
    v = None
    for bi, (shard, cols) in enumerate(blocks):
        dev = _shard_device(layout, devices, shard)
        if v is not None:
            v = jax.device_put(v, dev)                  # halo: carry forward
        outs, v = kernel(v, [svec_cols[c] for c in cols], *make_args(cols),
                         [keys[c] for c in cols],
                         first=(bi == 0), last=(bi == len(blocks) - 1))
        start = cols[0] - 1 if bi > 0 else 0
        for k, t in enumerate(outs):
            out_cols[start + k] = t
    # halo: each block's first output is the previous block's last column —
    # hand it back to its owner so the boundary MPS stays column-sharded.
    for bi in range(1, len(blocks)):
        prev_shard, prev_cols = blocks[bi - 1]
        c = prev_cols[-1]
        out_cols[c] = jax.device_put(
            out_cols[c], _shard_device(layout, devices, prev_shard))
    return out_cols


def _row_twolayer(svec_cols, bra_row, ket_row, option: DistributedBMPS,
                  layout, devices, key) -> List[jnp.ndarray]:
    def kernel(v, svec, bra, ket, keys, first, last):
        return zipup_block_twolayer(v, svec, bra, ket, option.chi, option.svd,
                                    keys, first=first, last=last)
    make_args = lambda cols: ([bra_row[c] for c in cols],
                              [ket_row[c] for c in cols])
    return _absorb_row(svec_cols, layout, devices, kernel, make_args,
                       _keys(key, layout.ncol))


def _row_onelayer(svec_cols, row, option: DistributedBMPS, layout, devices,
                  key) -> List[jnp.ndarray]:
    def kernel(v, svec, mpo, keys, first, last):
        return zipup_block(v, svec, mpo, option.chi, option.svd, keys,
                           first=first, last=last)
    make_args = lambda cols: ([row[c] for c in cols],)
    return _absorb_row(svec_cols, layout, devices, kernel, make_args,
                       _keys(key, layout.ncol))


def _sweep_rows(svec_cols, grids, option: DistributedBMPS, layout, devices,
                row_keys, kernel_name: str, collect: bool = False):
    """Absorb all rows of ``grids`` into ``svec_cols``, per-row dispatching
    between the host pipeline and the compiled SPMD superstep.

    ``grids`` is ``(rows,)`` one-layer or ``(bra_rows, ket_rows)`` two-layer
    (pass the same list object twice for <psi|psi>).  ``row_keys[i]`` is row
    ``i``'s key, split into per-column keys identically on both paths.
    ``collect=True`` returns one gathered boundary level per row (for
    environment sweeps).  The wavefront mode decides the dispatch; values
    are mode-independent (same einsumsvd sequence everywhere).

    Engines without block kernels (``supports_blocks=False``) cannot run
    the halo protocol: their rows are absorbed row-local on the default
    device — gather boundary + row, absorb, re-scatter to the owners —
    producing exactly the single-device values (``wavefront != "host"``
    was already rejected at option construction for such engines).
    """
    nrow = len(grids[0])
    eng = get_engine(option.engine)
    if not eng.supports_blocks:
        return _sweep_rows_rowlocal(eng, svec_cols, grids, option, layout,
                                    devices, row_keys, kernel_name, collect)
    mode = option.wavefront
    spmd_mod = None
    if mode != "host":
        from repro.core import spmd as spmd_mod
        kernel = (spmd_mod.TWO_LAYER if kernel_name == "twolayer"
                  else spmd_mod.ONE_LAYER)
    levels = []
    used_spmd = False
    i = 0
    while i < nrow:
        run, plan = 0, None
        if spmd_mod is not None:
            run, plan = spmd_mod.plan_run(
                kernel, svec_cols, grids, i, option.chi, option.svd,
                layout.n_shards, devices, mode)
        if run:
            slices = []
            for g in grids:
                if slices and g is grids[0]:
                    slices.append(slices[0])
                else:
                    slices.append([g[i + j] for j in range(run)])
            svec_cols, lv = spmd_mod.absorb_rows(
                kernel, svec_cols, tuple(slices), option.chi, option.svd,
                plan, row_keys[i:i + run], devices, collect=collect)
            # hand back to the host pipeline's placement (no-op when the
            # superstep layout matches the column-block-cyclic one)
            svec_cols = [jax.device_put(t, _owner_device(layout, devices, c))
                         for c, t in enumerate(svec_cols)]
            if collect:
                levels.extend(lv)
            used_spmd = True
            i += run
            continue
        key = row_keys[i]
        if kernel_name == "twolayer":
            svec_cols = _row_twolayer(svec_cols, grids[0][i], grids[1][i],
                                      option, layout, devices, key)
        else:
            svec_cols = _row_onelayer(svec_cols, grids[0][i], option, layout,
                                      devices, key)
        if collect:
            levels.append(gather_columns(svec_cols))
        if spmd_mod is not None:
            spmd_mod.note_host_rows(1)
        i += 1
    if mode == "spmd" and not used_spmd and nrow > 0:
        import warnings
        warnings.warn(
            "wavefront='spmd' sweep never engaged the SPMD superstep (all "
            "rows were bond-ramp rows, or no uniform column split exists "
            "for this lattice/device set) — the whole sweep ran on the "
            "explicit-placement host pipeline. Use wavefront='auto' to "
            "silence this.", stacklevel=3)
    return svec_cols, levels


def _sweep_rows_rowlocal(eng, svec_cols, grids, option: DistributedBMPS,
                         layout, devices, row_keys, kernel_name: str,
                         collect: bool):
    """Row-local sweep for engines without block kernels (see _sweep_rows).

    Each row is gathered to the default device, absorbed by the engine
    exactly as on the single-device path (same key per row), and the new
    boundary is re-scattered to the column owners, so the sweep stays
    layout-compatible with every downstream consumer."""
    nrow = len(grids[0])
    d0 = jax.local_devices()[0]
    levels = []
    for i in range(nrow):
        svec_g = gather_columns(svec_cols, d0)
        if kernel_name == "twolayer":
            bra_g = gather_columns(grids[0][i], d0)
            ket_g = (bra_g if grids[1] is grids[0]
                     else gather_columns(grids[1][i], d0))
            svec_g = eng.absorb_twolayer(svec_g, bra_g, ket_g, option.chi,
                                         option.svd, row_keys[i])
        else:
            svec_g = eng.absorb_onelayer(svec_g, gather_columns(grids[0][i], d0),
                                         option.chi, option.svd, row_keys[i])
        svec_cols = [jax.device_put(t, _owner_device(layout, devices, c))
                     for c, t in enumerate(svec_g)]
        if collect:
            levels.append(gather_columns(svec_cols))
    return svec_cols, levels


def _final_scalar(svec_cols, layout: ColumnLayout, devices) -> jnp.ndarray:
    """Close a fully-absorbed boundary MPS (all dangling axes dim 1).

    Per-block partial chain products run shard-resident (in parallel, via
    async dispatch); only the tiny per-block (l, r) matrices are gathered
    for the final ordered product."""
    partials = []
    for shard, cols in layout.blocks:
        acc = None
        for c in cols:
            t = svec_cols[c]
            mat = t.reshape(t.shape[0], t.shape[-1])
            acc = mat if acc is None else acc @ mat
        partials.append(acc)
    d0 = jax.local_devices()[0]
    vec = jnp.ones((1,), dtype=svec_cols[0].dtype)
    for p in partials:
        vec = vec @ jax.device_put(p, d0)
    return vec.reshape(())


# ---------------------------------------------------------------------------
# Contraction entry points (dispatched to from repro.core.bmps)
# ---------------------------------------------------------------------------

def contract_twolayer(bra_rows, ket_rows, option: DistributedBMPS,
                      key=None) -> jnp.ndarray:
    """Column-sharded <bra|ket>; same arithmetic as the single-device path."""
    nrow, ncol = len(bra_rows), len(bra_rows[0])
    layout, devices = option.resolve(ncol)
    keys = _keys(key, max(nrow, 2))
    bra = put_columns(bra_rows, layout, devices)
    ket = bra if ket_rows is bra_rows else put_columns(ket_rows, layout, devices)
    dtype = bra_rows[0][0].dtype
    svec = [jax.device_put(jnp.ones((1, 1, 1, 1), dtype=dtype),
                           _owner_device(layout, devices, c))
            for c in range(ncol)]
    svec, _ = _sweep_rows(svec, (bra, ket), option, layout, devices,
                          keys[:nrow], "twolayer")
    return _final_scalar(svec, layout, devices)


def contract_onelayer(rows, option: DistributedBMPS, key=None) -> jnp.ndarray:
    """Column-sharded Alg. 2 (one-layer) contraction to a scalar."""
    nrow, ncol = len(rows), len(rows[0])
    layout, devices = option.resolve(ncol)
    keys = _keys(key, max(nrow, 2))
    rows_c = put_columns(rows, layout, devices)
    # initial boundary MPS = row 0 with u squeezed: (l, d, r)
    svec = [t.reshape(t.shape[1], t.shape[2], t.shape[3]) for t in rows_c[0]]
    svec, _ = _sweep_rows(svec, (rows_c[1:],), option, layout, devices,
                          keys[1:nrow], "onelayer")
    return _final_scalar(svec, layout, devices)


def top_environments(bra_rows, ket_rows, option: DistributedBMPS,
                     key=None) -> List[List[jnp.ndarray]]:
    """Sharded sibling of :func:`repro.core.environments.top_environments`.

    The O(nrow) boundary sweeps — the expensive part of every cached
    expectation — run column-sharded (host pipeline or SPMD superstep per
    the wavefront mode); each environment level is then *gathered* to the
    default device, because the strip contractions that consume
    environments (``expectation.strip_value``, the full update's
    neighborhood extraction) are short, chi-bounded host-local networks.
    Returned values match the single-device function to rounding."""
    nrow, ncol = len(bra_rows), len(bra_rows[0])
    layout, devices = option.resolve(ncol)
    dtype = bra_rows[0][0].dtype
    if key is None:
        from repro.core.environments import DEFAULT_KEY_SEED
        key = jax.random.PRNGKey(DEFAULT_KEY_SEED)
    keys = jax.random.split(key, max(nrow, 2))
    bra = put_columns(bra_rows, layout, devices)
    ket = bra if ket_rows is bra_rows else put_columns(ket_rows, layout, devices)
    svec = [jax.device_put(jnp.ones((1, 1, 1, 1), dtype=dtype),
                           _owner_device(layout, devices, c))
            for c in range(ncol)]
    _, levels = _sweep_rows(svec, (bra, ket), option, layout, devices,
                            keys[:nrow], "twolayer", collect=True)
    envs = [[jnp.ones((1, 1, 1, 1), dtype=dtype) for _ in range(ncol)]]
    envs.extend(levels)
    return envs


# ---------------------------------------------------------------------------
# Introspection (used by benchmarks and docs examples)
# ---------------------------------------------------------------------------

def halo_bytes_per_row(state_or_rows, option: DistributedBMPS) -> int:
    """Bytes crossing shard boundaries per two-layer row absorption.

    Counts the forward carry and the backward boundary tensor at every
    block edge, assuming steady-state bonds (boundary = chi, pair bonds =
    the interior bond squared) — the analytic communication volume the
    scaling benchmarks report alongside wall time."""
    rows = getattr(state_or_rows, "sites", state_or_rows)
    ncol = len(rows[0])
    layout, _ = option.resolve(ncol)
    t = rows[min(1, len(rows) - 1)][min(1, ncol - 1)]
    r = max(t.shape[1:])                       # interior bond
    chi = option.chi
    itemsize = jnp.dtype(t.dtype).itemsize
    carry = chi * r * r * chi * r * r          # (m, h1, h2, g, k1, k2)
    backward = chi * r * r * chi               # (l, d_bra, d_ket, r)
    blocks = layout.blocks
    # only block edges whose two sides live on DIFFERENT shards move bytes
    # (consecutive same-shard blocks — e.g. n_shards=1 — exchange nothing)
    edges = sum(1 for i in range(1, len(blocks))
                if blocks[i][0] != blocks[i - 1][0])
    return edges * (carry + backward) * itemsize
