"""Exact state-vector simulator — the correctness oracle for every PEPS path.

The state of ``n`` qubits is a jnp array of shape ``(2,)*n`` (complex128).
Grid site ``(i, j)`` of an ``nrow x ncol`` PEPS maps to qubit ``i*ncol + j``,
matching the paper's row-major site labelling.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np


def zeros(n: int) -> jnp.ndarray:
    state = np.zeros((2,) * n, dtype=np.complex128)
    state[(0,) * n] = 1.0
    return jnp.asarray(state)


def apply_gate(state: jnp.ndarray, g: np.ndarray, sites: Sequence[int]) -> jnp.ndarray:
    """Apply a 1- or 2-site gate tensor on the given qubit indices."""
    g = jnp.asarray(g, dtype=state.dtype)
    k = len(sites)
    if k == 1:
        # G[i, j] state[..., j, ...]
        out = jnp.tensordot(g, state, axes=[[1], [int(sites[0])]])
        return jnp.moveaxis(out, 0, int(sites[0]))
    if k == 2:
        a, b = int(sites[0]), int(sites[1])
        out = jnp.tensordot(g, state, axes=[[2, 3], [a, b]])
        # output axes 0,1 correspond to sites a,b
        return jnp.moveaxis(out, (0, 1), (a, b))
    raise ValueError(f"unsupported gate arity {k}")


def amplitude(state: jnp.ndarray, bits: Sequence[int]) -> jnp.ndarray:
    return state[tuple(int(b) for b in bits)]


def inner(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """<a|b>."""
    return jnp.vdot(a, b)


def norm(state: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.real(inner(state, state)))


def normalize(state: jnp.ndarray) -> jnp.ndarray:
    return state / norm(state)


def expectation(state: jnp.ndarray, terms) -> jnp.ndarray:
    """<psi|H|psi> / <psi|psi> for H given as Observable-style terms.

    ``terms`` iterates over ``(sites, matrix, coeff)`` with ``matrix`` of
    shape (2,2) or (2,2,2,2) gate-tensor layout.
    """
    total = 0.0 + 0.0j
    nrm = inner(state, state)
    for sites, mat, coeff in terms:
        phi = apply_gate(state, mat, sites)
        total = total + coeff * inner(state, phi)
    return total / nrm
