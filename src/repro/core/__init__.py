"""Core PEPS library — the paper's contribution (Koala) in JAX.

Importing this package enables float64/complex128 support, which quantum
tensor-network arithmetic needs for meaningful accuracy studies. LM-substrate
modules (repro.models, repro.launch) use explicit dtypes and are unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.peps import PEPS, computational_zeros, random_peps  # noqa: E402,F401
from repro.core.gates import GATES, gate, two_site_gate  # noqa: E402,F401
from repro.core.observable import Observable  # noqa: E402,F401
from repro.core.einsumsvd import DirectSVD, RandomizedSVD, einsumsvd  # noqa: E402,F401
