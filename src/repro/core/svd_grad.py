"""Degeneracy-regularized gradients for the einsumsvd linear-algebra seam.

Differentiating a truncated SVD is the one numerically treacherous step in
making ``vqe_energy_peps`` a traceable, differentiable JAX function: the
textbook SVD differential

    dU, dV  ~  F_{ij} = 1 / (s_j^2 - s_i^2),      s_inv = 1 / s

blows up whenever two singular values (nearly) coincide or a singular value
(nearly) vanishes.  Both happen *structurally* in PEPS circuit simulation —
a bond whose actual rank is below the padded bond dimension carries exact
zero singular values (e.g. every bond of the t=0 product state), and
symmetric circuits produce exactly degenerate pairs.  JAX's stock
``jnp.linalg.svd``/``eigh`` JVP rules zero the *exactly* equal entries but
return huge, noise-amplifying values for nearly-equal ones, and divide by
exact zeros in the thin-SVD completion term.

This module provides drop-in wrappers whose **forward pass is bit-identical**
to ``jnp.linalg.svd(a, full_matrices=False)`` / ``jnp.linalg.eigh(a)`` /
``jnp.sqrt(s)`` (they call exactly those), with custom JVP rules that replace
every reciprocal-spectral-gap factor by its Lorentzian broadening

    1 / d   ->   d / (d^2 + tol^2),     tol = SVD_GRAD_RTOL * scale

(``scale`` = the largest singular value / eigenvalue of the same matrix, so
the broadening is relative).  The broadened factor agrees with ``1/d`` to
``O((tol/d)^2)`` for well-separated spectra and rolls smoothly to zero at
coincidence instead of diverging.

Why zeroing the degenerate directions is *correct* for this library (the
gauge argument): everything downstream of an einsumsvd consumes the
truncated product ``U_k S_k V_k^H`` (possibly with ``sqrt(S_k)`` absorbed to
each side) contracted back into a gauge-invariant network — a unitary
rotation *within* a degenerate singular subspace changes ``U``/``V``
individually but leaves the product invariant.  The entries the regularizer
suppresses are precisely those intra-subspace gauge rotations, so the
gradient of any gauge-invariant downstream quantity (an energy, an
amplitude) is untouched.  The only genuinely non-differentiable point is a
degeneracy *straddling the truncation cut* (the retained subspace itself is
then discontinuous) — a measure-zero set where no finite answer exists;
there the regularized gradient stays finite and picks the symmetric
subgradient.  The contract is measured in ``tests/test_vqe_grad.py``
(autodiff vs central finite differences, including a maximally degenerate
product-state case) and documented in ``docs/vqe.md``.

All rules are written batch-polymorphic (``...``-leading shapes) so they
compose with ``jax.vmap`` — the batched VQE ensemble drivers differentiate
through them under vmap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Relative Lorentzian broadening of reciprocal spectral gaps.  1e-12 keeps
#: the regularizer ~4 orders of magnitude below the 1e-8 FD-visible scale of
#: an O(1) energy while still bounding every factor by ~1/(2*tol*scale).
SVD_GRAD_RTOL = 1e-12


def _t(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.swapaxes(x, -1, -2)


def _h(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.swapaxes(x, -1, -2).conj()


def _broadened_reciprocal(d: jnp.ndarray, tol: jnp.ndarray) -> jnp.ndarray:
    """``d / (d^2 + tol^2)``, exactly zero where both d and tol vanish.

    The double-``where`` guards the all-zero-matrix corner (``tol`` scales
    with the spectrum, so a zero operand gives 0/0 without it) and keeps the
    expression safe under further differentiation."""
    denom = d * d + tol * tol
    safe = jnp.where(denom == 0.0, 1.0, denom)
    return jnp.where(denom == 0.0, 0.0, d / safe)


@jax.custom_jvp
def svd_reg(a: jnp.ndarray):
    """Thin SVD ``(u, s, vh)`` with a degeneracy-regularized JVP.

    Forward values are bit-identical to
    ``jnp.linalg.svd(a, full_matrices=False)``; only the derivative rule
    differs (see the module docstring).  Reverse mode (``jax.grad``) works
    through JAX's linearize-then-transpose of the JVP, exactly like the
    builtin rule.

    Returns a plain ``(u, s, vh)`` tuple (not the ``SVDResult`` namedtuple
    — the JVP's output pytree must match the primal's)."""
    u, s, vh = jnp.linalg.svd(a, full_matrices=False)
    return u, s, vh


@svd_reg.defjvp
def _svd_reg_jvp(primals, tangents):
    (a,), (da,) = primals, tangents
    u, s, vh = svd_reg(a)
    ut, v = _h(u), _h(vh)
    s_row = s[..., None, :]                       # (..., 1, k)
    ds_mat = ut @ da @ v                          # (..., k, k)
    ds = jnp.real(jnp.diagonal(ds_mat, axis1=-2, axis2=-1))

    smax = s[..., :1]                             # descending order: s[0] = max
    # F_{ij} = reg(1 / (s_j^2 - s_i^2)); diagonal vanishes identically.
    s_diffs = (s_row + _t(s_row)) * (s_row - _t(s_row))
    tol_f = (SVD_GRAD_RTOL * smax * smax)[..., None, :]
    f = _broadened_reciprocal(s_diffs, tol_f).astype(a.dtype)

    dss = s_row.astype(a.dtype) * ds_mat          # dS @ diag(s)
    sds = _t(s_row).astype(a.dtype) * ds_mat      # diag(s) @ dS
    s_inv = _broadened_reciprocal(s, SVD_GRAD_RTOL * smax)
    eye = jnp.eye(s.shape[-1], dtype=a.dtype)
    s_inv_mat = s_inv[..., None, :].astype(a.dtype) * eye
    du_dv_diag = 0.5 * (ds_mat - _h(ds_mat)) * s_inv_mat
    du = u @ (f * (dss + _h(dss)) + du_dv_diag)
    dv = v @ (f * (sds + _h(sds)))

    m, n = a.shape[-2], a.shape[-1]
    s_inv_row = s_inv[..., None, :].astype(a.dtype)
    if m > n:
        dav = da @ v
        du = du + (dav - u @ (ut @ dav)) * s_inv_row
    if n > m:
        dahu = _h(da) @ u
        dv = dv + (dahu - v @ (_h(v) @ dahu)) * s_inv_row
    return (u, s, vh), (du, ds.astype(s.dtype), _h(dv))


@jax.custom_jvp
def eigh_reg(a: jnp.ndarray):
    """Hermitian eigendecomposition ``(w, v)`` with a regularized JVP.

    Forward values are bit-identical to ``jnp.linalg.eigh(a)``.  Used by
    :func:`repro.core.orthogonalize.gram_qr`, whose Gram matrices have
    *squared* singular values as eigenvalues — rank deficiency there means
    a cluster of exactly degenerate zero eigenvalues.

    Returns a plain ``(w, v)`` tuple (not the ``EighResult`` namedtuple —
    the JVP's output pytree must match the primal's)."""
    w, v = jnp.linalg.eigh(a)
    return w, v


@eigh_reg.defjvp
def _eigh_reg_jvp(primals, tangents):
    (a,), (da,) = primals, tangents
    w, v = eigh_reg(a)
    # eigh reads only one triangle of a, so (like JAX's builtin rule) the
    # tangent is symmetrized — this fixes the gradient's convention on the
    # anti-Hermitian directions the primal never sees.
    da = 0.5 * (da + _h(da))
    vdag_da_v = _h(v) @ da @ v
    dw = jnp.real(jnp.diagonal(vdag_da_v, axis1=-2, axis2=-1))
    # F_{ij} = reg(1 / (w_j - w_i)); diagonal vanishes identically.
    delta = w[..., None, :] - w[..., None]
    wmax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    tol = (SVD_GRAD_RTOL * wmax)[..., None, :]
    f = _broadened_reciprocal(delta, tol).astype(a.dtype)
    dv = v @ (f * vdag_da_v)
    return (w, v), (dw.astype(w.dtype), dv)


#: Ridge broadening of the QR-differential's triangular inverse.  Looser
#: than ``SVD_GRAD_RTOL``: the ALS boundary sweeps chain many QR shifts, so
#: per-shift noise amplification ``sigma_min^-1 ~ 1e16`` COMPOUNDS
#: geometrically across a sweep — the ridge caps each factor at ``~1/tol``
#: and turns the compounded blowup into a compounded suppression.
QR_GRAD_RTOL = 1e-8


@jax.custom_jvp
def qr_reg(a: jnp.ndarray):
    """Reduced QR ``(q, r)`` with a rank-deficiency-safe JVP.

    Forward values are bit-identical to ``jnp.linalg.qr(a)`` (reduced mode).
    The standard QR differential applies ``r^{-1}`` from the right — on the
    numerically rank-deficient bonds a truncated circuit state carries
    (near-zero Schmidt values), ``1/r_jj`` reaches ``1e16`` and the ALS
    boundary sweeps compound it into astronomically wrong (though finite)
    gradients.  This rule replaces the triangular solve with the ridge

        X r^{-1}  ->  X r^H (r r^H + tol^2 I)^{-1},  tol = QR_GRAD_RTOL*|r|

    which agrees to ``O((tol/sigma)^2)`` on well-conditioned directions and
    rolls the noise directions to zero (their columns of ``q`` are gauge:
    they span the numerical null space, whose downstream weight is the
    ``O(sigma_min)`` noise itself).  Like JAX's builtin rule, only the tall/
    square case (``m >= n``) is differentiable.

    Returns a plain ``(q, r)`` tuple (not the ``QRResult`` namedtuple — the
    JVP's output pytree must match the primal's)."""
    q, r = jnp.linalg.qr(a)
    return q, r


@qr_reg.defjvp
def _qr_reg_jvp(primals, tangents):
    (a,), (da,) = primals, tangents
    q, r = qr_reg(a)
    m, n = a.shape[-2], a.shape[-1]
    if m < n:
        raise NotImplementedError(
            "qr_reg JVP is tall/square only (same contract as jnp.linalg.qr)")
    rdiag = jnp.abs(jnp.diagonal(r, axis1=-2, axis2=-1))
    tol = QR_GRAD_RTOL * jnp.max(rdiag, axis=-1, keepdims=True)
    tol = jnp.where(tol == 0.0, 1.0, tol)  # a == 0: gram = I, gradient 0
    eye = jnp.eye(n, dtype=a.dtype)
    gram = r @ _h(r) + (tol * tol)[..., None].astype(a.dtype) * eye
    # dx_rinv = da @ r^{-1}, ridge-regularized: X gram = da r^H solved as
    # gram^T X^T = (da r^H)^T (gram is Hermitian PD, so the solve is stable)
    dx_rinv = _t(jnp.linalg.solve(_t(gram), _t(da @ _h(r))))
    qt_dx_rinv = _h(q) @ dx_rinv
    lower = jnp.tril(qt_dx_rinv, -1)
    do = lower - _h(lower)
    do = do + eye * (qt_dx_rinv - jnp.real(qt_dx_rinv))
    dq = q @ (do - qt_dx_rinv) + dx_rinv
    dr = (qt_dx_rinv - do) @ r
    return (q, r), (dq, dr)


@jax.custom_jvp
def sqrt_reg(s: jnp.ndarray) -> jnp.ndarray:
    """``jnp.sqrt`` whose derivative is taken as 0 at exactly 0.

    ``absorb_factors`` folds ``sqrt(s)`` into both einsumsvd factors; the
    derivative ``1/(2 sqrt(s))`` of the stock sqrt is infinite at the exact
    zero singular values a rank-deficient bond carries.  Those directions
    multiply a zero factor downstream (gauge again), so the symmetric
    subgradient 0 is the correct finite choice."""
    return jnp.sqrt(s)


@sqrt_reg.defjvp
def _sqrt_reg_jvp(primals, tangents):
    (s,), (ds,) = primals, tangents
    r = jnp.sqrt(s)
    safe = jnp.where(r == 0.0, 1.0, r)
    dr = jnp.where(r == 0.0, 0.0, 0.5 / safe) * ds
    return r, dr
