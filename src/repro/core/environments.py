"""Cached row environments for PEPS expectation values (paper Section IV-B).

For ``H = sum_i H_i`` every local term's two-layer contraction shares the
boundary-MPS environments of the rows above and below it.  Two full sweeps
(top-down and bottom-up) produce ``top[i]`` / ``bottom[i]`` for all ``i``;
each local-term expectation then only costs a short strip contraction
(a 3xN or 4xN network instead of a full NxN one).

Environment MPS tensors are in two-layer boundary layout ``(l, d_bra,
d_ket, r)``; ``bottom`` environments face upward (their pair axes contract
with the strip's bottom).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.bmps import BMPS, _zipup_row_twolayer, trivial_twolayer_boundary


def trivial_env(ncol: int, dtype) -> List[jnp.ndarray]:
    one = jnp.ones((1, 1, 1, 1), dtype=dtype)
    return [one for _ in range(ncol)]


def _flip_rows(rows: Sequence[Sequence[jnp.ndarray]]):
    """Vertical flip of a (p,u,l,d,r) grid: reverse rows, swap u<->d."""
    return [[jnp.transpose(t, (0, 3, 2, 1, 4)) for t in row]
            for row in reversed(rows)]


def top_environments(bra_rows, ket_rows, option: BMPS, key=None) -> List[List[jnp.ndarray]]:
    """``top[i]`` = boundary MPS of rows ``0..i-1`` (``top[0]`` trivial).

    Length ``nrow+1``: ``top[nrow]`` is the fully-absorbed network still in
    MPS form (dangling pair axes of dim 1) — closing it gives <bra|ket>."""
    nrow, ncol = len(bra_rows), len(bra_rows[0])
    dtype = bra_rows[0][0].dtype
    if key is None:
        key = jax.random.PRNGKey(11)
    keys = jax.random.split(key, max(nrow, 2))
    envs = [trivial_env(ncol, dtype)]
    svec = trivial_twolayer_boundary(ncol, dtype)
    for i in range(nrow):
        svec = _zipup_row_twolayer(svec, bra_rows[i], ket_rows[i],
                                   option.chi, option.svd, keys[i])
        envs.append(svec)
    return envs


def row_environments(state, option: BMPS, key=None) -> Tuple[List, List]:
    """(top, bottom) environments of the <psi|psi> network of a PEPS.

    * ``top[i]``    covers rows ``0..i-1``       (len nrow+1, ``top[0]`` trivial)
    * ``bottom[i]`` covers rows ``i+1..nrow-1``  (len nrow,  ``bottom[nrow-1]`` trivial)

    This costs two full two-layer sweeps; every local-term expectation after
    that is a strip contraction (the paper's caching strategy)."""
    if key is None:
        key = jax.random.PRNGKey(13)
    k1, k2 = jax.random.split(key)
    nrow = state.nrow
    top = top_environments(state.sites, state.sites, option, k1)
    flipped = top_environments(_flip_rows(state.sites), _flip_rows(state.sites),
                               option, k2)
    bottom = [flipped[nrow - 1 - i] for i in range(nrow)]
    return top, bottom
