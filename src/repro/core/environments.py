"""Cached row environments for PEPS expectation values (paper Section IV-B).

For ``H = sum_i H_i`` every local term's two-layer contraction shares the
boundary-MPS environments of the rows above and below it.  Two full sweeps
(top-down and bottom-up) produce ``top[i]`` / ``bottom[i]`` for all ``i``;
each local-term expectation then only costs a short strip contraction
(a 3xN or 4xN network instead of a full NxN one).

Environment MPS tensors are in two-layer boundary layout ``(l, d_bra,
d_ket, r)``; ``bottom`` environments face upward (their pair axes contract
with the strip's bottom).
"""
from __future__ import annotations

import itertools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.core.bmps import BMPS, trivial_twolayer_boundary
from repro.core.engines import get_engine


#: Seed of the PRNG key used when an environment sweep is called with
#: ``key=None``.  The distributed sibling
#: (:func:`repro.core.distributed.top_environments`) shares this constant so
#: ``key=None`` means the *same* sweep on every path — a divergent default
#: would silently break the sharded == single-device guarantee.
DEFAULT_KEY_SEED = 11


def trivial_env(ncol: int, dtype) -> List[jnp.ndarray]:
    one = jnp.ones((1, 1, 1, 1), dtype=dtype)
    return [one for _ in range(ncol)]


def _flip_rows(rows: Sequence[Sequence[jnp.ndarray]]):
    """Vertical flip of a (p,u,l,d,r) grid: reverse rows, swap u<->d."""
    return [[jnp.transpose(t, (0, 3, 2, 1, 4)) for t in row]
            for row in reversed(rows)]


def top_environments(bra_rows, ket_rows, option: BMPS, key=None) -> List[List[jnp.ndarray]]:
    """``top[i]`` = boundary MPS of rows ``0..i-1`` (``top[0]`` trivial).

    Length ``nrow+1``: ``top[nrow]`` is the fully-absorbed network still in
    MPS form (dangling pair axes of dim 1) — closing it gives <bra|ket>.

    ``option`` may be a :class:`~repro.core.distributed.DistributedBMPS`:
    the sweeps then run column-sharded across devices — the host
    halo-exchange pipeline of :mod:`repro.core.distributed`, or, for
    chi-saturated rows under ``wavefront="spmd"``/``"auto"``, the compiled
    superstep of :mod:`repro.core.spmd` (per-row environment levels are
    collected inside the compiled program) — and each environment level is
    gathered back to the default device, so every downstream consumer —
    ``expectation`` strips, the full update's neighborhood extraction —
    works unchanged.  Values match the single-device sweep to rounding."""
    if key is None:
        key = jax.random.PRNGKey(DEFAULT_KEY_SEED)
    from repro.core.bmps import _distributed_module
    dist = _distributed_module(option)
    if dist is not None:
        return dist.top_environments(bra_rows, ket_rows, option, key)
    eng = get_engine(option.engine)
    nrow, ncol = len(bra_rows), len(bra_rows[0])
    dtype = bra_rows[0][0].dtype
    keys = jax.random.split(key, max(nrow, 2))
    envs = [trivial_env(ncol, dtype)]
    svec = trivial_twolayer_boundary(ncol, dtype)
    for i in range(nrow):
        svec = eng.absorb_twolayer(svec, bra_rows[i], ket_rows[i],
                                   option.chi, option.svd, keys[i])
        envs.append(svec)
    return envs


# ---------------------------------------------------------------------------
# One-layer prefix environments (the serving engine's amplitude "prefix")
# ---------------------------------------------------------------------------
#
# The <x|psi> amplitude network is one-layer: site (i, j) is the PEPS
# tensor projected on bit x[i, j].  Its top boundary environments depend
# only on the bits of the rows absorbed so far, so they are shared by
# every query with the same bit *prefix* — the amplitude analog of the
# two-layer ``top_environments`` (which are fully query-independent).
# ``repro.core.serving`` caches these per registered state; the helpers
# here are the uncached reference entry points.

def onelayer_top_environments(rows, option: BMPS, key=None,
                              nrow_total: int = None) -> List[List[jnp.ndarray]]:
    """Boundary-MPS levels of a one-layer (u,l,d,r) grid, top-down.

    Returns ``env`` with ``env[k]`` = the boundary MPS after absorbing rows
    ``0..k`` (length ``len(rows)``; tensors ``(l, d, r)``).  Key
    consumption matches :func:`repro.core.bmps.contract_onelayer` exactly —
    row ``i`` consumes ``keys[i]`` of one ``len == max(nrow, 2)`` split —
    so closing ``env[-1]`` reproduces the per-query contraction bit-for-bit.
    ``nrow_total`` sets the split length when ``rows`` is only the prefix
    of a taller grid (default: ``len(rows)``).
    """
    from repro.core.bmps import _distributed_module, _keys
    if _distributed_module(option) is not None:
        raise TypeError("onelayer prefix environments serve single-device "
                        "BMPS options")
    eng = get_engine(option.engine)
    nrow = nrow_total if nrow_total is not None else len(rows)
    keys = _keys(key, max(nrow, 2))
    svec = [t.reshape(t.shape[1], t.shape[2], t.shape[3]) for t in rows[0]]
    envs = [svec]
    for i in range(1, len(rows)):
        svec = eng.absorb_onelayer(svec, rows[i], option.chi, option.svd,
                                   keys[i])
        envs.append(svec)
    return envs


def onelayer_prefix_environment(state, prefix_bits, option: BMPS,
                                key=None) -> List[jnp.ndarray]:
    """Boundary MPS of rows ``0..len(prefix_bits)-1`` of <x|psi>.

    ``prefix_bits`` is a sequence of per-row bit sequences (typically rows
    ``0..nrow-2`` — everything but the final row).  An empty prefix (a
    one-row state) returns the trivial boundary.  Combined with
    :func:`repro.core.bmps.final_row_amplitudes` this evaluates amplitudes
    for any batch of final-row bits."""
    ncol = state.ncol
    if len(prefix_bits) == 0:
        return [jnp.ones((1, 1, 1), dtype=state.dtype) for _ in range(ncol)]
    rows = [[state.sites[i][j][int(prefix_bits[i][j])] for j in range(ncol)]
            for i in range(len(prefix_bits))]
    return onelayer_top_environments(rows, option, key,
                                     nrow_total=state.nrow)[-1]


# ---------------------------------------------------------------------------
# Strip boundaries (the full update's left/right neighborhood environments)
# ---------------------------------------------------------------------------
#
# A strip is [top_env; bra rows; ket rows; bottom_env] — the same object
# ``expectation.strip_value`` contracts to a scalar.  Here we instead contract
# only the columns left (or right) of a cut, leaving the horizontal bonds at
# the cut open.  The boundary tensor's axes are
#
#     (top_bond, bra_bond_0, ket_bond_0, ..., bra_bond_{n-1}, ket_bond_{n-1},
#      bottom_bond)
#
# for an n-row strip.  Combined with the cached ``row_environments`` these
# give the two-site neighborhood environment of any lattice bond with one
# short column sweep — no full-network recontraction per bond.

def _absorb_strip_column(v, top_t, bra_ts, ket_ts, bot_t, from_left: bool):
    """Absorb one strip column into a boundary tensor ``v``.

    ``v`` holds the open bonds at the current cut (facing the column);
    returns the boundary at the next cut.  All contractions run through the
    planner's path cache (one cache entry per shape class, shared across
    columns/sites/sweeps).
    """
    n = len(bra_ts)
    counter = itertools.count(1)
    fresh = lambda: next(counter)
    v_labels = [fresh() for _ in range(2 * n + 2)]
    args = [v, v_labels]
    t_new = fresh()
    up_bra, up_ket = fresh(), fresh()
    top_lab = ([v_labels[0], up_bra, up_ket, t_new] if from_left else
               [t_new, up_bra, up_ket, v_labels[0]])
    args += [top_t, top_lab]
    out = [t_new]
    for r in range(n):
        p = fresh()
        d_bra, d_ket = fresh(), fresh()
        n_bra, n_ket = fresh(), fresh()
        if from_left:
            args += [bra_ts[r].conj(), [p, up_bra, v_labels[1 + 2 * r], d_bra, n_bra]]
            args += [ket_ts[r], [p, up_ket, v_labels[2 + 2 * r], d_ket, n_ket]]
        else:
            args += [bra_ts[r].conj(), [p, up_bra, n_bra, d_bra, v_labels[1 + 2 * r]]]
            args += [ket_ts[r], [p, up_ket, n_ket, d_ket, v_labels[2 + 2 * r]]]
        out += [n_bra, n_ket]
        up_bra, up_ket = d_bra, d_ket
    b_new = fresh()
    bot_lab = ([v_labels[-1], up_bra, up_ket, b_new] if from_left else
               [b_new, up_bra, up_ket, v_labels[-1]])
    args += [bot_t, bot_lab]
    out.append(b_new)
    args.append(out)
    return planner.int_einsum(*args)


def strip_boundary(top_env, bottom_env, bra_rows, ket_rows, cut: int,
                   from_left: bool):
    """Boundary tensor of a strip at column ``cut``.

    ``from_left=True`` contracts columns ``[0, cut)`` (open bonds face right);
    ``from_left=False`` contracts columns ``[cut, ncol)`` (open bonds face
    left).  Strips are at most two rows high in practice, so the boundary
    stays exact (no truncation) and polynomial."""
    n = len(bra_rows)
    ncol = len(top_env)
    dtype = top_env[0].dtype
    v = jnp.ones((1,) * (2 * n + 2), dtype=dtype)
    cols = range(cut) if from_left else range(ncol - 1, cut - 1, -1)
    for c in cols:
        v = _absorb_strip_column(v, top_env[c],
                                 [row[c] for row in bra_rows],
                                 [row[c] for row in ket_rows],
                                 bottom_env[c], from_left)
    return v


def row_environments(state, option: BMPS, key=None) -> Tuple[List, List]:
    """(top, bottom) environments of the <psi|psi> network of a PEPS.

    * ``top[i]``    covers rows ``0..i-1``       (len nrow+1, ``top[0]`` trivial)
    * ``bottom[i]`` covers rows ``i+1..nrow-1``  (len nrow,  ``bottom[nrow-1]`` trivial)

    This costs two full two-layer sweeps; every local-term expectation after
    that is a strip contraction (the paper's caching strategy)."""
    if key is None:
        key = jax.random.PRNGKey(13)
    k1, k2 = jax.random.split(key)
    nrow = state.nrow
    top = top_environments(state.sites, state.sites, option, k1)
    flipped = top_environments(_flip_rows(state.sites), _flip_rows(state.sites),
                               option, k2)
    bottom = [flipped[nrow - 1 - i] for i in range(nrow)]
    return top, bottom
