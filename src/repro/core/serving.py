"""Batched PEPS query serving: amplitudes and observables as a service.

The RQC amplitude workload (paper Section VI) is high-traffic by nature:
millions of ``<x|psi>`` and ``<psi|O|psi>`` queries against a small set of
hot PEPS states, where most of the boundary-MPS sweep is query-independent.
This module turns the repo's contraction stack into a query engine:

* **Environment prefix cache** — per registered state, an LRU-bounded
  cache of one-layer boundary environments keyed by the query's *bit
  prefix* (the bits of the rows absorbed so far).  Queries sharing a
  prefix share the whole sweep; because the final row's dangling bonds
  are dim 1, its absorption is rank-lossless and the per-query work
  collapses to one exact transfer-matrix close
  (:func:`repro.core.bmps.final_row_amplitudes` — see the derivation
  there).  Observable queries use the fully query-independent
  :func:`repro.core.environments.row_environments` as their prefix: two
  sweeps per state, then one strip contraction per term
  (:func:`repro.core.expectation.expectation_from_envs`).
* **Batched final-row contraction** — amplitude requests that share a
  state and prefix are closed in one batched, jit-compiled call.  Batches
  are padded up to a fixed ladder of bucket sizes so the planner's
  fused-executable cache (:func:`repro.core.planner.fused_fn`, tag
  ``"serve_close"``) stays warm: every bucket size compiles once per
  state-shape signature and then replays.
* **Request queue + dispatcher** — a thread-safe submit/await front end
  (:class:`concurrent.futures.Future` results) with a micro-batching
  window: the dispatcher drains the queue for up to ``window_ms`` (or
  ``max_batch`` requests), groups by state, and executes.  All JAX work
  runs in the dispatcher thread (or the calling thread for the
  synchronous entry points) under one engine lock — client threads only
  enqueue, so arrival order never changes any result.

Cache lifecycle rules (tested in ``tests/test_serving.py``):

* ``register_state`` with an existing name **invalidates** that state's
  cached environments immediately — a served query that starts after
  ``register_state`` returns always sees the new tensors (stale-env
  serving is a silent-wrong-answer bug, so this is load-bearing).
* At most ``max_states`` registered states keep materialized caches; the
  least recently *queried* state's environments are dropped when the
  budget is exceeded.  The state itself stays registered — the next query
  re-materializes its environments (a cache miss, never an error).
* Eviction only unlinks cache entries; an in-flight batch holds direct
  references to the environments it reads, so eviction can never corrupt
  a result.

See docs/serving.md for the full contract and ``launch/serve.py`` for the
CLI server; throughput/latency baselines are pinned by
``benchmarks/bench_serving.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import queue as _queuelib
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bmps import BMPS, _distributed_module, _keys, \
    final_row_amplitudes
from repro.core.engines import get_engine
from repro.core.environments import row_environments
from repro.core.expectation import DEFAULT_EXPECTATION_KEY_SEED, \
    expectation_from_envs
from repro.core.observable import Observable

#: Default ladder of amplitude batch sizes.  A batch of B queries is
#: executed in chunks: full chunks of the largest bucket, then the
#: smallest bucket that fits the remainder (padded).  Each bucket size
#: jit-compiles the batched close once per state-shape signature.
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


class LRUCache:
    """Ordered-dict LRU with hit/miss/eviction counters.

    Not internally locked: the serving engine serializes all access under
    its own lock.  ``get`` counts a hit or miss; ``peek`` does neither
    (used for ancestor-prefix probes, so the counters reflect one lookup
    per query group and stay reconcilable against a query log)."""

    def __init__(self, max_entries: int):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self._d: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self):
        return len(self._d)

    def get(self, key):
        try:
            val = self._d[key]
        except KeyError:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return val

    def peek(self, key):
        val = self._d.get(key)
        if val is not None:
            self._d.move_to_end(key)
        return val

    def put(self, key, value):
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)
            self.evictions += 1

    def clear(self):
        self._d.clear()

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._d)}


@dataclasses.dataclass
class _StateEntry:
    """A registered state plus its derived, evictable caches."""
    name: str
    state: object
    option: BMPS
    amp_keys: list                 # per-row keys, matching contract_onelayer
    env_key: object                # row_environments key (observable path)
    prefix: LRUCache               # bit-prefix tuple -> boundary MPS
    version: int
    obs_envs: Optional[tuple] = None   # cached (top, bottom) or None
    obs_env_builds: int = 0
    obs_env_hits: int = 0


@dataclasses.dataclass
class _Request:
    kind: str                      # "amplitude" | "expectation"
    name: str
    payload: object                # (nrow, ncol) int bits / Observable
    future: Future
    submitted: float


_SHUTDOWN = object()


class ServingEngine:
    """Batched PEPS query engine with an environment prefix cache.

    Parameters
    ----------
    max_states:    how many registered states keep materialized caches
                   (LRU on last query; see module docstring).
    max_prefixes:  per-state bound on cached bit-prefix environments.
    bucket_sizes:  amplitude batch-size ladder (sorted ascending).
    window_ms:     micro-batching window of the dispatcher: after the
                   first request is dequeued, keep draining for this long
                   (or until ``max_batch``) before executing.
    max_batch:     upper bound on requests per dispatch cycle.
    start:         start the dispatcher thread immediately.  With
                   ``start=False`` the synchronous entry points still work
                   (they compute in the calling thread); ``submit_*``
                   requires the dispatcher and will start it lazily.

    The synchronous entry points (:meth:`amplitude`, :meth:`amplitude_batch`,
    :meth:`expectation`) and the dispatcher share one compute path and one
    lock, so values never depend on which path served them.
    """

    def __init__(self, max_states: int = 4, max_prefixes: int = 128,
                 bucket_sizes: Sequence[int] = DEFAULT_BUCKETS,
                 window_ms: float = 2.0, max_batch: int = 256,
                 start: bool = True):
        if max_states < 1:
            raise ValueError("max_states must be >= 1")
        self.max_states = max_states
        self.max_prefixes = max_prefixes
        self.bucket_sizes = tuple(sorted(set(int(b) for b in bucket_sizes)))
        if not self.bucket_sizes or self.bucket_sizes[0] < 1:
            raise ValueError("bucket_sizes must be positive")
        self.window_ms = float(window_ms)
        self.max_batch = int(max_batch)
        self._states: Dict[str, _StateEntry] = {}
        self._hot: "collections.OrderedDict" = collections.OrderedDict()
        self._lock = threading.RLock()
        self._queue: "_queuelib.Queue" = _queuelib.Queue()
        self._counters = collections.Counter()
        self._dispatcher: Optional[threading.Thread] = None
        self._closed = False
        if start:
            self._ensure_dispatcher()

    # -- lifecycle ----------------------------------------------------------

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        """Stop the dispatcher (idempotent).  Pending requests drain first."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            t = self._dispatcher
        if t is not None:
            self._queue.put(_SHUTDOWN)
            t.join()
        with self._lock:
            self._dispatcher = None

    def _ensure_dispatcher(self):
        with self._lock:
            if self._closed:
                raise RuntimeError("ServingEngine is closed")
            if self._dispatcher is None or not self._dispatcher.is_alive():
                self._dispatcher = threading.Thread(
                    target=self._dispatch_loop, name="peps-serving-dispatch",
                    daemon=True)
                self._dispatcher.start()

    # -- registration -------------------------------------------------------

    def register_state(self, name: str, state, option: BMPS, key=None,
                       env_key=None) -> None:
        """Register (or replace) a servable state.

        ``option`` must be a single-device :class:`~repro.core.bmps.BMPS`.
        ``key`` seeds the amplitude row keys exactly like
        ``bmps.amplitude(..., key=...)`` (default ``None`` — the same
        default split); ``env_key`` seeds the observable row environments
        (default: :func:`repro.core.expectation.expectation`'s default).
        Re-registering a name **replaces the state and invalidates every
        cached environment derived from it**; queries executing after this
        call returns are served from the new tensors.
        """
        if not isinstance(option, BMPS) or _distributed_module(option) is not None:
            raise TypeError(
                f"serving requires a single-device BMPS option, got "
                f"{type(option).__name__}")
        get_engine(option.engine)  # fail fast
        if env_key is None:
            env_key = jax.random.PRNGKey(DEFAULT_EXPECTATION_KEY_SEED)
        amp_keys = list(_keys(key, max(state.nrow, 2)))
        with self._lock:
            if self._closed:
                raise RuntimeError("ServingEngine is closed")
            old = self._states.get(name)
            version = old.version + 1 if old is not None else 0
            self._states[name] = _StateEntry(
                name=name, state=state, option=option, amp_keys=amp_keys,
                env_key=env_key, prefix=LRUCache(self.max_prefixes),
                version=version)
            # the new entry starts cold: whatever budget slot the old
            # version held is released (its envs are unreachable now).
            self._hot.pop(name, None)
            if old is not None:
                self._counters["invalidations"] += 1

    def unregister(self, name: str) -> None:
        with self._lock:
            self._states.pop(name)  # KeyError propagates: caller bug
            self._hot.pop(name, None)

    def registered(self) -> List[str]:
        with self._lock:
            return list(self._states)

    # -- submission (thread-safe; any thread) -------------------------------

    def submit_amplitude(self, name: str, bits) -> Future:
        """Enqueue one <bits|psi> query; resolves to a complex scalar."""
        self._ensure_dispatcher()
        bits = np.asarray(bits, dtype=np.int64)
        fut: Future = Future()
        self._queue.put(_Request("amplitude", name, bits, fut,
                                 time.monotonic()))
        return fut

    def submit_expectation(self, name: str, obs: Observable) -> Future:
        """Enqueue one <psi|O|psi>/<psi|psi> query."""
        self._ensure_dispatcher()
        fut: Future = Future()
        self._queue.put(_Request("expectation", name, obs, fut,
                                 time.monotonic()))
        return fut

    # -- synchronous entry points ------------------------------------------

    def amplitude(self, name: str, bits) -> jnp.ndarray:
        return self.amplitude_batch(name, [bits])[0]

    def amplitude_batch(self, name: str, bits_batch) -> jnp.ndarray:
        """Serve a whole amplitude batch in the calling thread.

        Same cache, bucketing and compiled closes as the queued path —
        benchmarks and bulk clients (the chi sweep of
        ``examples/rqc_amplitude.py``) call this to skip queue latency."""
        with self._lock:
            entry = self._entry(name)
            bits_arr = self._check_bits(entry, np.asarray(bits_batch,
                                                          dtype=np.int64))
            return self._execute_amplitudes(entry, bits_arr)

    def expectation(self, name: str, obs: Observable) -> jnp.ndarray:
        with self._lock:
            entry = self._entry(name)
            return self._execute_expectation(entry, obs)

    # -- stats --------------------------------------------------------------

    def stats(self) -> Dict:
        """Counters + per-state cache stats (a consistent snapshot)."""
        with self._lock:
            out = dict(self._counters)
            out.setdefault("queries_amplitude", 0)
            out.setdefault("queries_expectation", 0)
            out.setdefault("batches", 0)
            out.setdefault("rows_absorbed", 0)
            out.setdefault("state_evictions", 0)
            out.setdefault("invalidations", 0)
            out.setdefault("padded_queries", 0)
            per_state = {}
            for name, entry in self._states.items():
                st = {f"prefix_{k}": v for k, v in entry.prefix.stats().items()}
                st["obs_env_builds"] = entry.obs_env_builds
                st["obs_env_hits"] = entry.obs_env_hits
                st["version"] = entry.version
                st["materialized"] = name in self._hot
                per_state[name] = st
            out["per_state"] = per_state
            out["states"] = len(self._states)
            return out

    # -- internals ----------------------------------------------------------

    def _entry(self, name: str) -> _StateEntry:
        try:
            return self._states[name]
        except KeyError:
            raise KeyError(
                f"state {name!r} is not registered (have "
                f"{sorted(self._states)})") from None

    @staticmethod
    def _check_single(entry: _StateEntry, bits_arr: np.ndarray) -> np.ndarray:
        """One query's bits -> (nrow, ncol); flat or grid layout accepted."""
        n = entry.state.nrow * entry.state.ncol
        if bits_arr.size != n:
            raise ValueError(
                f"bits shape {bits_arr.shape} does not match the "
                f"{entry.state.nrow}x{entry.state.ncol} grid of "
                f"{entry.name!r}")
        return bits_arr.reshape(entry.state.nrow, entry.state.ncol)

    @staticmethod
    def _check_bits(entry: _StateEntry, bits_arr: np.ndarray) -> np.ndarray:
        """A batch of queries -> (B, nrow, ncol).

        Accepts ``(B, nrow, ncol)``, ``(B, nrow*ncol)`` or a single query
        (``(nrow, ncol)`` / ``(nrow*ncol,)`` — returned with ``B == 1``).
        A 2-D array whose total size is one grid is always read as a
        single query, never as a batch of flat one-row queries."""
        n = entry.state.nrow * entry.state.ncol
        if bits_arr.ndim == 1 or bits_arr.size == n:
            return ServingEngine._check_single(entry, bits_arr)[None]
        B = bits_arr.shape[0]
        if bits_arr.size != B * n:
            raise ValueError(
                f"bits batch shape {bits_arr.shape} does not match the "
                f"{entry.state.nrow}x{entry.state.ncol} grid of "
                f"{entry.name!r}")
        return bits_arr.reshape(B, entry.state.nrow, entry.state.ncol)

    def _touch(self, entry: _StateEntry) -> None:
        """Mark a state's caches as materialized + recently used (LRU).

        Evicts the least-recently-queried other state's environments when
        more than ``max_states`` states hold materialized caches."""
        self._hot[entry.name] = True
        self._hot.move_to_end(entry.name)
        while len(self._hot) > self.max_states:
            victim_name, _ = self._hot.popitem(last=False)
            victim = self._states.get(victim_name)
            if victim is not None:
                victim.prefix.clear()
                victim.obs_envs = None
                self._counters["state_evictions"] += 1

    def _prefix_env(self, entry: _StateEntry, prefix: tuple):
        """Boundary MPS for a bit prefix, via the LRU cache.

        One counted lookup per call (the full prefix); ancestor probes and
        intermediate-level inserts are uncounted, so stats reconcile as
        one hit-or-miss per served query group."""
        state, option = entry.state, entry.option
        ncol = state.ncol
        if len(prefix) == 0:  # one-row state: trivial boundary above row 0
            return [jnp.ones((1, 1, 1), dtype=state.dtype)
                    for _ in range(ncol)]
        env = entry.prefix.get(prefix)
        if env is not None:
            return env
        depth = len(prefix)
        k = depth - 1
        env = None
        while k >= 1:
            env = entry.prefix.peek(prefix[:k])
            if env is not None:
                break
            k -= 1
        if env is None:
            k = 1
            row0 = [state.sites[0][j][int(prefix[0][j])] for j in range(ncol)]
            env = [t.reshape(t.shape[1], t.shape[2], t.shape[3]) for t in row0]
            entry.prefix.put(prefix[:1], env)
        eng = get_engine(option.engine)
        while k < depth:
            row = [state.sites[k][j][int(prefix[k][j])] for j in range(ncol)]
            env = eng.absorb_onelayer(env, row, option.chi, option.svd,
                                      entry.amp_keys[k])
            k += 1
            entry.prefix.put(prefix[:k], env)
            self._counters["rows_absorbed"] += 1
        return env

    def _chunks(self, n: int) -> List[int]:
        """Split a group of n queries into padded bucket-sized chunks."""
        out = []
        big = self.bucket_sizes[-1]
        while n >= big:
            out.append(big)
            n -= big
        if n > 0:
            out.append(next(b for b in self.bucket_sizes if b >= n))
        return out

    def _execute_amplitudes(self, entry: _StateEntry,
                            bits_arr: np.ndarray) -> jnp.ndarray:
        """Batched amplitudes for one state (caller holds the lock)."""
        self._touch(entry)
        B = bits_arr.shape[0]
        self._counters["queries_amplitude"] += B
        groups: Dict[tuple, List[int]] = {}
        for idx in range(B):
            prefix = tuple(tuple(int(b) for b in row)
                           for row in bits_arr[idx][:-1])
            groups.setdefault(prefix, []).append(idx)
        vals: List = [None] * B
        row_sites = entry.state.sites[-1]
        for prefix, idxs in groups.items():
            env = self._prefix_env(entry, prefix)
            final_bits = bits_arr[idxs, -1, :].astype(np.int32)
            done = 0
            for bucket in self._chunks(len(idxs)):
                take = min(bucket, len(idxs) - done)
                chunk = final_bits[done:done + take]
                if take < bucket:  # pad by repeating the first query
                    pad = np.broadcast_to(chunk[0], (bucket - take,
                                                     chunk.shape[1]))
                    chunk = np.concatenate([chunk, pad], axis=0)
                    self._counters["padded_queries"] += bucket - take
                out = final_row_amplitudes(env, row_sites,
                                           jnp.asarray(chunk),
                                           entry.state.log_scale)
                for k in range(take):
                    vals[idxs[done + k]] = out[k]
                done += take
        self._counters["batches"] += 1
        return jnp.stack(vals)

    def _obs_envs(self, entry: _StateEntry):
        if entry.obs_envs is None:
            entry.obs_envs = row_environments(entry.state, entry.option,
                                              entry.env_key)
            entry.obs_env_builds += 1
        else:
            entry.obs_env_hits += 1
        return entry.obs_envs

    def _execute_expectation(self, entry: _StateEntry,
                             obs: Observable) -> jnp.ndarray:
        self._touch(entry)
        self._counters["queries_expectation"] += 1
        top, bottom = self._obs_envs(entry)
        return expectation_from_envs(entry.state, obs, top, bottom)

    # -- dispatcher ---------------------------------------------------------

    def _dispatch_loop(self):
        while True:
            try:
                first = self._queue.get(timeout=0.1)
            except _queuelib.Empty:
                if self._closed:
                    return
                continue
            if first is _SHUTDOWN:
                # keep draining: requests enqueued before close() resolve.
                if self._queue.empty():
                    return
                self._queue.put(_SHUTDOWN)
                continue
            batch = [first]
            deadline = time.monotonic() + self.window_ms / 1e3
            while len(batch) < self.max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except _queuelib.Empty:
                    break
                if nxt is _SHUTDOWN:
                    self._queue.put(_SHUTDOWN)
                    break
                batch.append(nxt)
            self._run_batch(batch)

    def _run_batch(self, batch: List[_Request]):
        """Group a dispatch cycle by (state, kind) and execute under the lock.

        The state entry is resolved *here*, after the lock is taken — a
        ``register_state`` that completed before this point is always
        honored (cache invalidation rule), and one that raced the cycle
        serializes against it."""
        amp_groups: Dict[str, List[_Request]] = collections.OrderedDict()
        exp_reqs: List[_Request] = []
        for req in batch:
            if req.kind == "amplitude":
                amp_groups.setdefault(req.name, []).append(req)
            else:
                exp_reqs.append(req)
        with self._lock:
            for name, reqs in amp_groups.items():
                try:
                    entry = self._entry(name)
                    bits_arr = np.stack([
                        self._check_single(entry, r.payload) for r in reqs])
                    vals = self._execute_amplitudes(entry, bits_arr)
                except Exception as e:  # noqa: BLE001 — delivered per-future
                    for r in reqs:
                        if not r.future.cancelled():
                            r.future.set_exception(e)
                    continue
                for r, v in zip(reqs, vals):
                    if not r.future.cancelled():
                        r.future.set_result(v)
            for req in exp_reqs:
                try:
                    entry = self._entry(req.name)
                    val = self._execute_expectation(entry, req.payload)
                except Exception as e:  # noqa: BLE001
                    if not req.future.cancelled():
                        req.future.set_exception(e)
                    continue
                if not req.future.cancelled():
                    req.future.set_result(val)
