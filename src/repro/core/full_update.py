"""Full (environment-aware) two-site updates for PEPS imaginary time evolution.

The QR simple update (``peps.QRUpdate``, paper Alg. 1) truncates the bond of
a two-site gate application as if the rest of the network were the identity.
The *full update* of Lubasch, Cirac & Bañuls (arXiv:1405.3259) — shown by
Liu et al. (arXiv:1908.09359) to be decisively more accurate for finite
PEPS — instead truncates in the metric of the two-site neighborhood
environment: the bond is optimized so that the *physical state* changes as
little as possible, not the local tensors.

Pipeline per bond (horizontal or vertical, no transpose trick — the
environment is orientation-specific):

1. **Reduced split** — Gram-QR both site tensors (paper Alg. 5) so only the
   small reduced tensors ``Ra``/``Rb`` carrying (physical, bond) participate
   in the optimization; the isometries ``Qa``/``Qb`` stay fixed.
2. **Neighborhood environment** — contract the cached top/bottom row
   environments (``environments.row_environments``) with a left/right strip
   boundary (``environments.strip_boundary``) and the ``Q`` isometries into
   the bond environment ``E`` over the bra/ket reduced bonds.
3. **Gauge / positive fix** — hermitize ``E`` and clamp its spectrum to be
   positive semi-definite (it is a fidelity metric; truncated boundary
   contractions break exact Hermiticity), then normalize by its largest
   eigenvalue.
4. **ALS** — seed the truncated pair with the existing einsumsvd split
   (``DirectSVD``/``RandomizedSVD``) of the gate-applied reduced network,
   then run a fixed number of alternating least-squares sweeps minimizing
   ``||theta - a.b||_E`` (regularized normal equations, static shapes).
5. **Reabsorb** the ``Q`` isometries and write the sites back.

Steps 3–4 are jit-fused into one compiled executable per network signature
via :func:`planner.fused_fn`; the environment/strip contractions of step 2
run through the planner's path cache.  Across sites and Trotter steps the
evolution loop replays compiled code, the same architecture as the fused
rSVD engine.

The ALS objective also yields the **bond truncation fidelity**

    F = |<ab|E|theta>|^2 / (<ab|E|ab> <theta|E|theta>)

— an O(1)-cost estimate of how faithfully the truncation preserved the
global state, logged per bond and surfaced in ``ite.ITEResult``.
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.core.bmps import BMPS
from repro.core.einsumsvd import einsumsvd
from repro.core.environments import row_environments, strip_boundary
from repro.core.orthogonalize import gram_qr


# ---------------------------------------------------------------------------
# Fidelity log (drained by ite.ite_run; skipped under jit/vmap tracing)
# ---------------------------------------------------------------------------

_FIDELITY_LOG: List = []
# Callers that never drain (e.g. eager sharding dry-runs) must not leak: the
# log keeps only the most recent entries.  ite_run drains once per
# measurement window, far below this cap for any sane measure_every.
_FIDELITY_LOG_MAX = 4096


def drain_fidelities() -> List[float]:
    """Pop all bond fidelities logged since the last drain.

    Entries are stored as device scalars and only synced to host here, so
    logging a bond never blocks JAX's async dispatch."""
    out = [float(jnp.real(f)) for f in _FIDELITY_LOG]
    _FIDELITY_LOG.clear()
    return out


def _log_fidelity(f) -> None:
    if isinstance(f, jax.core.Tracer):  # vmapped/jitted caller: nothing to log
        return
    if len(_FIDELITY_LOG) >= _FIDELITY_LOG_MAX:
        del _FIDELITY_LOG[0]
    _FIDELITY_LOG.append(f)


def pending_fidelities() -> List[float]:
    """The undrained window, synced to host, WITHOUT clearing it.

    Checkpointing uses this: a mid-window snapshot must carry the bonds
    logged since the last measurement so a resumed run drains the same
    window the uninterrupted run would have."""
    return [float(jnp.real(f)) for f in _FIDELITY_LOG]


def restore_fidelities(values) -> None:
    """Replace the undrained window (resume path; pairs with
    :func:`pending_fidelities`)."""
    _FIDELITY_LOG.clear()
    _FIDELITY_LOG.extend(float(v) for v in values)


# ---------------------------------------------------------------------------
# Environment extraction
# ---------------------------------------------------------------------------

def env_option(update) -> BMPS:
    """The boundary-MPS option used for this update's row environments.

    ``FullUpdate.env_contract``, when set, wins — that is the seam through
    which distributed (column-sharded) environment sweeps enter full-update
    ITE; see :mod:`repro.core.distributed`."""
    if getattr(update, "env_contract", None) is not None:
        return update.env_contract
    return BMPS(update.chi, update.env_svd)


def envs_compatible(state, s0: Tuple[int, int], s1: Tuple[int, int],
                    envs) -> bool:
    """Do the cached row environments still fit the current bond dimensions?

    Environments go stale in two ways.  Value-staleness (tensors updated
    since the sweep) is the documented ``env_refresh_every`` trade-off.
    *Shape*-staleness — a bond has grown since the sweep, typical during the
    first ITE steps from a product state and along SWAP chains — is not
    survivable: einsum would either silently broadcast the environment's
    dim-1 axes (a meaningless metric) or fail on a dim mismatch.  Callers
    must refresh when this returns False."""
    (i0, j0), (i1, j1) = s0, s1
    top, bottom = envs
    rows = [i0] if i0 == i1 else [min(i0, i1), max(i0, i1)]
    t_env, b_env = top[rows[0]], bottom[rows[-1]]
    for c in range(state.ncol):
        u = state.sites[rows[0]][c].shape[1]
        d = state.sites[rows[-1]][c].shape[3]
        if t_env[c].shape[1] != u or t_env[c].shape[2] != u:
            return False
        if b_env[c].shape[1] != d or b_env[c].shape[2] != d:
            return False
    return True


def bond_environment(state, s0: Tuple[int, int], s1: Tuple[int, int],
                     qa, qb, envs) -> jnp.ndarray:
    """Neighborhood environment of the bond ``s0 -> s1`` (right or down).

    ``qa``/``qb`` are the reduced-split isometries of the two sites (their
    last two axes are the open reduced-bond pair).  ``envs`` is the
    ``(top, bottom)`` pair from :func:`environments.row_environments`.

    Returns ``E`` with eight axes: the bra reduced-bond pairs of a and b,
    then the ket pairs — ``(A1,A2,C1,C2,a1,a2,c1,c2)``.
    """
    (i0, j0), (i1, j1) = s0, s1
    top, bottom = envs
    sites = state.sites
    if i0 == i1:                                         # horizontal bond
        i, j = i0, j0
        t_env, b_env = top[i], bottom[i]
        bra = [sites[i]]
        left = strip_boundary(t_env, b_env, bra, bra, j, from_left=True)
        right = strip_boundary(t_env, b_env, bra, bra, j + 2, from_left=False)
        # labels: open bra pair (11,12 / 13,14), open ket pair (15,16 / 17,18)
        return planner.int_einsum(
            left, [1, 2, 3, 4],                          # (t, bra_l, ket_l, bt)
            t_env[j], [1, 5, 6, 7],
            t_env[j + 1], [7, 8, 9, 10],
            qa.conj(), [5, 2, 20, 11, 12],               # (u, l, d, A1, A2)
            qa, [6, 3, 21, 15, 16],
            qb.conj(), [8, 22, 24, 13, 14],              # (U, D, R, C1, C2)
            qb, [9, 23, 25, 17, 18],
            b_env[j], [4, 20, 21, 26],
            b_env[j + 1], [26, 22, 23, 27],
            right, [10, 24, 25, 27],
            [11, 12, 13, 14, 15, 16, 17, 18])
    # vertical bond: two-row strip, rows i0 and i0+1
    i, j = i0, j0
    t_env, b_env = top[i], bottom[i + 1]
    bra = [sites[i], sites[i + 1]]
    left = strip_boundary(t_env, b_env, bra, bra, j, from_left=True)
    right = strip_boundary(t_env, b_env, bra, bra, j + 1, from_left=False)
    return planner.int_einsum(
        left, [1, 2, 3, 4, 5, 6],        # (t, braA_l, ketA_l, braB_l, ketB_l, bt)
        t_env[j], [1, 7, 8, 9],
        qa.conj(), [7, 2, 20, 11, 12],                   # (u, l, r, A1, A2)
        qa, [8, 3, 21, 15, 16],
        qb.conj(), [4, 22, 24, 13, 14],                  # (l, d, r, C1, C2)
        qb, [5, 23, 25, 17, 18],
        b_env[j], [6, 22, 23, 27],
        right, [9, 20, 21, 24, 25, 27],
        [11, 12, 13, 14, 15, 16, 17, 18])


def positive_fix(env: jnp.ndarray) -> jnp.ndarray:
    """Hermitize + clamp the bond environment to PSD, normalized to ||.||=1.

    ``env`` is the 8-axis tensor of :func:`bond_environment`; the matrix view
    groups (bra pairs | ket pairs).  Truncated (and randomized) boundary
    contractions leave E only approximately Hermitian/positive; using it
    raw can steer the ALS toward unphysical solutions (Lubasch et al.,
    Section IV-B2)."""
    sh = env.shape
    d = sh[0] * sh[1] * sh[2] * sh[3]
    m = env.reshape(d, d)
    m = 0.5 * (m + m.conj().T)
    w, v = jnp.linalg.eigh(m)
    w = jnp.maximum(w.real, 0.0)
    scale = jnp.maximum(jnp.max(w), jnp.finfo(env.real.dtype).tiny)
    m = (v * (w / scale)) @ v.conj().T
    return m.reshape(sh)


# ---------------------------------------------------------------------------
# ALS bond optimization (jit-fused per signature)
# ---------------------------------------------------------------------------

def _env_overlap(env, p, q):
    """<p|E|q> for pair tensors (a,b,x,y,c,d) over the metric E."""
    return planner.cached_einsum("ABxyCD,ABCDabcd,abxycd->",
                                 p.conj(), env, q)


def _pair(a, b):
    """Merge reduced factors a:(a,b,x,m), b:(m,y,c,d) into (a,b,x,y,c,d)."""
    return planner.cached_einsum("abxm,mycd->abxycd", a, b)


def _regularized_solve(m, rhs, eps):
    d = m.shape[0]
    reg = eps * (jnp.trace(m).real / d + jnp.finfo(m.real.dtype).tiny)
    return jnp.linalg.solve(m + reg * jnp.eye(d, dtype=m.dtype), rhs)


def _als_sweep(env, theta, a, b, eps):
    """One alternating sweep: re-solve a given b, then b given a."""
    # --- a given b:  M_a a = S_a, block-diagonal in the physical index x
    ma = planner.cached_einsum("MyCD,ABCDabcd,mycd->ABMabm",
                               b.conj(), env, b)
    sa = planner.cached_einsum("MyCD,ABCDabcd,abxycd->ABMx",
                               b.conj(), env, theta)
    da, db_, dm = a.shape[0], a.shape[1], a.shape[3]
    dx = a.shape[2]
    sol = _regularized_solve(ma.reshape(da * db_ * dm, da * db_ * dm),
                             sa.reshape(da * db_ * dm, dx), eps)
    a = jnp.moveaxis(sol.reshape(da, db_, dm, dx), 3, 2)
    # --- b given a
    mb = planner.cached_einsum("ABxM,ABCDabcd,abxm->MCDmcd",
                               a.conj(), env, a)
    sb = planner.cached_einsum("ABxM,ABCDabcd,abxycd->MCDy",
                               a.conj(), env, theta)
    dc, dd = b.shape[2], b.shape[3]
    dy = b.shape[1]
    sol = _regularized_solve(mb.reshape(dm * dc * dd, dm * dc * dd),
                             sb.reshape(dm * dc * dd, dy), eps)
    b = jnp.moveaxis(sol.reshape(dm, dc, dd, dy), 3, 1)
    return a, b


def _optimize_bond(env, theta, a0, b0, *, n_iter: int, eps: float,
                   positive: bool):
    """Positive-fix the environment, run ALS, return (a, b, fidelity)."""
    if positive:
        env = positive_fix(env)
    else:
        sh = env.shape
        d = sh[0] * sh[1] * sh[2] * sh[3]
        m = env.reshape(d, d)
        env = (0.5 * (m + m.conj().T)).reshape(sh)
    a, b = a0, b0
    for _ in range(n_iter):
        a, b = _als_sweep(env, theta, a, b, eps)
    # norm-balance the shared bond (cheap gauge hygiene for long evolutions)
    na = jnp.maximum(jnp.linalg.norm(a), jnp.finfo(a.real.dtype).tiny)
    nb = jnp.maximum(jnp.linalg.norm(b), jnp.finfo(b.real.dtype).tiny)
    g = jnp.sqrt(nb / na)
    a, b = a * g, b / g
    ab = _pair(a, b)
    num = _env_overlap(env, ab, theta)
    d1 = jnp.real(_env_overlap(env, ab, ab))
    d2 = jnp.real(_env_overlap(env, theta, theta))
    fid = jnp.abs(num) ** 2 / jnp.maximum(d1 * d2,
                                          jnp.finfo(a.real.dtype).tiny)
    return a, b, fid


def _fused_optimize(env, theta, a0, b0, update):
    sig = (tuple(env.shape), tuple(theta.shape), tuple(a0.shape),
           tuple(b0.shape), jnp.dtype(env.dtype).name,
           update.als_iters, update.als_eps, update.positive,
           jax.default_backend())
    builder = lambda: jax.jit(partial(_optimize_bond, n_iter=update.als_iters,
                                      eps=update.als_eps,
                                      positive=update.positive))
    return planner.fused_fn("full-update-als", sig, builder)(env, theta, a0, b0)


# ---------------------------------------------------------------------------
# The full update itself
# ---------------------------------------------------------------------------

def _reduced_split(t: jnp.ndarray, axes: Tuple[int, ...]):
    """Gram-QR ``t`` with its axes permuted to ``axes`` (last two = small)."""
    return gram_qr(jnp.transpose(t, axes), 2)


def full_update_bond(state, g, s0: Tuple[int, int], s1: Tuple[int, int],
                     update, key, envs=None):
    """Apply a two-site gate on adjacent sites with the full update.

    ``envs`` is an optional cached ``(top, bottom)`` pair from
    :func:`environments.row_environments`; when omitted it is recomputed
    from the current state (exact cadence, maximum cost).  Returns the new
    state; the bond fidelity is appended to the module log (see
    :func:`drain_fidelities`)."""
    (i0, j0), (i1, j1) = s0, s1
    # canonical orientations: left->right or top->bottom
    if (i0 == i1 and j1 == j0 - 1) or (j0 == j1 and i1 == i0 - 1):
        gt = jnp.transpose(jnp.asarray(g), (1, 0, 3, 2))
        return full_update_bond(state, gt, s1, s0, update, key, envs)
    if not ((i0 == i1 and j1 == j0 + 1) or (j0 == j1 and i1 == i0 + 1)):
        raise ValueError(f"sites {s0}, {s1} are not adjacent")

    g = jnp.asarray(g, dtype=state.dtype)
    key, env_key, seed_key = jax.random.split(key, 3)
    if envs is None or not envs_compatible(state, s0, s1, envs):
        # missing, or shape-stale (a bond grew since the cached sweep —
        # first ITE steps, SWAP chains): recompute from the current state
        envs = row_environments(state, env_option(update), env_key)

    a = state.sites[i0][j0]
    b = state.sites[i1][j1]
    horizontal = i0 == i1
    if horizontal:
        # a:(p,u,l,d,k) bond=r ; b:(q,U,k,D,R) bond=l
        qa, ra = _reduced_split(a, (1, 2, 3, 0, 4))      # qa:(u,l,d,A1,A2)
        qb, rb = _reduced_split(b, (1, 3, 4, 0, 2))      # qb:(U,D,R,C1,C2)
    else:
        # a:(p,u,l,d,r) bond=d ; b:(q,u,l,d,r) bond=u
        qa, ra = _reduced_split(a, (1, 2, 4, 0, 3))      # qa:(u,l,r,A1,A2)
        qb, rb = _reduced_split(b, (2, 3, 4, 0, 1))      # qb:(l,d,r,C1,C2)

    env = bond_environment(state, s0, s1, qa, qb, envs)

    # gate-applied reduced pair and its rSVD/SVD seed (the simple-update
    # answer in the reduced gauge — the ALS starts from it and can only
    # improve in the environment metric)
    theta = planner.cached_einsum("xypq,abpk,cdqk->abxycd", g, ra, rb)
    left, right = einsumsvd(
        update.svd, [g, ra, rb], ["xypq", "abpk", "cdqk"],
        row="xab", col="ycd", rank=update.rank, absorb="both", key=seed_key)
    a0 = jnp.moveaxis(left, 0, 2)                        # (a,b,x,m)
    b0 = right                                           # (m,y,c,d)

    ar, br, fid = _fused_optimize(env, theta, a0, b0, update)

    # Runtime-guard hook: a non-finite ALS result or a truncation fidelity
    # below the configured floor retries the bond once from a deterministic
    # exact-SVD seed (rSVD seeds on ill-conditioned reduced networks are
    # where ALS divergence starts).  NaN after the retry raises a
    # structured GuardExhaustedError; a still-low fidelity is recorded as
    # degraded-but-accepted unless fidelity_strict.  See core/runtime_guard.
    from repro.core import runtime_guard
    guard = runtime_guard.current()
    if guard is not None and not isinstance(fid, jax.core.Tracer):
        cause = runtime_guard.check_bond(guard, ar, br, fid)
        if cause is not None:
            runtime_guard.bond_failure(guard, cause, retried=False,
                                       detail=f"bond {s0}->{s1}")
            from repro.core.einsumsvd import DirectSVD
            left, right = einsumsvd(
                DirectSVD(), [g, ra, rb], ["xypq", "abpk", "cdqk"],
                row="xab", col="ycd", rank=update.rank, absorb="both",
                key=seed_key)
            a0 = jnp.moveaxis(left, 0, 2)
            b0 = right
            ar, br, fid = _fused_optimize(env, theta, a0, b0, update)
            recheck = runtime_guard.check_bond(guard, ar, br, fid)
            if recheck is None:
                runtime_guard.bond_recovered(guard, cause)
            else:
                runtime_guard.bond_failure(
                    guard, recheck, retried=True,
                    detail=f"bond {s0}->{s1} fid={float(jnp.real(fid)):.3e}")

    _log_fidelity(fid)

    if horizontal:
        new_a = planner.cached_einsum("uldab,abxm->xuldm", qa, ar)
        new_b = planner.cached_einsum("UDRcd,mycd->yUmDR", qb, br)
    else:
        new_a = planner.cached_einsum("ulrab,abxm->xulmr", qa, ar)
        new_b = planner.cached_einsum("LDRcd,mycd->ymLDR", qb, br)

    new = state.copy()
    new.sites[i0][j0] = new_a
    new.sites[i1][j1] = new_b
    return new
