"""Quantum circuit generators: random quantum circuits (RQC) and VQE ansatze.

A circuit is a list of ``(gate_ndarray, [flat_site, ...])`` moments applied
in order.  RQC construction follows the paper's Section VI-B protocol
(after Arute et al. 2019): random single-qubit gates from
{sqrt(X), sqrt(Y), sqrt(W)} every layer, and iSWAP on all neighbouring pairs
every four layers — each iSWAP round multiplies the bond dimension by 4,
so 8 layers yield bond dimension 16 under exact evolution.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core import gates as G

Circuit = List[Tuple[np.ndarray, List[int]]]


def _neighbor_pairs(nrow: int, ncol: int) -> List[Tuple[int, int]]:
    pairs = []
    for i in range(nrow):
        for j in range(ncol):
            s = i * ncol + j
            if j + 1 < ncol:
                pairs.append((s, s + 1))
            if i + 1 < nrow:
                pairs.append((s, s + ncol))
    return pairs


def random_circuit(nrow: int, ncol: int, n_layers: int, seed: int = 0,
                   iswap_every: int = 4) -> Circuit:
    """Paper's RQC: per layer a random sqrt-gate on every site; every
    ``iswap_every`` layers, iSWAP on all neighbouring pairs."""
    rng = np.random.default_rng(seed)
    singles = [G.SQRT_X, G.SQRT_Y, G.SQRT_W]
    circuit: Circuit = []
    n = nrow * ncol
    last = -np.ones(n, dtype=int)
    for layer in range(n_layers):
        for s in range(n):
            choices = [k for k in range(3) if k != last[s]]
            k = int(rng.choice(choices))
            last[s] = k
            circuit.append((singles[k], [s]))
        if (layer + 1) % iswap_every == 0:
            for pair in _neighbor_pairs(nrow, ncol):
                circuit.append((G.ISWAP, list(pair)))
    return circuit


def _ry_traced(theta):
    """Ry(theta) as a traceable jnp expression (real 2x2; cast to the state
    dtype downstream — the real->complex injection is differentiable)."""
    import jax.numpy as jnp
    c, s = jnp.cos(theta * 0.5), jnp.sin(theta * 0.5)
    return jnp.stack([jnp.stack([c, -s]), jnp.stack([s, c])])


def vqe_ansatz(nrow: int, ncol: int, thetas: Sequence[float]) -> Circuit:
    """Paper Section VI-D2 ansatz: repeated layers of Ry(theta) on every
    qubit followed by CNOT on all nearest-neighbour pairs.

    ``thetas`` has length n_layers * nrow * ncol.  Accepts a plain sequence
    / numpy array (concrete numpy gates, the historical path) **or** a JAX
    array — including tracers, so ``jax.jit``/``jax.grad``/``jax.vmap`` of
    an energy built on this ansatz trace through the gate angles (see
    :func:`repro.core.vqe.vqe_energy_and_grad`)."""
    import jax
    n = nrow * ncol
    assert len(thetas) % n == 0, "thetas must be a multiple of the qubit count"
    n_layers = len(thetas) // n
    # numpy arrays / lists keep the bit-exact math.cos legacy gates; any
    # jax.Array (tracer or concrete device array) gets traceable jnp gates.
    traced = isinstance(thetas, jax.core.Tracer) or isinstance(thetas, jax.Array)
    circuit: Circuit = []
    idx = 0
    for _ in range(n_layers):
        for s in range(n):
            ry = _ry_traced(thetas[idx]) if traced else G.RY(float(thetas[idx]))
            circuit.append((ry, [s]))
            idx += 1
        for pair in _neighbor_pairs(nrow, ncol):
            circuit.append((G.CX, list(pair)))
    return circuit


def apply_circuit_peps(state, circuit: Circuit, update, key=None):
    """Run a circuit on a PEPS with the given two-site update option."""
    import jax
    from repro.core.peps import apply_operator
    if key is None:
        key = jax.random.PRNGKey(123)
    for g, sites in circuit:
        key, sub = jax.random.split(key)
        state = apply_operator(state, g, sites, update, key=sub)
    return state


def apply_circuit_exact_peps(state, circuit: Circuit):
    """Run a circuit on a PEPS with NO truncation (exact evolution).

    Bond dimensions grow multiplicatively at every two-site gate; use only
    for the small RQC accuracy studies (the paper does the same)."""
    from repro.core.peps import apply_operator, DirectUpdate
    for g, sites in circuit:
        if len(sites) == 1:
            state = apply_operator(state, g, sites)
        else:
            # rank bound = product of the current shared-bond dim and gate rank
            i0, j0 = state.coords(sites[0])
            i1, j1 = state.coords(sites[1])
            if abs(i0 - i1) + abs(j0 - j1) != 1:
                raise ValueError("exact evolution supports adjacent gates only")
            t0 = state.sites[i0][j0]
            # shared bond dim
            if i0 == i1:
                k = t0.shape[4] if j1 > j0 else t0.shape[2]
            else:
                k = t0.shape[3] if i1 > i0 else t0.shape[1]
            state = apply_operator(state, g, sites, DirectUpdate(rank=4 * k))
    return state


def apply_circuit_statevector(vec, circuit: Circuit):
    from repro.core import statevector as sv
    for g, sites in circuit:
        vec = sv.apply_gate(vec, g, sites)
    return vec
