"""Reshape-avoiding orthogonalization via Gram matrices (paper Alg. 5).

On a distributed tensor, matricizing for QR forces a full data redistribution
(Cyclops) / an all-to-all re-layout (GSPMD).  The paper instead forms the
small Gram matrix ``G = A*A`` with a *contraction* over the big modes, which
the backend executes as a GEMM with no reshape of the big operand, then
eigendecomposes G locally and reconstitutes the isometry with one more GEMM.

All functions are jit-safe (static shapes, `eigh` only on the small matrix).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.svd_grad import eigh_reg
from repro.kernels import dispatch as _dispatch

_EPS = {jnp.float32.dtype: 1e-6, jnp.float64.dtype: 1e-13,
        jnp.complex64.dtype: 1e-6, jnp.complex128.dtype: 1e-13,
        jnp.bfloat16.dtype: 1e-3}


def _eps_for(dtype) -> float:
    return _EPS.get(jnp.dtype(dtype), 1e-6)


# --------------------------------------------------------------------------
# Kernel dispatch (tall-skinny hot paths -> Pallas kernels)
# --------------------------------------------------------------------------
#
# Two dispatch sites (registered in repro.kernels.dispatch, which owns the
# shared gate: f32/bf16/c64 only — the kernels accumulate in f32, so
# routing f64 there would silently halve precision — and, in auto mode,
# tall-skinny shapes on a real TPU backend; CPU CI stays dense/exact):
#
#   * "gram"       — G = A^H A of Alg. 5: the streaming-Gram kernel
#     (src/repro/kernels/gram.py), G resident in VMEM while A streams.
#   * "tall_apply" — the reconstitution Q = A P (and the final rSVD
#     projections in core/rsvd.py): the streaming tall-apply kernel
#     (src/repro/kernels/matvec.py), small matrix resident, A streams.
#
# Together the two sites cover every big-operand GEMM of one rSVD power
# iteration.  set_gram_backend/gram_backend/gram_dispatch_stats are the
# PR 1 names, kept as thin aliases of the registry-wide controls; see
# tests/test_planner.py + tests/test_dispatch.py.


def set_gram_backend(mode: str) -> str:
    """Select the kernel backend mode: 'auto' (shape/dtype/backend-gated
    Pallas), 'pallas' (force kernels), or 'dense'.  Returns the previous
    mode.  Alias of ``repro.kernels.dispatch.set_kernel_backend`` (global
    mode), kept for the PR 1 API."""
    if mode not in ("auto", "pallas", "dense"):
        raise ValueError(f"bad gram backend {mode!r}")
    return _dispatch.set_kernel_backend(mode)


def gram_backend() -> str:
    """The currently-selected global kernel backend mode."""
    return _dispatch.kernel_backend()


def gram_dispatch_stats() -> dict:
    """Per-site pallas/dense call counters (all sites, not just gram)."""
    return _dispatch.dispatch_stats()


def reset_gram_dispatch_stats() -> None:
    _dispatch.reset_dispatch_stats()


def _gram_dense(a: jnp.ndarray, big_axes, nbig: int, nsmall: int):
    g = jnp.tensordot(a.conj(), a, axes=(big_axes, big_axes))
    return g.reshape(nsmall, nsmall)


def _gram_pallas(a: jnp.ndarray, big_axes, nbig: int, nsmall: int):
    from repro.kernels.gram import gram, gram_complex
    mat = a.reshape(nbig, nsmall)
    compute = _dispatch.kernel_compute()
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        return gram_complex(mat, compute=compute)
    return gram(mat, compute=compute)


_dispatch.register_kernel(
    "gram", pallas=_gram_pallas, dense=_gram_dense,
    supported=lambda a, big_axes, nbig, nsmall:
        _dispatch.dtype_supported(a.dtype),
    auto=lambda a, big_axes, nbig, nsmall:
        _dispatch.tall_skinny_auto(nbig, nsmall))


def _gram_matrix(a: jnp.ndarray, big_axes: Tuple[int, ...],
                 nbig: int, nsmall: int) -> jnp.ndarray:
    """G = A^H A as an (nsmall, nsmall) matrix, Pallas-dispatched."""
    return _dispatch.dispatch("gram", a, big_axes, nbig, nsmall)


def _tall_project_dense(a: jnp.ndarray, mat: jnp.ndarray, n_small: int):
    small_shape = a.shape[a.ndim - n_small:]
    small_axes = tuple(range(a.ndim - n_small, a.ndim))
    p = mat.reshape(small_shape + (mat.shape[1],))
    return jnp.tensordot(a, p, axes=(small_axes, tuple(range(n_small))))


def _tall_project_pallas(a: jnp.ndarray, mat: jnp.ndarray, n_small: int):
    from repro.kernels.matvec import planar_matmul
    big_shape = a.shape[: a.ndim - n_small]
    nbig = 1
    for s in big_shape:
        nbig *= s
    out = planar_matmul(a.reshape(nbig, mat.shape[0]), mat,
                        compute=_dispatch.kernel_compute())
    return out.reshape(big_shape + (mat.shape[1],))


def _tall_project_nbig(a: jnp.ndarray, n_small: int) -> int:
    nbig = 1
    for s in a.shape[: a.ndim - n_small]:
        nbig *= s
    return nbig


_dispatch.register_kernel(
    "tall_apply", pallas=_tall_project_pallas, dense=_tall_project_dense,
    supported=lambda a, mat, n_small:
        _dispatch.dtype_supported(a.dtype, mat.dtype),
    auto=lambda a, mat, n_small:
        _dispatch.tall_skinny_auto(_tall_project_nbig(a, n_small),
                                   max(mat.shape)))


def tall_project(a: jnp.ndarray, mat: jnp.ndarray,
                 n_small: int) -> jnp.ndarray:
    """Contract ``a``'s trailing ``n_small`` axes with the 2D matrix ``mat``.

    ``mat`` is ``(nsmall, q)`` with ``nsmall`` the product of the trailing
    axes; the result has shape ``big_shape + (q,)``.  This is the streaming
    "apply a small matrix to a tall operand" step of the rSVD chain —
    Pallas-dispatched (site ``"tall_apply"``); the dense path is the exact
    pre-kernel ``tensordot``."""
    return _dispatch.dispatch("tall_apply", a, mat, n_small)


def gram_qr(a: jnp.ndarray, n_small: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """QR-equivalent factorization A = Q @ R via the Gram trick (Alg. 5).

    ``a`` is treated as an operator from its *last* ``n_small`` modes (the
    small space) to its leading modes (the big space).  Returns:

    * ``q`` with the same shape as ``a`` — isometric over the big modes
      (on the row space of A),
    * ``r`` of shape ``small + small`` such that ``a == q . r`` (contraction
      over the last ``n_small`` modes of ``q`` with the first ``n_small`` of
      ``r``).

    No reshape touches the big modes: G = A*A is formed by a contraction, the
    eigendecomposition happens on the small G only.
    """
    big_shape = a.shape[: a.ndim - n_small]
    small_shape = a.shape[a.ndim - n_small:]
    nbig = 1
    for s in big_shape:
        nbig *= s
    nsmall = 1
    for s in small_shape:
        nsmall *= s

    big_axes = tuple(range(a.ndim - n_small))
    # G_{cc'} = sum_big conj(A)_{big,c} A_{big,c'} — contraction, no reshape of A
    # (or the Pallas streaming-Gram kernel when the operand qualifies).
    g_mat = _gram_matrix(a, big_axes, nbig, nsmall)  # small, local
    # eigh_reg == jnp.linalg.eigh forward; its regularized JVP keeps the
    # gradient finite when G is rank-deficient (clusters of exactly zero
    # eigenvalues — the squared singular values of a padded bond).  The
    # eps clamp below additionally stops the gradient through the noise
    # directions (jnp.maximum passes the gradient to the clamp side).
    lam, x = eigh_reg(g_mat)
    eps = _eps_for(a.dtype) * jnp.maximum(jnp.max(jnp.abs(lam)), 1.0)
    lam = jnp.maximum(lam.real, eps)
    sqrt_lam = jnp.sqrt(lam)
    r_mat = (sqrt_lam[:, None] * x.conj().T)           # R = sqrt(L) X^H
    p_mat = x / sqrt_lam[None, :]                      # P = R^{-1} = X L^{-1/2}
    # Q = A P (contraction over the small modes — big modes untouched;
    # Pallas-dispatched via the "tall_apply" site, dense path identical to
    # the pre-kernel tensordot).
    q = tall_project(a, p_mat, n_small).reshape(a.shape)
    r = r_mat.reshape(small_shape + small_shape)
    return q, r


def orthogonalize_cols(t: jnp.ndarray) -> jnp.ndarray:
    """Orthonormalize a sketch tensor over its last axis via the Gram trick.

    ``t`` has shape ``(*dims, k)``; returns ``q`` of the same shape with
    ``q^H q = I_k`` (over the leading modes).  This is the `orthogonalize`
    inside randomized SVD (paper Alg. 4 lines 2/4/5).
    """
    q, _ = gram_qr(t, 1)
    return q


def reshape_qr(a: jnp.ndarray, n_small: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Baseline: matricize + LAPACK QR (the approach Alg. 5 avoids).

    Same contract as :func:`gram_qr`; used for benchmarking the trade-off
    (paper Fig. 7b) and in tests as a reference.
    """
    big_shape = a.shape[: a.ndim - n_small]
    small_shape = a.shape[a.ndim - n_small:]
    nbig = 1
    for s in big_shape:
        nbig *= s
    nsmall = 1
    for s in small_shape:
        nsmall *= s
    mat = a.reshape(nbig, nsmall)
    q_mat, r_mat = jnp.linalg.qr(mat, mode="reduced")
    k = q_mat.shape[1]
    if k != nsmall:
        # wide case (nbig < nsmall): zero-pad so the inner bond stays nsmall
        q_mat = jnp.pad(q_mat, ((0, 0), (0, nsmall - k)))
        r_mat = jnp.pad(r_mat, ((0, nsmall - k), (0, 0)))
    q = q_mat.reshape(big_shape + small_shape)
    r = r_mat.reshape(small_shape + small_shape)
    return q, r
