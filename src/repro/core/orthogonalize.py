"""Reshape-avoiding orthogonalization via Gram matrices (paper Alg. 5).

On a distributed tensor, matricizing for QR forces a full data redistribution
(Cyclops) / an all-to-all re-layout (GSPMD).  The paper instead forms the
small Gram matrix ``G = A*A`` with a *contraction* over the big modes, which
the backend executes as a GEMM with no reshape of the big operand, then
eigendecomposes G locally and reconstitutes the isometry with one more GEMM.

All functions are jit-safe (static shapes, `eigh` only on the small matrix).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = {jnp.float32.dtype: 1e-6, jnp.float64.dtype: 1e-13,
        jnp.complex64.dtype: 1e-6, jnp.complex128.dtype: 1e-13}


def _eps_for(dtype) -> float:
    return _EPS.get(jnp.dtype(dtype), 1e-6)


# --------------------------------------------------------------------------
# Gram backend dispatch (tall-skinny hot path -> Pallas kernel)
# --------------------------------------------------------------------------
#
# The Gram matrix G = A^H A of Alg. 5 is the tall-skinny GEMM the Pallas
# ``gram`` kernel (src/repro/kernels/gram.py) implements: G stays in VMEM
# while A streams through in tiles.  Dispatch rule ("auto"):
#   * f32/bf16/c64 only (the kernel accumulates in f32 — routing f64 there
#     would silently halve precision), AND
#   * tall and skinny: nbig >= _PALLAS_MIN_BIG, nsmall <= _PALLAS_MAX_SMALL,
#     nbig >= 8 * nsmall, AND
#   * a real TPU backend (on CPU the kernel runs in interpret mode, which is
#     for correctness testing, not speed).
# "pallas" forces the kernel (interpret mode off-TPU; still dtype-gated);
# "dense" forces the jnp contraction.  See tests/test_planner.py.

_GRAM_BACKEND = {"mode": "auto"}
_PALLAS_MIN_BIG = 4096
_PALLAS_MAX_SMALL = 512
_DISPATCH_COUNTERS = {"pallas_gram_calls": 0, "dense_gram_calls": 0}

# dtypes the f32-accumulating kernel serves at full (or better) precision
_KERNEL_DTYPES = (jnp.float32.dtype, jnp.bfloat16.dtype, jnp.complex64.dtype)


def set_gram_backend(mode: str) -> str:
    """Select the Gram backend: 'auto' (shape/dtype/backend-gated Pallas),
    'pallas' (force the kernel), or 'dense'.  Returns the previous mode."""
    if mode not in ("auto", "pallas", "dense"):
        raise ValueError(f"bad gram backend {mode!r}")
    prev = _GRAM_BACKEND["mode"]
    _GRAM_BACKEND["mode"] = mode
    return prev


def gram_backend() -> str:
    """The currently-selected Gram backend mode ('auto'|'pallas'|'dense')."""
    return _GRAM_BACKEND["mode"]


def gram_dispatch_stats() -> dict:
    return dict(_DISPATCH_COUNTERS)


def reset_gram_dispatch_stats() -> None:
    for k in _DISPATCH_COUNTERS:
        _DISPATCH_COUNTERS[k] = 0


def _pallas_eligible(dtype, nbig: int, nsmall: int) -> bool:
    if jnp.dtype(dtype) not in _KERNEL_DTYPES:
        return False
    mode = _GRAM_BACKEND["mode"]
    if mode == "pallas":
        return True
    return (nbig >= _PALLAS_MIN_BIG and nsmall <= _PALLAS_MAX_SMALL
            and nbig >= 8 * nsmall and jax.default_backend() == "tpu")


def _gram_matrix(a: jnp.ndarray, big_axes: Tuple[int, ...],
                 nbig: int, nsmall: int) -> jnp.ndarray:
    """G = A^H A as an (nsmall, nsmall) matrix, Pallas-dispatched."""
    if _GRAM_BACKEND["mode"] != "dense" and _pallas_eligible(a.dtype, nbig,
                                                             nsmall):
        from repro.kernels.gram import gram, gram_complex
        _DISPATCH_COUNTERS["pallas_gram_calls"] += 1
        mat = a.reshape(nbig, nsmall)
        interpret = jax.default_backend() != "tpu"
        if jnp.issubdtype(a.dtype, jnp.complexfloating):
            return gram_complex(mat, interpret=interpret)
        return gram(mat, interpret=interpret)
    _DISPATCH_COUNTERS["dense_gram_calls"] += 1
    g = jnp.tensordot(a.conj(), a, axes=(big_axes, big_axes))
    return g.reshape(nsmall, nsmall)


def gram_qr(a: jnp.ndarray, n_small: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """QR-equivalent factorization A = Q @ R via the Gram trick (Alg. 5).

    ``a`` is treated as an operator from its *last* ``n_small`` modes (the
    small space) to its leading modes (the big space).  Returns:

    * ``q`` with the same shape as ``a`` — isometric over the big modes
      (on the row space of A),
    * ``r`` of shape ``small + small`` such that ``a == q . r`` (contraction
      over the last ``n_small`` modes of ``q`` with the first ``n_small`` of
      ``r``).

    No reshape touches the big modes: G = A*A is formed by a contraction, the
    eigendecomposition happens on the small G only.
    """
    big_shape = a.shape[: a.ndim - n_small]
    small_shape = a.shape[a.ndim - n_small:]
    nbig = 1
    for s in big_shape:
        nbig *= s
    nsmall = 1
    for s in small_shape:
        nsmall *= s

    big_axes = tuple(range(a.ndim - n_small))
    # G_{cc'} = sum_big conj(A)_{big,c} A_{big,c'} — contraction, no reshape of A
    # (or the Pallas streaming-Gram kernel when the operand qualifies).
    g_mat = _gram_matrix(a, big_axes, nbig, nsmall)  # small, local
    lam, x = jnp.linalg.eigh(g_mat)
    eps = _eps_for(a.dtype) * jnp.maximum(jnp.max(jnp.abs(lam)), 1.0)
    lam = jnp.maximum(lam.real, eps)
    sqrt_lam = jnp.sqrt(lam)
    r_mat = (sqrt_lam[:, None] * x.conj().T)           # R = sqrt(L) X^H
    p_mat = x / sqrt_lam[None, :]                      # P = R^{-1} = X L^{-1/2}
    p = p_mat.reshape(small_shape + small_shape)
    # Q = A P (contraction over the small modes — big modes untouched).
    small_axes = tuple(range(a.ndim - n_small, a.ndim))
    q = jnp.tensordot(a, p, axes=(small_axes, tuple(range(n_small))))
    r = r_mat.reshape(small_shape + small_shape)
    return q, r


def orthogonalize_cols(t: jnp.ndarray) -> jnp.ndarray:
    """Orthonormalize a sketch tensor over its last axis via the Gram trick.

    ``t`` has shape ``(*dims, k)``; returns ``q`` of the same shape with
    ``q^H q = I_k`` (over the leading modes).  This is the `orthogonalize`
    inside randomized SVD (paper Alg. 4 lines 2/4/5).
    """
    q, _ = gram_qr(t, 1)
    return q


def reshape_qr(a: jnp.ndarray, n_small: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Baseline: matricize + LAPACK QR (the approach Alg. 5 avoids).

    Same contract as :func:`gram_qr`; used for benchmarking the trade-off
    (paper Fig. 7b) and in tests as a reference.
    """
    big_shape = a.shape[: a.ndim - n_small]
    small_shape = a.shape[a.ndim - n_small:]
    nbig = 1
    for s in big_shape:
        nbig *= s
    nsmall = 1
    for s in small_shape:
        nsmall *= s
    mat = a.reshape(nbig, nsmall)
    q_mat, r_mat = jnp.linalg.qr(mat, mode="reduced")
    k = q_mat.shape[1]
    if k != nsmall:
        # wide case (nbig < nsmall): zero-pad so the inner bond stays nsmall
        q_mat = jnp.pad(q_mat, ((0, 0), (0, nsmall - k)))
        r_mat = jnp.pad(r_mat, ((0, nsmall - k), (0, 0)))
    q = q_mat.reshape(big_shape + small_shape)
    r = r_mat.reshape(small_shape + small_shape)
    return q, r
