"""Quantum gate library.

Conventions
-----------
* Single-qubit gates are ``(2, 2)`` matrices ``G[i, j] = <i|G|j>``.
* Two-qubit gates are ``(2, 2, 2, 2)`` tensors
  ``G[i1, i2, j1, j2] = <i1 i2|G|j1 j2>`` (outputs first, inputs last),
  matching Eq. (2) of the paper.
* All gates are numpy ``complex128``; callers may cast down.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

_C = np.complex128

I = np.eye(2, dtype=_C)
X = np.array([[0, 1], [1, 0]], dtype=_C)
Y = np.array([[0, -1j], [1j, 0]], dtype=_C)
Z = np.array([[1, 0], [0, -1]], dtype=_C)
H = np.array([[1, 1], [1, -1]], dtype=_C) / math.sqrt(2)
S = np.array([[1, 0], [0, 1j]], dtype=_C)
T = np.array([[1, 0], [0, np.exp(1j * math.pi / 4)]], dtype=_C)

# sqrt gates used by random quantum circuits (Arute et al. 2019).
SQRT_X = 0.5 * np.array([[1 + 1j, 1 - 1j], [1 - 1j, 1 + 1j]], dtype=_C)
SQRT_Y = 0.5 * np.array([[1 + 1j, -1 - 1j], [1 + 1j, 1 + 1j]], dtype=_C)
_W = (X + Y) / math.sqrt(2)


def _sqrtm_unitary(u: np.ndarray) -> np.ndarray:
    """Principal square root of a unitary via eigendecomposition."""
    w, v = np.linalg.eig(u)
    return (v * np.sqrt(w.astype(_C))) @ np.linalg.inv(v)


SQRT_W = _sqrtm_unitary(_W)


def RX(theta: float) -> np.ndarray:
    c, s = math.cos(theta / 2), math.sin(theta / 2)
    return np.array([[c, -1j * s], [-1j * s, c]], dtype=_C)


def RY(theta: float) -> np.ndarray:
    c, s = math.cos(theta / 2), math.sin(theta / 2)
    return np.array([[c, -s], [s, c]], dtype=_C)


def RZ(theta: float) -> np.ndarray:
    return np.array(
        [[np.exp(-0.5j * theta), 0], [0, np.exp(0.5j * theta)]], dtype=_C
    )


def _two_qubit(mat4: np.ndarray) -> np.ndarray:
    """Reshape a 4x4 matrix (basis order |00>,|01>,|10>,|11>) to (2,2,2,2)."""
    return np.asarray(mat4, dtype=_C).reshape(2, 2, 2, 2)


CX = _two_qubit(
    [[1, 0, 0, 0], [0, 1, 0, 0], [0, 0, 0, 1], [0, 0, 1, 0]]
)
CZ = _two_qubit(np.diag([1, 1, 1, -1]))
SWAP = _two_qubit(
    [[1, 0, 0, 0], [0, 0, 1, 0], [0, 1, 0, 0], [0, 0, 0, 1]]
)
ISWAP = _two_qubit(
    [[1, 0, 0, 0], [0, 0, 1j, 0], [0, 1j, 0, 0], [0, 0, 0, 1]]
)


def CPHASE(phi: float) -> np.ndarray:
    return _two_qubit(np.diag([1, 1, 1, np.exp(1j * phi)]))


def FSIM(theta: float, phi: float) -> np.ndarray:
    c, s = math.cos(theta), math.sin(theta)
    return _two_qubit(
        [
            [1, 0, 0, 0],
            [0, c, -1j * s, 0],
            [0, -1j * s, c, 0],
            [0, 0, 0, np.exp(-1j * phi)],
        ]
    )


GATES = {
    "I": I, "X": X, "Y": Y, "Z": Z, "H": H, "S": S, "T": T,
    "SQRT_X": SQRT_X, "SQRT_Y": SQRT_Y, "SQRT_W": SQRT_W,
    "CX": CX, "CNOT": CX, "CZ": CZ, "SWAP": SWAP, "ISWAP": ISWAP,
}

_PARAMETRIC = {"RX": RX, "RY": RY, "RZ": RZ, "CPHASE": CPHASE, "FSIM": FSIM}


def gate(name: str, *params: float) -> np.ndarray:
    """Look up a gate by name, with optional parameters."""
    if name in _PARAMETRIC:
        return _PARAMETRIC[name](*params)
    return GATES[name]


def two_site_gate(mat4: np.ndarray) -> np.ndarray:
    """Public helper: 4x4 matrix -> (2,2,2,2) two-site gate tensor."""
    return _two_qubit(mat4)


# ---------------------------------------------------------------------------
# Hamiltonian terms and Trotter gates
# ---------------------------------------------------------------------------

def pauli_term(names: str) -> np.ndarray:
    """Kronecker product of Pauli matrices, e.g. 'ZZ' or 'X'.

    Returns a (2^k, 2^k) Hermitian matrix.
    """
    mats = {"I": I, "X": X, "Y": Y, "Z": Z}
    out = np.array([[1.0 + 0j]])
    for ch in names:
        out = np.kron(out, mats[ch])
    return out


@lru_cache(maxsize=None)
def _expm_cache(key):
    mat_bytes, shape, tau = key
    h = np.frombuffer(mat_bytes, dtype=_C).reshape(shape)
    return _expm_hermitian(h, tau)


def _expm_hermitian(h: np.ndarray, tau: float) -> np.ndarray:
    """exp(-tau * h) for Hermitian h, via eigendecomposition."""
    w, v = np.linalg.eigh(h)
    return (v * np.exp(-tau * w)) @ v.conj().T


def trotter_gate(h: np.ndarray, tau: float) -> np.ndarray:
    """Imaginary-time-evolution gate exp(-tau*h) for a local Hermitian term.

    Accepts a (2,2) one-site term or a (4,4) two-site term; the latter is
    returned in (2,2,2,2) gate-tensor layout.
    """
    h = np.asarray(h, dtype=_C)
    g = _expm_cache((h.tobytes(), h.shape, float(tau)))
    if g.shape == (4, 4):
        return _two_qubit(g)
    return g
