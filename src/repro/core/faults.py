"""Deterministic fault injection for the runtime guard's degradation paths.

Every recovery path in :mod:`repro.core.runtime_guard` — rsvd -> exact SVD,
mixed -> exact precision, Pallas -> dense, torn checkpoint writes — exists
because some failure is possible in production but essentially impossible
to provoke on demand (a NaN from an ill-conditioned implicit operator, a
Pallas kernel crash on one TPU core, a process kill mid checkpoint write).
This module makes those failures *reproducible*: named **sites** in the
code call :func:`should_fire` on every pass, and a test arms a site to
misbehave on exactly the Nth call.  Each degradation rung is therefore
regression-testable on CPU with no randomness and no real hardware fault.

Instrumented sites (the registry accepts any name; these are the ones the
library calls):

========================  ==================================================
site                      effect when armed
========================  ==================================================
``einsumsvd.result``      the factors of the next einsumsvd solve are
                          corrupted per ``action`` (``"nan"`` | ``"inf"`` |
                          ``"zero"``) — see
                          ``runtime_guard.guarded_solve``
``kernel.<site>``         the kernel-dispatch site (``kernel.gram``,
                          ``kernel.tall_apply``, ...) raises
                          :class:`InjectedFault` instead of running its
                          Pallas implementation — see
                          ``repro.kernels.dispatch.dispatch``.  Fires at
                          Python dispatch (trace) time, the same tick
                          semantics as the dispatch counters
``checkpoint.write``      the next checkpoint write is torn: ``"torn"``
                          leaves a partial ``*.tmp`` and never publishes
                          (a kill mid-write), ``"torn_final"`` publishes a
                          directory with a truncated manifest (a kill
                          mid-``os.replace`` on a non-atomic filesystem) —
                          see ``repro.checkpoint.manager``
========================  ==================================================

Arming is per-process and explicitly scoped: :func:`arm` installs a spec,
:func:`clear` removes everything (tests pair them in try/finally or the
``armed`` context manager).  Call counting is deterministic — the site
counter ticks once per :func:`should_fire` call, and the spec fires for
calls ``nth .. nth+times-1`` (1-based), so "fail twice, then succeed"
exercises a two-rung escalation exactly.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional


class InjectedFault(RuntimeError):
    """Raised by an instrumented site armed with a raising action.

    ``site`` carries the site name so handlers (the runtime guard) can
    pick a recovery rung from where the failure came from."""

    def __init__(self, site: str, message: str = ""):
        super().__init__(message or f"injected fault at site {site!r}")
        self.site = site


@dataclasses.dataclass
class FaultSpec:
    """One armed fault: fire on calls ``nth .. nth+times-1`` (1-based)."""
    site: str
    nth: int = 1
    action: str = "nan"
    times: int = 1
    fired: int = 0      # how many times this spec has fired so far


_SPECS: Dict[str, FaultSpec] = {}
_CALLS: Dict[str, int] = {}


def arm(site: str, nth: int = 1, action: str = "nan",
        times: int = 1) -> FaultSpec:
    """Arm ``site`` to fire on its Nth call (and the ``times-1`` after it).

    Re-arming a site replaces its spec and resets its call counter, so a
    test's view of "the Nth call" always starts from its own ``arm``."""
    if nth < 1 or times < 1:
        raise ValueError(f"nth/times must be >= 1, got nth={nth} times={times}")
    spec = FaultSpec(site=site, nth=nth, action=action, times=times)
    _SPECS[site] = spec
    _CALLS[site] = 0
    return spec


def disarm(site: str) -> None:
    _SPECS.pop(site, None)
    _CALLS.pop(site, None)


def clear() -> None:
    """Disarm every site and drop all call counters."""
    _SPECS.clear()
    _CALLS.clear()


def active() -> Dict[str, FaultSpec]:
    """The currently armed specs (a copy; safe to inspect)."""
    return dict(_SPECS)


def should_fire(site: str) -> Optional[FaultSpec]:
    """Tick ``site``'s call counter; return its spec iff this call fires.

    Zero-cost for unarmed sites beyond one dict lookup — the instrumented
    hot paths (einsumsvd, kernel dispatch) stay un-slowed when no test is
    injecting."""
    spec = _SPECS.get(site)
    if spec is None:
        return None
    n = _CALLS.get(site, 0) + 1
    _CALLS[site] = n
    if spec.nth <= n < spec.nth + spec.times:
        spec.fired += 1
        return spec
    return None


@contextlib.contextmanager
def armed(site: str, nth: int = 1, action: str = "nan", times: int = 1):
    """Context-managed :func:`arm` — disarms the site on exit."""
    spec = arm(site, nth=nth, action=action, times=times)
    try:
        yield spec
    finally:
        disarm(site)
