"""Contraction-plan cache and fused einsumsvd engine (the library's hot path).

A BMPS sweep calls ``einsumsvd`` once per site, and every site of a row (bar
the edges) presents the *same* tensor-network structure: same subscripts,
same shapes, same dtype, same row/col split.  The paper (arXiv:2006.15234,
Alg. 4) and Lubasch et al. (arXiv:1405.3259) exploit exactly this repeated
subnetwork structure; the seed implementation instead re-derived an
``optimize="optimal"`` einsum path on every ``matvecs``/``rmatvecs`` call of
every power iteration and never reused compiled code across sites.

This module fixes both with two memoization layers, keyed by a **network
signature**:

``signature = (subscripts, shapes, dtypes, row, col [, solver config])``

1. **Path cache** — :func:`contraction_path` memoizes the opt_einsum
   contraction path for an einsum expression + operand shapes.
   :class:`~repro.core.rsvd.ImplicitOperator` routes every contraction
   through :func:`cached_einsum`, so the path search runs once per distinct
   network shape instead of once per matvec.
2. **Fused-solver cache** — :func:`fused_randomized_svd` jit-compiles the
   whole randomized-SVD refactorization (sketch -> power iterations ->
   Gram-QR final) as ONE function per signature.  All sites / rows / sweeps
   of ``contract_onelayer``, ``contract_twolayer`` and the ITE/VQE loops
   that share a signature reuse the same compiled executable.

Hit/miss counters are kept per layer (:func:`stats`) so tests and benchmarks
can assert cache behavior.  Counters tick at Python dispatch time: a fused
HIT means a previously-built compiled function was re-invoked.

:func:`disabled` temporarily switches both layers off, restoring the seed
behavior — used by ``benchmarks/bench_planner.py`` for A/B timing.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import opt_einsum

# --------------------------------------------------------------------------
# Configuration + counters
# --------------------------------------------------------------------------

_CONFIG = {
    "path_cache": True,   # memoize einsum contraction paths
    "fusion": True,       # jit-fuse randomized_svd per network signature
}

_PATH_CACHE: Dict[tuple, list] = {}
_FUSED_CACHE: Dict[tuple, object] = {}

_COUNTERS = {
    "path_hits": 0,
    "path_misses": 0,
    "path_uncached": 0,    # path searches with the cache disabled
    "path_preloaded": 0,   # entries installed by load_path_cache
    "fused_hits": 0,
    "fused_misses": 0,
}


def stats() -> Dict[str, int]:
    """Current cache counters + sizes (copies; safe to hold)."""
    out = dict(_COUNTERS)
    out["path_cache_size"] = len(_PATH_CACHE)
    out["fused_cache_size"] = len(_FUSED_CACHE)
    from repro.core import orthogonalize as _orth
    out.update(_orth.gram_dispatch_stats())
    from repro.core import runtime_guard as _guard
    out.update(_guard.global_counters())
    return out


def stats_since(before: Dict[str, int]) -> Dict[str, int]:
    """Counter deltas relative to an earlier :func:`stats` snapshot.

    Cache sizes (``*_cache_size``) stay absolute; everything else is the
    difference.  Lets callers measure a window without resetting the
    process-global counters."""
    now = stats()
    return {k: v if k.endswith("_cache_size") else v - before.get(k, 0)
            for k, v in now.items()}


def reset_stats() -> None:
    for k in _COUNTERS:
        _COUNTERS[k] = 0
    from repro.core import orthogonalize as _orth
    _orth.reset_gram_dispatch_stats()
    from repro.core import runtime_guard as _guard
    _guard.reset_global_counters()


def clear() -> None:
    """Drop both caches (and counters).  Compiled executables are released."""
    _PATH_CACHE.clear()
    _FUSED_CACHE.clear()
    reset_stats()


@contextlib.contextmanager
def disabled():
    """Temporarily restore the seed behavior (no path cache, no fusion)."""
    prev = dict(_CONFIG)
    _CONFIG["path_cache"] = False
    _CONFIG["fusion"] = False
    try:
        yield
    finally:
        _CONFIG.update(prev)


def configure(*, path_cache: bool = None, fusion: bool = None) -> Dict[str, bool]:
    """Flip individual layers; returns the previous configuration."""
    prev = dict(_CONFIG)
    if path_cache is not None:
        _CONFIG["path_cache"] = path_cache
    if fusion is not None:
        _CONFIG["fusion"] = fusion
    return prev


# --------------------------------------------------------------------------
# Signatures
# --------------------------------------------------------------------------

def network_signature(subscripts: Sequence[str],
                      shapes: Sequence[Tuple[int, ...]],
                      dtypes: Sequence,
                      row: str, col: str) -> tuple:
    """Hashable identity of an einsumsvd subnetwork.

    Two calls with equal signatures are guaranteed to contract identically:
    same labels, operand shapes, operand dtypes and row/col split."""
    return (
        tuple(subscripts),
        tuple(tuple(s) for s in shapes),
        tuple(jnp.dtype(d).name for d in dtypes),
        row,
        col,
    )


# --------------------------------------------------------------------------
# Layer 1: contraction-path cache
# --------------------------------------------------------------------------

def _path_optimizer(n_operands: int) -> str:
    # "optimal" enumerates orderings factorially — fine for the <=6-tensor
    # einsumsvd subnetworks, hopeless for the 8-10-tensor neighborhood
    # environments of the full update.  opt_einsum's dynamic-programming
    # search is exact w.r.t. contraction cost and scales to ~20 tensors.
    return "optimal" if n_operands <= 6 else "dp"


def contraction_path(expr: str, shapes: Tuple[Tuple[int, ...], ...]) -> list:
    """Optimal contraction path for ``expr`` over operands of ``shapes``.

    Memoized on (expr, shapes); the search itself runs on shapes only (no
    array data), via opt_einsum."""
    if not _CONFIG["path_cache"]:
        _COUNTERS["path_uncached"] += 1
        path, _ = opt_einsum.contract_path(expr, *shapes, shapes=True,
                                           optimize=_path_optimizer(len(shapes)))
        return path
    key = (expr, shapes)
    hit = _PATH_CACHE.get(key)
    if hit is not None:
        _COUNTERS["path_hits"] += 1
        return hit
    _COUNTERS["path_misses"] += 1
    path, _ = opt_einsum.contract_path(expr, *shapes, shapes=True,
                                       optimize=_path_optimizer(len(shapes)))
    _PATH_CACHE[key] = path
    return path


def cached_einsum(expr: str, *tensors: jnp.ndarray) -> jnp.ndarray:
    """``jnp.einsum`` along a plan-cached optimal path."""
    path = contraction_path(expr, tuple(tuple(t.shape) for t in tensors))
    return jnp.einsum(expr, *tensors, optimize=path)


# --------------------------------------------------------------------------
# Persistent path cache (warm-starting a restarted replica)
# --------------------------------------------------------------------------
#
# The path cache is pure data — (expr, shapes) -> a list of pairwise
# contraction steps — so unlike the fused cache (compiled executables,
# process-bound) it survives serialization.  A restarted replica preloads
# the file and replays an identical workload with zero path-search misses;
# the jit compiles still happen, but the opt_einsum dp searches (the
# dominant single-thread cost of a cold full-update start) do not.
#
# The file is JSON with a sha256 checksum over the canonicalized entries.
# Loading is load-or-ignore: any corruption — truncation, checksum
# mismatch, an unknown format version, plain bad JSON — degrades to a cold
# start with a RuntimeWarning, never a crash.  Entries are validated
# structurally (a path step is a tuple of operand indices) before install.

PATH_CACHE_FORMAT = 1


def _path_entries_canonical(entries: list) -> str:
    return json.dumps(entries, sort_keys=True, separators=(",", ":"))


def save_path_cache(path: str) -> int:
    """Serialize the in-memory path cache to ``path`` (atomic write).

    Returns the number of entries written."""
    entries = sorted(
        [expr, [list(s) for s in shapes], [list(step) for step in plan]]
        for (expr, shapes), plan in _PATH_CACHE.items()
    )
    payload = {
        "format": PATH_CACHE_FORMAT,
        "checksum": hashlib.sha256(
            _path_entries_canonical(entries).encode()).hexdigest(),
        "entries": entries,
    }
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)
    return len(entries)


def load_path_cache(path: str) -> int:
    """Preload contraction paths from ``path`` into the in-memory cache.

    Returns the number of entries installed (0 on a missing/corrupt/stale
    file — cold start with a RuntimeWarning, never an exception).  Installed
    entries tick ``path_preloaded``; subsequent lookups count as hits, so a
    fully warm-started workload shows ``path_misses == 0``."""
    try:
        with open(path) as f:
            payload = json.load(f)
        if payload["format"] != PATH_CACHE_FORMAT:
            raise ValueError(f"unknown path-cache format {payload['format']!r}")
        entries = payload["entries"]
        digest = hashlib.sha256(
            _path_entries_canonical(entries).encode()).hexdigest()
        if digest != payload["checksum"]:
            raise ValueError("path-cache checksum mismatch")
        installed = 0
        staged = {}
        for expr, shapes, plan in entries:
            if not isinstance(expr, str):
                raise ValueError("path-cache entry: expr must be a string")
            key = (expr, tuple(tuple(int(d) for d in s) for s in shapes))
            staged[key] = [tuple(int(i) for i in step) for step in plan]
            installed += 1
    except FileNotFoundError:
        return 0
    except (OSError, ValueError, KeyError, TypeError,
            json.JSONDecodeError) as e:
        warnings.warn(
            f"ignoring unusable planner path cache {path!r} ({e!r}): "
            f"cold start", RuntimeWarning)
        return 0
    _PATH_CACHE.update(staged)
    _COUNTERS["path_preloaded"] += installed
    return installed


_INT_LABELS = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"


def int_einsum(*args) -> jnp.ndarray:
    """Interleaved-format einsum with integer labels, along a plan-cached path.

    ``int_einsum(t0, labels0, t1, labels1, ..., out_labels)`` where each
    ``labels`` is a sequence of hashable (integer) axis labels.  The labels
    are remapped to a canonical subscript string so that structurally-equal
    networks built from *different* label counters share one cache entry —
    this is what lets the strip/environment contractions of
    ``expectation.strip_value`` and ``full_update`` hit the path cache across
    columns, sites and sweeps.

    Falls back to ``jnp.einsum(..., optimize="auto")`` (uncached) if the
    network uses more than 52 distinct labels.
    """
    *pairs, out = args
    tensors = list(pairs[0::2])
    labels = list(pairs[1::2])
    mapping: Dict = {}

    def lab(ls):
        for l in ls:
            if l not in mapping:
                mapping[l] = _INT_LABELS[len(mapping)]
        return "".join(mapping[l] for l in ls)

    try:
        expr = ",".join(lab(ls) for ls in labels) + "->" + lab(out)
    except IndexError:  # > 52 distinct labels: interleaved fallback
        _COUNTERS["path_uncached"] += 1
        flat = []
        for t, ls in zip(tensors, labels):
            flat += [t, list(ls)]
        flat.append(list(out))
        return jnp.einsum(*flat, optimize="auto")
    return cached_einsum(expr, *tensors)


# --------------------------------------------------------------------------
# Generic fused-function cache (shared by the rSVD engine and full update)
# --------------------------------------------------------------------------

def fused_fn(tag: str, signature: tuple, builder):
    """Memoized compiled callable per ``(tag,) + signature``.

    ``builder()`` is invoked once per distinct signature and should return a
    (typically ``jax.jit``-wrapped) function; later calls with an equal
    signature replay the cached callable.  Hits/misses tick the same
    ``fused_*`` counters as :func:`fused_randomized_svd`, so benchmarks and
    tests can assert cache behavior across *all* fused engines.  The caller
    is responsible for folding every trace-time decision (shapes, dtypes,
    static solver config, device backend) into ``signature``.

    With fusion disabled (:func:`disabled` / :func:`configure`), the builder
    result is neither cached nor counted — callers get a fresh (still
    correct, typically uncompiled) function each time.
    """
    if not _CONFIG["fusion"]:
        return builder()
    key = (tag,) + tuple(signature)
    fn = _FUSED_CACHE.get(key)
    if fn is None:
        _COUNTERS["fused_misses"] += 1
        fn = builder()
        _FUSED_CACHE[key] = fn
    else:
        _COUNTERS["fused_hits"] += 1
    return fn


# --------------------------------------------------------------------------
# Layer 2: fused randomized-SVD solver cache
# --------------------------------------------------------------------------

def _build_fused(subscripts: Tuple[str, ...], row: str, col: str,
                 rank: int, n_iter: int, oversample: int, gram_final: bool):
    from repro.core.rsvd import ImplicitOperator, randomized_svd

    @jax.jit
    def run(tensors: List[jnp.ndarray], key):
        op = ImplicitOperator(tensors, list(subscripts), row, col)
        return randomized_svd(op, rank, n_iter=n_iter, oversample=oversample,
                              key=key, gram_final=gram_final)

    return run


def fused_randomized_svd(op, rank: int, n_iter: int = 4, oversample: int = 8,
                         key=None, gram_final: bool = True):
    """Randomized SVD of an :class:`ImplicitOperator`, jit-fused per signature.

    The entire Alg. 4 pipeline — random sketch, power iterations (with
    Gram-QR orthogonalizations), final Gram-QR + small dense SVD — compiles
    to one executable, cached on the network signature + solver config and
    reused by every einsumsvd call with the same structure.  Numerically
    identical to :func:`repro.core.rsvd.randomized_svd` (same ops, traced).
    """
    from repro.core.rsvd import randomized_svd
    from repro.kernels import dispatch as _dispatch
    if key is None:
        key = jax.random.PRNGKey(0)
    if not _CONFIG["fusion"]:
        return randomized_svd(op, rank, n_iter=n_iter, oversample=oversample,
                              key=key, gram_final=gram_final)
    sig = network_signature(op.subscripts,
                            [t.shape for t in op.tensors],
                            [t.dtype for t in op.tensors],
                            op.row, op.col)
    # Kernel-dispatch state (backend mode, per-site overrides, compute
    # dtype, interpret mode) is a trace-time decision baked into the
    # compiled executable, so its full signature (and the device backend)
    # must be part of the key — otherwise set_kernel_backend() /
    # set_kernel_compute() would be silently ignored for already-compiled
    # signatures.
    sig = sig + (rank, n_iter, oversample, gram_final,
                 _dispatch.backend_signature(), jax.default_backend())
    fn = _FUSED_CACHE.get(sig)
    if fn is None:
        _COUNTERS["fused_misses"] += 1
        fn = _build_fused(tuple(op.subscripts), op.row, op.col,
                          rank, n_iter, oversample, gram_final)
        _FUSED_CACHE[sig] = fn
    else:
        _COUNTERS["fused_hits"] += 1
    return fn(list(op.tensors), key)
