"""The einsumsvd abstraction (paper Section II-C / IV-A).

``einsumsvd`` contracts a tensor network into one tensor and refactorizes it
into two tensors joined by a single truncated bond.  The *algorithm option*
decides how:

* :class:`DirectSVD` — materialize theta, matricize, LAPACK SVD (baseline).
* :class:`RandomizedSVD` — implicit randomized SVD (Alg. 4): theta is never
  formed; asymptotically cheaper and single-pass (IBMPS / two-layer IBMPS).

All paths truncate to a *static* rank (jit-friendly); an optional relative
``cutoff`` additionally zeroes trailing singular values (shape-preserving).

Planner architecture (see :mod:`repro.core.planner`)
----------------------------------------------------
The hot path is plan-cached and fused, keyed by the **network signature**
``(subscripts, shapes, dtypes, row, col)``:

* *Signature keying* — every einsumsvd subnetwork with the same structure
  (e.g. all interior sites of a BMPS zip-up row, across rows and sweeps)
  maps to one cache entry; a different shape/dtype/split is a different
  entry.
* *Fusion boundary* — with ``RandomizedSVD(fused=True)`` (the default) the
  whole refactorization (sketch -> power iterations -> Gram-QR final +
  small SVD) is one jit-compiled function per signature; the contraction
  paths inside it are memoized by the planner's path cache, which also
  serves the unfused and :class:`DirectSVD` paths through
  ``ImplicitOperator``.
* *Kernel dispatch rule* — the big-operand GEMMs of the solve (the Gram
  matrices of the orthogonalization steps, the tall-apply reconstitutions/
  projections of the rSVD chain, and the zip-up first-column/pair-merge
  einsums of the engines) are registered as sites in
  :mod:`repro.kernels.dispatch` and route to their Pallas kernels when the
  operand is tall-skinny (``nbig >= 8 * nsmall``, small side <= 512),
  32-bit, and a TPU backend is active; otherwise the exact dense
  contraction runs.  ``set_kernel_backend`` forces either path (globally
  or per site); f64/c128 operands stay dense unconditionally.  The full
  dispatch state is folded into the planner's fused-cache keys.

Precision (see :mod:`repro.core.precision`)
-------------------------------------------
``einsumsvd(..., precision="mixed")`` (or a wrapped option from
``precision.wrap_svd``) demotes the operand tensors one storage tier
around the solve (f64 -> f32, c128 -> c64), runs the Pallas kernel sites
with bf16 multiplicands / f32 accumulation, and promotes the factors back.
The default ``"exact"`` is the identity — bit-identical to the pre-policy
code path.

The same engines seed the full update's ALS bond optimization
(:mod:`repro.core.full_update`): the reduced gate-applied network is split
here first, then refined in the neighborhood-environment metric.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.core.rsvd import ImplicitOperator, randomized_svd
from repro.core.svd_grad import sqrt_reg, svd_reg


def _apply_cutoff(s: jnp.ndarray, cutoff: float) -> jnp.ndarray:
    if cutoff <= 0.0:
        return s
    return jnp.where(s >= cutoff * s[0], s, 0.0)


@dataclasses.dataclass(frozen=True)
class DirectSVD:
    """Explicitly contract theta, then truncated LAPACK SVD."""
    cutoff: float = 0.0

    def __call__(self, op: ImplicitOperator, rank: int, key=None):
        theta = op.dense()
        m, n = op.row_size, op.col_size
        rank = min(rank, m, n)
        mat = theta.reshape(m, n)
        # svd_reg: forward bit-identical to jnp.linalg.svd, JVP regularized
        # for (near-)degenerate / zero singular values (core/svd_grad.py) —
        # this seam is what jax.grad(vqe_energy_peps) differentiates through.
        u, s, vh = svd_reg(mat)
        u, s, vh = u[:, :rank], s[:rank], vh[:rank]
        s = _apply_cutoff(s, self.cutoff)
        return (
            u.reshape(op.row_shape + (rank,)),
            s,
            vh.reshape((rank,) + op.col_shape),
        )


@dataclasses.dataclass(frozen=True)
class RandomizedSVD:
    """Implicit randomized SVD (paper Alg. 4).

    ``gram_final`` replaces the paper's dense k x Ncol final SVD with a
    Gram-QR + local k x k SVD (beyond-paper; see EXPERIMENTS.md SSPerf).

    ``fused`` (default) runs the whole solve as one jit-compiled function
    per network signature, reused across all structurally-identical
    einsumsvd calls (see :mod:`repro.core.planner`).  ``fused=False`` is the
    op-by-op reference path; both produce the same result for the same key.
    """
    niter: int = 4
    oversample: int = 8
    cutoff: float = 0.0
    gram_final: bool = True
    fused: bool = True

    def __call__(self, op: ImplicitOperator, rank: int, key=None):
        if self.fused:
            u, s, v = planner.fused_randomized_svd(
                op, rank, n_iter=self.niter, oversample=self.oversample,
                key=key, gram_final=self.gram_final)
        else:
            u, s, v = randomized_svd(op, rank, self.niter, self.oversample,
                                     key, gram_final=self.gram_final)
        s = _apply_cutoff(s, self.cutoff)
        return u, s, v


def einsumsvd(
    option,
    tensors: Sequence[jnp.ndarray],
    subscripts: Sequence[str],
    row: str,
    col: str,
    rank: int,
    absorb: str = "both",
    key=None,
    precision=None,
) -> Tuple[jnp.ndarray, ...]:
    """Contract the network and refactorize into (left, right) along a new bond.

    Parameters
    ----------
    option:      DirectSVD() or RandomizedSVD(...).
    tensors, subscripts: the network (einsum-style labels, one string/tensor).
    row, col:    dangling labels that go to the left / right factor.
    rank:        truncation bond dimension (static).
    absorb:      'both' (sqrt(s) into each factor — simple update convention),
                 'left', 'right', or 'none' (returns (u, s, v)).
    precision:   optional policy name/instance (``"exact"`` | ``"mixed"``)
                 applied to the option for this call (see
                 :mod:`repro.core.precision`).  ``None`` keeps whatever
                 policy the option already carries.

    Returns (left, right) — or (u, s, v) when absorb='none'.  The new bond is
    the LAST axis of ``left`` and the FIRST axis of ``right``.
    """
    if precision is not None:
        from repro.core.precision import wrap_svd
        option = wrap_svd(option, precision)
    op = ImplicitOperator(tensors, subscripts, row, col)
    # Every truncation in the library funnels through this seam — boundary
    # zip-up rows, the variational engine's fits, full-update bond seeds —
    # so the runtime guard's detect/escalate/retry loop wraps exactly here.
    # Unguarded (no active RuntimeGuard), this is option(op, rank, key).
    from repro.core.runtime_guard import guarded_solve
    u, s, v = guarded_solve(option, op, rank, key)
    if absorb == "none":
        return u, s, v
    return absorb_factors(u, s, v, absorb)


def absorb_factors(u: jnp.ndarray, s: jnp.ndarray, v: jnp.ndarray,
                   absorb: str = "both") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fold the singular values into the factors (einsumsvd conventions).

    ``absorb='both'`` splits ``sqrt(s)`` into each factor (the simple-update
    gauge, also the ALS seed gauge of the full update); ``'left'``/``'right'``
    put all of ``s`` on one side.  ``u``'s LAST and ``v``'s FIRST axis are
    the shared bond."""
    if absorb == "both":
        # sqrt_reg == jnp.sqrt forward; its derivative at the exact zeros of
        # a rank-deficient bond is the (finite) symmetric subgradient 0.
        sq = sqrt_reg(s)
        return u * sq, sq[(slice(None),) + (None,) * (v.ndim - 1)] * v
    if absorb == "left":
        return u * s, v
    if absorb == "right":
        return u, s[(slice(None),) + (None,) * (v.ndim - 1)] * v
    raise ValueError(f"bad absorb={absorb!r}")


def truncation_error(op_dense: jnp.ndarray, u, s, v) -> jnp.ndarray:
    """Frobenius-norm relative error of a refactorization (test utility)."""
    rank = s.shape[0]
    left = u.reshape(-1, rank)
    right = v.reshape(rank, -1)
    approx = (left * s) @ right
    exact = op_dense.reshape(left.shape[0], right.shape[1])
    return jnp.linalg.norm(approx - exact) / jnp.maximum(jnp.linalg.norm(exact), 1e-300)
