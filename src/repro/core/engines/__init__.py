"""Pluggable boundary-absorption engines for the contraction stack.

The bottleneck of every contraction this library performs is the same
operation: absorb one PEPS row (an MPO) into the boundary MPS while keeping
the boundary bond at chi.  Historically exactly one strategy existed —
zip-up truncation with (randomized) einsumsvd — and it was hard-wired into
``core/bmps.py``, ``core/distributed.py``, ``core/spmd.py`` and
``core/environments.py``.  This package makes the strategy a first-class,
pluggable **boundary engine**:

* :mod:`repro.core.engines.zipup` — the extracted zip-up machinery
  (bit-identical to the pre-refactor code; the default);
* :mod:`repro.core.engines.variational` — a fixed-chi boundary MPS
  optimized by ALS fitting sweeps against the implicitly row-absorbed
  MPO·MPS (Lubasch-style local updates, arXiv:1405.3259; the
  variational/CTMRG-style family of arXiv:2110.12726), seeded from a cheap
  zip-up pass.  Globally optimal at fixed chi where zip-up is greedy.

Engine contract (:class:`BoundaryEngine`)
-----------------------------------------
An engine supplies **row absorption** for the one- and two-layer networks
plus the **final-scalar** closings, under three cross-cutting contracts:

1. *Key contract* — absorption consumes exactly one PRNG key per row and
   derives any per-column keys via ``jax.random.split(key, ncol)``, so a
   given ``(engine, key)`` pair is deterministic and every execution mode
   can reproduce it.
2. *Planner-signature contract* — all inner einsums/solvers must route
   through :mod:`repro.core.planner` (``cached_einsum`` / ``int_einsum`` /
   ``fused_fn`` / ``fused_randomized_svd``) keyed by network signature, so
   structurally-equal work replays compiled code across columns, rows and
   sweeps (hit rates > 99% after warm-up — asserted in tests).
3. *Block contract (optional)* — ``supports_blocks = True`` engines expose
   their row absorption as composable column-block kernels with a single
   carry tensor (``zipup_block*``); only such engines can run on the
   distributed halo-exchange pipeline shard-locally and inside the
   compiled SPMD superstep.  Engines without block structure still work
   with :class:`~repro.core.distributed.DistributedBMPS` — rows run
   engine-local on one device, sandwiched between the sharded layout — but
   the SPMD wavefront rejects them with a :class:`ValueError`.

Selecting an engine
-------------------
``BMPS`` / ``DistributedBMPS`` carry an ``engine`` field accepting either a
registered name (``"zipup"``, ``"variational"``) or an engine instance
(e.g. ``VariationalEngine(sweeps=4)`` for non-default hyper-parameters)::

    norm_squared(state, BMPS.randomized(16, engine="variational"))

``get_engine`` resolves the field; unknown names/objects raise a
``TypeError`` listing the registered engines (the repo-wide option-dispatch
convention, cf. ``peps.check_update``).
"""
from __future__ import annotations

from typing import Dict, List


class BoundaryEngine:
    """Base class / protocol for boundary-absorption engines.

    Subclasses set ``name`` (the registry key) and ``supports_blocks``
    (whether the distributed halo pipeline / SPMD superstep can schedule
    the engine shard-locally), and implement the four methods below.  The
    boundary-MPS tensor layouts are fixed across engines — one-layer
    ``(l, d, r)``, two-layer ``(l, d_bra, d_ket, r)`` — so engines are
    interchangeable mid-stack (environments produced by one engine close
    under another, etc.).
    """

    name: str = "abstract"
    supports_blocks: bool = False

    def absorb_onelayer(self, svec, row, chi, svd, key) -> List:
        """Absorb an (u,l,d,r)-site MPO row into the one-layer boundary."""
        raise NotImplementedError

    def absorb_twolayer(self, svec, bra_row, ket_row, chi, svd, key,
                        constrain_carry=None) -> List:
        """Absorb a bra/ket row pair ((p,u,l,d,r) sites) into the two-layer
        boundary.  The bra is conjugated by the engine."""
        raise NotImplementedError

    def final_scalar_onelayer(self, svec):
        """Close a fully-absorbed one-layer boundary (dangling dims 1)."""
        raise NotImplementedError

    def final_scalar_twolayer(self, svec):
        """Close a fully-absorbed two-layer boundary (dangling dims 1)."""
        raise NotImplementedError


_REGISTRY: Dict[str, BoundaryEngine] = {}


def register_engine(engine: BoundaryEngine) -> BoundaryEngine:
    """Add an engine to the registry under ``engine.name`` (last wins)."""
    _REGISTRY[engine.name] = engine
    return engine


def registered_engines() -> Dict[str, BoundaryEngine]:
    """Copy of the name -> engine registry (triggers built-in registration)."""
    _ensure_builtin()
    return dict(_REGISTRY)


def _ensure_builtin() -> None:
    # Built-in engines live in submodules that import this module; register
    # them lazily on first lookup to keep import order acyclic.
    if "zipup" not in _REGISTRY:
        from repro.core.engines import zipup  # noqa: F401
    if "variational" not in _REGISTRY:
        from repro.core.engines import variational  # noqa: F401


def get_engine(engine) -> BoundaryEngine:
    """Resolve an ``engine`` option value to a :class:`BoundaryEngine`.

    Accepts a registered name (str) or an engine instance; anything else is
    a caller bug and raises a ``TypeError`` naming the registered engines
    (the library's option-dispatch convention — no isinstance asserts)."""
    _ensure_builtin()
    if isinstance(engine, BoundaryEngine):
        return engine
    if isinstance(engine, str):
        hit = _REGISTRY.get(engine)
        if hit is not None:
            return hit
        raise TypeError(
            f"unknown boundary engine {engine!r}: registered engines are "
            f"{sorted(_REGISTRY)}")
    raise TypeError(
        f"expected a boundary-engine name or BoundaryEngine instance, got "
        f"{engine!r}: registered engines are {sorted(_REGISTRY)}")
