"""Variational boundary engine: ALS-fitted fixed-chi boundary MPS.

Where the zip-up engine truncates **greedily** — the einsumsvd at column
``j`` picks the best rank-chi split of everything absorbed so far, blind to
the columns still to its right — this engine solves the **global** fixed-chi
problem for one row absorption:

    minimize  || B  -  O · S ||_F   over MPS B with bond <= chi,

where ``O · S`` is the (implicitly represented, never materialized) product
of the PEPS row MPO with the incoming boundary MPS.  The optimization is
alternating least squares in site-canonical gauge (the MPO–MPS fitting of
Lubasch et al., arXiv:1405.3259; the variational boundary family surveyed
in Vanderstraeten et al., arXiv:2110.12726): with every tensor of ``B``
except site ``j`` held fixed and the complement kept orthonormal (mixed
canonical form), the optimal ``B_j`` is the plain projection

    B_j  =  L_j · (S_j O_j) · R_{j+1},

with ``L/R`` the left/right fit environments.  A left-to-right pass
QR-shifts the canonical center as it updates; a right-to-left pass mirrors
it; ``sweeps`` such round trips monotonically decrease the fit residual.
The initial guess is a cheap **zip-up pass** (the zipup engine itself, same
``svd`` option and PRNG key), so one sweep already starts from the greedy
solution and can only improve the Frobenius residual.

Cost: each local update contracts the same ``[L, S_j, (O_j|bra,ket), R]``
neighborhood the zip-up einsumsvd sees, so a full absorption costs
``O(sweeps)`` zip-up-like row passes plus the seed — the engine buys
accuracy per chi at a constant-factor FLOP premium (benchmarked in
``benchmarks/bench_engines.py``).

Planner contract: every environment step and local update is one function
``jax.jit``-compiled per **network signature** through
:func:`repro.core.planner.fused_fn` (tag ``"varfit"``), and every einsum
inside routes through :func:`repro.core.planner.cached_einsum` (path
cache).  All interior columns of a row share one signature, so after a
one-row warm-up the sweeps replay compiled executables across columns,
rows, and repeated absorptions — the same > 99% hit-rate regime as the
fused zip-up (asserted in ``tests/test_engines.py``).

No block/carry structure: an ALS sweep needs the whole row (it is a global
solve), so ``supports_blocks = False`` — the distributed pipeline runs
this engine row-local on one device between sharded layouts, and the SPMD
superstep rejects it (see docs/contraction.md, mode decision table).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.core.engines import BoundaryEngine, register_engine
from repro.core.svd_grad import qr_reg


def _fused(tag: str, builder, *tensors):
    """Run ``builder()``'s function on ``tensors``, jit-fused per signature.

    The signature is the operand shape/dtype tuple plus the device backend —
    every trace-time decision of these fixed-structure einsum+QR steps."""
    sig = (tuple(tuple(t.shape) for t in tensors),
           tuple(jnp.dtype(t.dtype).name for t in tensors),
           jax.default_backend())
    fn = planner.fused_fn(tag, sig, builder)
    return fn(*tensors)


def _qr_shift_right(b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """QR of ``b`` matricized as (left+dangles, right): returns
    (left-orthonormal Q with b's layout, r to absorb rightwards).

    ``qr_reg`` == ``jnp.linalg.qr`` forward; its ridge-regularized JVP is
    what keeps ``jax.grad`` through a variational-engine contraction from
    compounding ``1/sigma_min`` noise across the ALS sweeps (the canonical-
    shift QRs see the numerically rank-deficient bonds of circuit states)."""
    m = b.shape[-1]
    mat = b.reshape(-1, m)
    q, r = qr_reg(mat)
    return q.reshape(b.shape[:-1] + (q.shape[-1],)), r


def _lq_shift_left(b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """LQ of ``b`` matricized as (left, dangles+right): returns
    (r to absorb leftwards, right-orthonormal Q with b's layout)."""
    a = b.shape[0]
    mat = b.reshape(a, -1)
    qh, rh = qr_reg(mat.conj().T)
    q = qh.conj().T            # (k, dangles*right), right-orthonormal rows
    r = rh.conj().T            # (a, k)
    return r, q.reshape((q.shape[0],) + b.shape[1:])


# ---------------------------------------------------------------------------
# Per-site fit steps.  ``site`` is (s_j, o_j) one-layer or (s_j, bra_j,
# ket_j) two-layer; the T-network einsum strings below mirror the zip-up
# kernels' label conventions (see engines/zipup.py).
# ---------------------------------------------------------------------------

_NETS = {
    # nsite tensors: (M from left, B from M·R, L-advance, M from right,
    #                 B from L·M, R-advance)
    2: {  # one-layer: s (b,f,g)=(l,d,r), o (f,c,h,k)=(u,l,d,r)
        "Ml": ("bca,bfg,fchk->ahgk", "ahgk,gkm->ahm", "ahgk,ahn->gkn"),
        "Mr": ("gkm,bfg,fchk->bchm", "bca,bchm->ahm", "bchm,nhm->bcn"),
    },
    3: {  # two-layer: s (b,f,F,g), bra* (p,f,c,h,k), ket (p,F,C,H,K)
        "Ml": ("bcCa,bfFg,pfchk,pFCHK->ahHgkK", "ahHgkK,gkKm->ahHm",
               "ahHgkK,ahHn->gkKn"),
        "Mr": ("gkKm,bfFg,pfchk,pFCHK->bcChHm", "bcCa,bcChHm->ahHm",
               "bcChHm,nhHm->bcCn"),
    },
}


def _site_tensors(site: Sequence[jnp.ndarray]) -> List[jnp.ndarray]:
    """The T-network operands for one column (bra conjugated, two-layer)."""
    if len(site) == 3:
        s, bra, ket = site
        return [s, bra.conj(), ket]
    return list(site)


def _step_lr(L, site, R, last: bool):
    """One left-to-right local update: fit ``B_j``, QR-shift, advance L.

    Returns ``(B_j, L_next)``; at the last site the un-orthogonalized fit
    (carrying the norm) is kept and ``L_next`` is None."""
    ops = _site_tensors(site)
    net = _NETS[len(ops)]["Ml"]

    if last:
        def build():
            @jax.jit
            def run(L, *rest):
                R = rest[-1]
                m = planner.cached_einsum(net[0], L, *rest[:-1])
                return planner.cached_einsum(net[1], m, R)
            return run
        return _fused("varfit_lr_last", build, L, *ops, R), None

    def build():
        @jax.jit
        def run(L, *rest):
            R = rest[-1]
            m = planner.cached_einsum(net[0], L, *rest[:-1])
            b = planner.cached_einsum(net[1], m, R)
            q, _ = _qr_shift_right(b)
            return q, planner.cached_einsum(net[2], m, q.conj())
        return run
    return _fused("varfit_lr", build, L, *ops, R)


def _step_rl(R, site, L, first: bool):
    """One right-to-left local update: fit ``B_j``, LQ-shift, advance R.

    Returns ``(B_j, R_prev)``; at the first site the full fit is kept."""
    ops = _site_tensors(site)
    net = _NETS[len(ops)]["Mr"]

    if first:
        def build():
            @jax.jit
            def run(R, *rest):
                L = rest[-1]
                m = planner.cached_einsum(net[0], R, *rest[:-1])
                return planner.cached_einsum(net[1], L, m)
            return run
        return _fused("varfit_rl_first", build, R, *ops, L), None

    def build():
        @jax.jit
        def run(R, *rest):
            L = rest[-1]
            m = planner.cached_einsum(net[0], R, *rest[:-1])
            b = planner.cached_einsum(net[1], L, m)
            _, q = _lq_shift_left(b)
            return q, planner.cached_einsum(net[2], m, q.conj())
        return run
    return _fused("varfit_rl", build, R, *ops, L)


def _renv_step(R, site, b):
    """Extend the right fit environment over one column (uses conj(b))."""
    ops = _site_tensors(site)
    net = _NETS[len(ops)]["Mr"]

    def build():
        @jax.jit
        def run(R, *rest):
            b_ = rest[-1]
            m = planner.cached_einsum(net[0], R, *rest[:-1])
            return planner.cached_einsum(net[2], m, b_.conj())
        return run
    return _fused("varfit_renv", build, R, *ops, b)


def _canonicalize_right(bs: List[jnp.ndarray]) -> List[jnp.ndarray]:
    """Right-canonicalize an MPS in place (LQ sweep, right to left)."""
    bs = list(bs)
    for j in range(len(bs) - 1, 0, -1):
        def build():
            @jax.jit
            def run(prev, cur):
                r, q = _lq_shift_left(cur)
                nprev = jnp.tensordot(prev, r, axes=[[prev.ndim - 1], [0]])
                return nprev, q
            return run
        bs[j - 1], bs[j] = _fused("varfit_canon", build, bs[j - 1], bs[j])
    return bs


class VariationalEngine(BoundaryEngine):
    """ALS boundary-MPS fitting engine (module docstring has the math).

    Parameters
    ----------
    sweeps: full ALS round trips (left-to-right + right-to-left) per row
        absorption.  ``sweeps=0`` degenerates to the zip-up seed itself
        (useful for A/B isolation of the fitting gain).
    """

    name = "variational"
    supports_blocks = False

    def __init__(self, sweeps: int = 2):
        if sweeps < 0:
            raise ValueError(f"sweeps must be >= 0, got {sweeps}")
        self.sweeps = sweeps

    def __repr__(self):
        return f"VariationalEngine(sweeps={self.sweeps})"

    # -- fitting core -------------------------------------------------------

    def _fit(self, sites: List[Sequence[jnp.ndarray]],
             seed: List[jnp.ndarray]) -> List[jnp.ndarray]:
        """ALS-fit an MPS (seeded by ``seed``) to the column network
        ``sites`` (per column: the T-network operand tuple)."""
        ncol = len(sites)
        if ncol < 2 or self.sweeps == 0:
            return seed    # a single column is exact; sweeps=0 is the seed
        nb = len(_site_tensors(sites[0])) + 1  # env rank: bonds + B bond
        dtype = seed[0].dtype
        triv = jnp.ones((1,) * nb, dtype=dtype)
        bs = _canonicalize_right(seed)
        renvs: List = [None] * (ncol + 1)
        renvs[ncol] = triv
        for j in range(ncol - 1, 0, -1):
            renvs[j] = _renv_step(renvs[j + 1], sites[j], bs[j])
        for _ in range(self.sweeps):
            # left-to-right pass (leaves bs left-canonical, center at -1)
            lenvs: List = [triv] + [None] * ncol
            for j in range(ncol):
                lastp = j == ncol - 1
                bj, ln = _step_lr(lenvs[j], sites[j], renvs[j + 1], lastp)
                bs[j] = bj
                if not lastp:
                    lenvs[j + 1] = ln
            # right-to-left pass (leaves bs right-canonical, center at 0,
            # and rebuilds renvs for the next sweep)
            for j in range(ncol - 1, -1, -1):
                firstp = j == 0
                bj, rn = _step_rl(renvs[j + 1], sites[j], lenvs[j], firstp)
                bs[j] = bj
                if not firstp:
                    renvs[j] = rn
        return bs

    def _fit_with_policy(self, sites, seed, svd):
        """Run :meth:`_fit` under the precision policy the svd option
        carries: with the mixed policy the site tensors and the zip-up seed
        are demoted one storage tier for the ALS sweeps (the local solves
        are where the FLOPs are) and the fitted boundary is promoted back,
        mirroring :class:`repro.core.precision.PrecisionWrapped`.  The
        exact policy is a no-op passthrough."""
        from repro.core.precision import demote, policy_of
        pol = policy_of(svd)
        if not pol.demote:
            return self._fit(sites, seed)
        orig_dtype = seed[0].dtype
        sites_d = [tuple(demote(t, pol) for t in site) for site in sites]
        seed_d = [demote(t, pol) for t in seed]
        out = self._fit(sites_d, seed_d)
        return [t.astype(orig_dtype) for t in out]

    # -- BoundaryEngine interface -------------------------------------------

    def absorb_onelayer(self, svec, row, chi, svd, key):
        from repro.core.engines.zipup import _zipup_row
        seed = _zipup_row(svec, row, chi, svd, key)
        return self._fit_with_policy(
            [(svec[j], row[j]) for j in range(len(svec))], seed, svd)

    def absorb_twolayer(self, svec, bra_row, ket_row, chi, svd, key,
                        constrain_carry=None):
        # constrain_carry pins the *zip-up* carry's sharding; the ALS pass
        # is row-local (no carry), so it only applies to the seed.
        from repro.core.engines.zipup import _zipup_row_twolayer
        seed = _zipup_row_twolayer(svec, bra_row, ket_row, chi, svd, key,
                                   constrain_carry=constrain_carry)
        return self._fit_with_policy(
            [(svec[j], bra_row[j], ket_row[j]) for j in range(len(svec))],
            seed, svd)

    def final_scalar_onelayer(self, svec):
        from repro.core.engines.zipup import _mps_to_scalar
        return _mps_to_scalar(svec)

    def final_scalar_twolayer(self, svec):
        from repro.core.engines.zipup import _twolayer_final_scalar
        return _twolayer_final_scalar(svec)


register_engine(VariationalEngine())
