"""Zip-up boundary engine (paper Alg. 3) — the library's default.

This module owns the zip-up machinery that used to live inline in
:mod:`repro.core.bmps`: the shard-local block kernels
(:func:`zipup_block` / :func:`zipup_block_twolayer`), the whole-row
absorptions built from them, and the final-scalar closings.  The move is a
pure extraction — same einsumsvd call sequence, same PRNG key consumption,
same planner signatures — and :mod:`repro.core.bmps` re-exports every
public name, so pre-refactor call sites (including
:mod:`repro.core.distributed` and :mod:`repro.core.spmd`, which compose
the block kernels across devices) keep working bit-identically.

The engine-facing wrapper is :class:`ZipUpEngine` (see
:mod:`repro.core.engines` for the :class:`~repro.core.engines.BoundaryEngine`
contract).  Because a zip-up row absorption is expressible as composable
*column blocks* with a single carry tensor, this engine sets
``supports_blocks = True`` and is the only engine the distributed
halo-exchange pipeline and the compiled SPMD superstep can schedule.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.einsumsvd import einsumsvd
from repro.core.engines import BoundaryEngine, register_engine
from repro.kernels.zipup_block import (
    first_column_onelayer,
    first_column_twolayer,
    pair_merge,
)


def _keys(key, n):
    if key is None:
        key = jax.random.PRNGKey(0)
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# One-layer: PEPS without physical indices, site tensors (u, l, d, r)
# ---------------------------------------------------------------------------

def zipup_block(v: Optional[jnp.ndarray], svec_block: Sequence[jnp.ndarray],
                row_block: Sequence[jnp.ndarray], chi: int, svd,
                keys: Sequence, first: bool, last: bool):
    """Shard-local one-layer zip-up kernel over a contiguous column block.

    Absorbs ``row_block`` (an MPO slice) into the matching boundary slice
    ``svec_block``, threading the carry tensor ``v`` (axes ``(a, e, b, c)``:
    truncated bond, dangling, boundary bond, MPO bond) through the block.
    ``first`` blocks initialize the carry from column 0 (no truncation);
    ``last`` blocks close it into the final boundary tensor.

    Returns ``(out, carry)``: the einsumsvd at block-local column ``j``
    emits the *output boundary tensor of the previous column*, so a block
    covering columns ``[lo, hi)`` returns tensors for columns
    ``[lo-1, hi-1)`` (plus column ``hi-1`` when ``last``) and the carry for
    column ``hi`` (``None`` when ``last``).  ``keys[j]`` must be the row's
    per-column key for the block's ``j``-th column — the orchestration
    (single-device or distributed) slices one row-level key split so both
    execute identical arithmetic.
    """
    out: List[jnp.ndarray] = []
    j0 = 0
    if first:
        # V0: contract S_0 (b,f,g) with O_0 (f,c,h,k); left bonds b,c are dim 1.
        # Kernel-dispatched (site "zipup_first_onelayer"); the dense path is
        # verbatim the original einsum.
        s0, o0 = svec_block[0], row_block[0]
        v = first_column_onelayer(s0, o0)
        b, c = v.shape[0], v.shape[1]
        v = v.reshape(b * c, v.shape[2], v.shape[3], v.shape[4])  # (a, e, b', c')
        j0 = 1
    for j in range(j0, len(svec_block)):
        sj, oj = svec_block[j], row_block[j]
        left, right = einsumsvd(
            svd,
            [v, sj, oj],
            ["aebc", "bfg", "fchk"],
            row="ae", col="hgk",
            rank=chi, absorb="right", key=keys[j],
        )
        out.append(left)                       # (a, e, m) == (l, d, r)
        # right: (m, h, g, k) == next V's (a, e, b, c)
        v = right
    if last:
        # last V: right bonds g,k are dim 1
        m, h = v.shape[0], v.shape[1]
        out.append(v.reshape(m, h, v.shape[2] * v.shape[3]))
        v = None
    return out, v


def _zipup_row(svec: List[jnp.ndarray], row: Sequence[jnp.ndarray], chi: int,
               svd, key) -> List[jnp.ndarray]:
    """Alg. 3: approximately apply one PEPS row (as an MPO) to the boundary
    MPS ``svec``; zip-up with einsumsvd, truncating to ``chi``."""
    out, _ = zipup_block(None, svec, row, chi, svd, _keys(key, len(svec)),
                         first=True, last=True)
    return out


def _mps_to_scalar(svec: List[jnp.ndarray]) -> jnp.ndarray:
    """Contract an MPS whose dangling (d) indices are all dim 1."""
    acc = jnp.ones((1,), dtype=svec[0].dtype)
    for t in svec:
        mat = t.reshape(t.shape[0], t.shape[2])
        acc = acc @ mat
    return acc.reshape(())


# ---------------------------------------------------------------------------
# Two-layer: <bra|ket> with layers kept implicit (two-layer IBMPS)
# ---------------------------------------------------------------------------

def zipup_block_twolayer(v: Optional[jnp.ndarray],
                         svec_block: Sequence[jnp.ndarray],
                         bra_block, ket_block, chi: int, svd,
                         keys: Sequence, first: bool, last: bool,
                         constrain_carry=None):
    """Shard-local two-layer zip-up kernel over a contiguous column block.

    The two-layer sibling of :func:`zipup_block`; identical block/carry
    semantics, with carry axes ``(a, e1, e2, b, c1, c2)`` (truncated bond,
    bra/ket dangling, boundary bond, bra/ket pair bonds).  Boundary tensors
    are truncated; the row's pair bonds (c1,c2 / k1,k2) stay separate — the
    implicit structure that gives two-layer IBMPS its complexity edge
    (Table II).  The carry is the only tensor a distributed sweep ships
    between neighboring shards (the forward halo)."""
    out: List[jnp.ndarray] = []
    j0 = 0
    if first:
        tb0, tk0 = bra_block[0].conj(), ket_block[0]
        s0 = svec_block[0]
        # S_0:(b,f1,f2,g), bra:(p,f1,c1,h1,k1), ket:(p,f2,c2,h2,k2); b,c1,c2 dim 1
        # Kernel-dispatched (site "zipup_first_twolayer").
        v = first_column_twolayer(s0, tb0, tk0)
        sh = v.shape
        v = v.reshape(sh[0] * sh[1] * sh[2], sh[3], sh[4], sh[5], sh[6], sh[7])
        # v: (a, e1, e2, b, c1, c2)
        j0 = 1
    for j in range(j0, len(svec_block)):
        sj = svec_block[j]
        tb, tk = bra_block[j].conj(), ket_block[j]
        left, right = einsumsvd(
            svd,
            [v, sj, tb, tk],
            ["aeEbcC", "bfFg", "pfchk", "pFCHK"],
            row="aeE", col="hHgkK",
            rank=chi, absorb="right", key=keys[j],
        )
        out.append(left)                       # (a, e1, e2, m)
        v = right                              # (m, h1, h2, g, k1, k2)
        if constrain_carry is not None:
            v = constrain_carry(v)
    if last:
        m = v.shape[0]
        out.append(v.reshape(m, v.shape[1], v.shape[2],
                             v.shape[3] * v.shape[4] * v.shape[5]))
        v = None
    return out, v


def _zipup_row_twolayer(svec: List[jnp.ndarray], bra_row, ket_row, chi, svd,
                        key, constrain_carry=None) -> List[jnp.ndarray]:
    """One full row absorption = :func:`zipup_block_twolayer` as one block."""
    out, _ = zipup_block_twolayer(None, svec, bra_row, ket_row, chi, svd,
                                  _keys(key, len(svec)), first=True, last=True,
                                  constrain_carry=constrain_carry)
    return out


def _init_twolayer_boundary(bra_row, ket_row) -> List[jnp.ndarray]:
    """First-row boundary: merge only the horizontal pair bonds."""
    out = []
    for tb, tk in zip(bra_row, ket_row):
        # (p,1,l1,d1,r1)* x (p,1,l2,d2,r2) -> (l1 l2, d1, d2, r1 r2)
        # Kernel-dispatched (site "pair_merge").
        pair = pair_merge(tb.conj(), tk)
        s = pair.shape
        out.append(pair.reshape(s[0] * s[1], s[2], s[3], s[4] * s[5]))
    return out


def _twolayer_final_scalar(svec: List[jnp.ndarray]) -> jnp.ndarray:
    acc = jnp.ones((1,), dtype=svec[0].dtype)
    for t in svec:
        mat = t.reshape(t.shape[0], t.shape[-1])
        acc = acc @ mat
    return acc.reshape(())


def trivial_twolayer_boundary(ncol: int, dtype) -> List[jnp.ndarray]:
    one = jnp.ones((1, 1, 1, 1), dtype=dtype)
    return [one for _ in range(ncol)]


# ---------------------------------------------------------------------------
# The engine wrapper
# ---------------------------------------------------------------------------

class ZipUpEngine(BoundaryEngine):
    """Row absorption by zip-up truncation (einsumsvd per column).

    The default engine: one einsumsvd per column, carry threaded left to
    right, truncation interleaved with the MPO application.  Cheapest per
    row; the truncation at column ``j`` cannot see columns ``> j``, which is
    the accuracy gap the variational engine closes at fixed chi.
    """
    name = "zipup"
    supports_blocks = True

    def absorb_onelayer(self, svec, row, chi, svd, key):
        return _zipup_row(svec, row, chi, svd, key)

    def absorb_twolayer(self, svec, bra_row, ket_row, chi, svd, key,
                        constrain_carry=None):
        return _zipup_row_twolayer(svec, bra_row, ket_row, chi, svd, key,
                                   constrain_carry=constrain_carry)

    def final_scalar_onelayer(self, svec):
        return _mps_to_scalar(svec)

    def final_scalar_twolayer(self, svec):
        return _twolayer_final_scalar(svec)


register_engine(ZipUpEngine())
