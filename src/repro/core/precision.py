"""Precision policy: the per-precision error budget replacing "always 1e-16".

Until this module, every contraction in the library implicitly promised
full-precision agreement with its dense reference (the pinned goldens of
``tests/test_engines.py`` assert <= 1e-12).  Mixed-precision compute — the
standard accelerator trade (bf16 multiplicands, f32 accumulation; one-tier
storage demotion) — breaks that blanket promise, so it only ships together
with a *documented, tested* budget per workload:

* ``precision="exact"`` (default, and what CPU CI runs) — the identity
  policy.  Bit-identical to the pre-policy code: the svd option is passed
  through unwrapped, kernels multiply in the operand dtype.
* ``precision="mixed"`` — around every einsumsvd refactorization the
  operand tensors are demoted one storage tier (f64 -> f32, c128 -> c64;
  f32/bf16/c64 are fixed points), the solve runs in the demoted dtype, and
  the factors are promoted back so downstream shapes/dtypes are unchanged.
  While the solve runs, the Pallas kernel sites multiply in bf16
  (:func:`repro.kernels.dispatch.set_kernel_compute`) with f32
  accumulation — on TPU that is the MXU's native fast path.  There is no
  bf16 *emulation* on the dense path: off-kernel math runs in the demoted
  storage dtype, so CPU validation measures the storage-demotion error and
  TPU adds the (bounded, kernel-local) bf16 multiplicand error.

The budgets live in :data:`ERROR_BUDGETS` — the single source of truth.
``docs/contraction.md`` renders the same table
(:func:`budget_table_markdown`) and ``tests/test_precision.py`` parses the
doc back and asserts equality, so docs and tests cannot drift; the same
tests then *measure* each workload against its budget.

Threading: ``BMPS(..., precision=...)`` / ``DistributedBMPS`` wrap their
``svd`` option in :class:`PrecisionWrapped` at construction, so every code
path that forwards ``option.svd`` (engines, distributed halo pipeline, the
SPMD superstep, cached environments, the full update's einsumsvd seed)
inherits the policy with no signature changes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """How much numerical precision a contraction is allowed to give up.

    ``demote`` — demote operand storage one tier around each einsumsvd
    solve (f64 -> f32, c128 -> c64); results are promoted back.
    ``kernel_compute`` — multiplicand dtype inside Pallas kernel sites
    while a solve under this policy runs (accumulation is always f32);
    ``None`` keeps the operand dtype.
    """
    name: str
    demote: bool = False
    kernel_compute: Optional[str] = None

    def __str__(self):
        return self.name


EXACT = PrecisionPolicy("exact")
MIXED = PrecisionPolicy("mixed", demote=True, kernel_compute="bfloat16")

_POLICIES = {"exact": EXACT, "mixed": MIXED}


def resolve_precision(precision) -> PrecisionPolicy:
    """Accept a policy name or instance; TypeError names the choices."""
    if isinstance(precision, PrecisionPolicy):
        return precision
    if isinstance(precision, str) and precision in _POLICIES:
        return _POLICIES[precision]
    raise TypeError(
        f"unknown precision {precision!r}: expected one of "
        f"{sorted(_POLICIES)} or a PrecisionPolicy instance")


# ---------------------------------------------------------------------------
# Dtype demotion/promotion
# ---------------------------------------------------------------------------

_DEMOTE = {
    jnp.float64.dtype: jnp.float32.dtype,
    jnp.complex128.dtype: jnp.complex64.dtype,
}


def demote_dtype(dtype, policy: PrecisionPolicy):
    if not policy.demote:
        return jnp.dtype(dtype)
    return _DEMOTE.get(jnp.dtype(dtype), jnp.dtype(dtype))


def demote(x: jnp.ndarray, policy: PrecisionPolicy) -> jnp.ndarray:
    target = demote_dtype(x.dtype, policy)
    return x if x.dtype == target else x.astype(target)


def real_dtype(dtype):
    """The real scalar dtype matching ``dtype`` (c128 -> f64, c64 -> f32)."""
    return jnp.zeros((), dtype).real.dtype


# ---------------------------------------------------------------------------
# The svd-option wrapper
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrecisionWrapped:
    """An einsumsvd option wrapped with a precision policy.

    Callable with the option protocol ``(op, rank, key) -> (u, s, v)``:
    demotes the implicit operator's tensors per the policy, points the
    kernel sites at the policy's compute dtype for the duration of the
    solve (restored in ``finally``; the dispatch signature keys the
    planner's fused cache, so exact and mixed solves never share a
    compiled executable), and promotes the factors back to the original
    operand dtype.  The exact policy never constructs this wrapper —
    :func:`wrap_svd` returns the inner option untouched."""
    inner: object
    policy: PrecisionPolicy

    def __call__(self, op, rank: int, key=None):
        from repro.core.rsvd import ImplicitOperator
        from repro.kernels import dispatch
        pol = self.policy
        orig_dtype = jnp.result_type(*[t.dtype for t in op.tensors])
        tensors = list(op.tensors)
        scale = None
        if pol.demote:
            # Per-solve operand scaling: normalize every tensor to unit
            # max-abs BEFORE demotion and fold the product of scales back
            # into s.  Without this, unnormalized networks (zip-up carries
            # drift multiplicatively) push the demoted spectrum under the
            # f32 Gram-QR eigenvalue clamp and the solve collapses to zero
            # — scaling is what makes the mixed policy magnitude-safe.
            scales = []
            for t in tensors:
                c = jnp.max(jnp.abs(t))
                scales.append(jnp.where(jnp.isfinite(c) & (c > 0), c, 1.0))
            tensors = [t / c for t, c in zip(tensors, scales)]
            scale = scales[0]
            for c in scales[1:]:
                scale = scale * c
        tensors = [demote(t, pol) for t in tensors]
        changed = any(t.dtype != o.dtype for t, o in zip(tensors, op.tensors))
        if changed or scale is not None:
            op = ImplicitOperator(tensors, list(op.subscripts), op.row, op.col)
        prev = dispatch.set_kernel_compute(pol.kernel_compute)
        try:
            u, s, v = self.inner(op, rank, key)
        finally:
            dispatch.set_kernel_compute(prev)
        if changed:
            u = u.astype(orig_dtype)
            v = v.astype(orig_dtype)
            s = s.astype(real_dtype(orig_dtype))
        if scale is not None:
            s = s * scale.astype(s.dtype)
        return u, s, v


def wrap_svd(svd, precision) -> object:
    """Apply a precision policy to an einsumsvd option.

    Idempotent and re-entrant: an already-wrapped option is unwrapped
    first, so ``dataclasses.replace(opt, precision=...)`` flips cleanly in
    both directions.  The exact policy returns the bare option (bit-
    identical construction: ``BMPS(chi)`` before and after this PR build
    equal options)."""
    policy = resolve_precision(precision)
    if isinstance(svd, PrecisionWrapped):
        svd = svd.inner
    if not policy.demote and policy.kernel_compute is None:
        return svd    # identity policy: no wrapper, bit-identical options
    return PrecisionWrapped(svd, policy)


def policy_of(svd) -> PrecisionPolicy:
    """The policy an (optionally wrapped) svd option carries."""
    if isinstance(svd, PrecisionWrapped):
        return svd.policy
    return EXACT


# ---------------------------------------------------------------------------
# The error-budget table (single source of truth; docs render it, tests
# parse the doc back and assert equality, then measure each workload)
# ---------------------------------------------------------------------------

#: Per-(workload, precision) relative-error budgets.  ``exact`` budgets are
#: measured against the pinned goldens / dense references (the pre-existing
#: 1e-12 contract); ``mixed`` budgets are measured against the *exact-path
#: result of the identical contraction* (same chi, engine, PRNG key), so
#: they isolate the precision policy from the truncation error.  Values
#: were measured on the acceptance cases (see each entry's ``case``) and
#: padded ~10x for cross-platform headroom.
ERROR_BUDGETS: Dict[str, Dict[str, object]] = {
    "contract_onelayer": {
        "case": "4x4 random one-layer D=3 grid, chi=8 zip-up",
        "exact": 1e-12,
        "mixed": 1e-4,
    },
    "contract_twolayer": {
        "case": "4x4 TFI D=3 ITE state, norm via chi=8 two-layer zip-up",
        "exact": 1e-12,
        "mixed": 1e-5,
    },
    "amplitude": {
        "case": "3x3 RQC (8 layers), one amplitude vs exact statevector",
        "exact": 1e-12,
        "mixed": 2e-5,
    },
    "full_update_ite_step": {
        "case": "one full-update ITE step on the 4x4 TFI D=3 state (energy)",
        "exact": 1e-12,
        "mixed": 5e-6,
    },
    "kernel_bf16_gemm": {
        "case": "forced-Pallas bf16-multiplicand gram/tall-apply vs f32 dense",
        "exact": 1e-12,
        "mixed": 2e-2,
    },
}


def error_budget(workload: str, precision) -> float:
    """The documented budget for ``workload`` under ``precision``."""
    policy = resolve_precision(precision)
    try:
        return float(ERROR_BUDGETS[workload][policy.name])
    except KeyError:
        raise KeyError(
            f"no budget for workload {workload!r} / precision "
            f"{policy.name!r}: known workloads {sorted(ERROR_BUDGETS)}")


def budget_table_markdown() -> str:
    """The budget table as GitHub markdown — docs/contraction.md embeds
    exactly this rendering; tests/test_precision.py parses it back."""
    lines = [
        "| workload | acceptance case | exact | mixed |",
        "|---|---|---|---|",
    ]
    for name, row in ERROR_BUDGETS.items():
        lines.append(f"| `{name}` | {row['case']} | {row['exact']:.0e} "
                     f"| {row['mixed']:.0e} |")
    return "\n".join(lines)
