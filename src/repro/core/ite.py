"""Imaginary time evolution (paper Sections II-D1, VI-D1).

TEBD with first-order Trotter-Suzuki: one ITE step applies
``exp(-tau * c_i * H_i)`` for every local term of the Hamiltonian, using a
(truncating) two-site update — the QR simple update (``QRUpdate``), the
direct einsumsvd update (``DirectUpdate``), or the environment-aware full
update (``FullUpdate``, Lubasch et al. arXiv:1405.3259).  Diagonal
(next-nearest-neighbour) terms are routed with SWAP chains automatically by
``apply_operator``.

With ``FullUpdate`` the loop maintains cached top/bottom row environments
and refreshes them every ``update.env_refresh_every`` gate applications;
every bond truncation then costs only a strip contraction + a jit-fused
ALS.  The per-bond truncation fidelities are aggregated into the result.

The Rayleigh quotient <psi|H|psi>/<psi|psi> (via cached-environment
expectation) tracks convergence to the ground state.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gates as G
from repro.core import planner
from repro.core import statevector as sv
from repro.core.bmps import BMPS
from repro.core.environments import row_environments
from repro.core.expectation import expectation
from repro.core.observable import Observable
from repro.core.peps import (FullUpdate, PEPS, apply_operator, check_update,
                             normalize_sites)


def trotter_moments(obs: Observable, tau: float):
    """One first-order Trotter step: [(gate, sites), ...] for exp(-tau*H)."""
    moments = []
    for term in obs:
        g = G.trotter_gate(term.coeff * term.matrix, tau)
        moments.append((g, list(term.sites)))
    return moments


@dataclasses.dataclass
class ITEResult:
    state: PEPS
    energies: List[float]
    steps: List[int]
    # planner cache counters over the run (path/fused hit rates) — the
    # evolution loop re-applies the same Trotter moments every step, so
    # after step 1 the einsumsvd engine should be all cache hits.
    planner_stats: Optional[dict] = None
    # FullUpdate only: per measurement point, the worst (minimum) bond
    # truncation fidelity observed since the previous measurement — the
    # cheap environment-metric estimate |<ab|E|theta>|^2 normalized (see
    # repro.core.full_update).  None for QRUpdate/DirectUpdate runs.
    fidelities: Optional[List[float]] = None


def ite_run(
    state: PEPS,
    obs: Observable,
    tau: float,
    steps: int,
    update,
    contract: BMPS,
    measure_every: int = 10,
    key=None,
    callback: Optional[Callable] = None,
) -> ITEResult:
    """Run TEBD imaginary time evolution on a PEPS.

    ``update`` selects the two-site truncation tier: :class:`QRUpdate`
    (simple update), :class:`DirectUpdate`, or :class:`FullUpdate`
    (environment-aware; row environments are cached and refreshed every
    ``update.env_refresh_every`` gate applications)."""
    check_update(update)
    if key is None:
        key = jax.random.PRNGKey(2020)
    moments = trotter_moments(obs, tau)
    energies, measured_at = [], []
    planner_before = planner.stats()

    is_full = isinstance(update, FullUpdate)
    fidelities: Optional[List[float]] = [] if is_full else None
    envs = None
    since_refresh = 0
    if is_full:
        from repro.core import full_update as _fu
        _fu.drain_fidelities()  # start the log window fresh

    for step in range(steps):
        for g, sites in moments:
            key, sub = jax.random.split(key)
            if is_full and len(sites) == 2:
                s0, s1 = state.coords(sites[0]), state.coords(sites[1])
                if (envs is None or since_refresh >= update.env_refresh_every
                        or not _fu.envs_compatible(state, s0, s1, envs)):
                    key, ek = jax.random.split(key)
                    envs = row_environments(state, _fu.env_option(update), ek)
                    since_refresh = 0
            state = apply_operator(state, g, sites, update, key=sub, envs=envs)
            since_refresh += 1
        # environments survive normalize_sites (the positive-fixed metric is
        # invariant under uniform rescales) and step boundaries — only the
        # refresh cadence and bond-dimension growth invalidate them
        state = normalize_sites(state)
        if (step + 1) % measure_every == 0 or step == steps - 1:
            key, sub = jax.random.split(key)
            e = float(jnp.real(expectation(state, obs, contract, use_cache=True,
                                           key=sub)))
            energies.append(e)
            measured_at.append(step + 1)
            if is_full:
                window = _fu.drain_fidelities()
                fidelities.append(min(window) if window else float("nan"))
            if callback is not None:
                callback(step + 1, e, state)
    return ITEResult(state, energies, measured_at,
                     planner.stats_since(planner_before), fidelities)


def ite_statevector(nrow: int, ncol: int, obs: Observable, tau: float,
                    steps: int) -> Tuple[jnp.ndarray, float]:
    """Reference: the same Trotterized ITE applied to the exact statevector.

    This is the paper's \"state vector simulation after 1000 ITE steps\"
    baseline for Fig. 13."""
    vec = sv.zeros(nrow * ncol)
    moments = trotter_moments(obs, tau)
    for _ in range(steps):
        for g, sites in moments:
            vec = sv.apply_gate(vec, g, sites)
        vec = sv.normalize(vec)
    energy = float(jnp.real(sv.expectation(vec, obs.as_tuples())))
    return vec, energy
