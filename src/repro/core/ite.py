"""Imaginary time evolution (paper Sections II-D1, VI-D1).

TEBD with first-order Trotter-Suzuki: one ITE step applies
``exp(-tau * c_i * H_i)`` for every local term of the Hamiltonian, using a
(truncating) two-site update — the QR simple update (``QRUpdate``), the
direct einsumsvd update (``DirectUpdate``), or the environment-aware full
update (``FullUpdate``, Lubasch et al. arXiv:1405.3259).  Diagonal
(next-nearest-neighbour) terms are routed with SWAP chains automatically by
``apply_operator``.

With ``FullUpdate`` the loop maintains cached top/bottom row environments
and refreshes them every ``update.env_refresh_every`` gate applications;
every bond truncation then costs only a strip contraction + a jit-fused
ALS.  The per-bond truncation fidelities are aggregated into the result.

The Rayleigh quotient <psi|H|psi>/<psi|psi> (via cached-environment
expectation) tracks convergence to the ground state.

Production hardening (see ``docs/robustness.md``):

* ``checkpoint_dir=``/``checkpoint_every=`` snapshot the *complete* loop
  state — site tensors, ``log_scale``, the PRNG key, the energy trace, the
  cached row environments and refresh counter, the undrained fidelity
  window — through :class:`repro.checkpoint.manager.CheckpointManager`
  (async write, atomic publish).  A killed run re-invoked with the same
  arguments resumes from the latest checkpoint and reproduces the
  uninterrupted run's per-step energies **bit-identically**: environments
  and the refresh counter are part of the snapshot precisely so the resume
  consumes the PRNG key stream at the same offsets the uninterrupted run
  would have (an extra forced env refresh would split the key once more
  and diverge every subsequent truncation).
* ``guard=`` activates the runtime guard (:mod:`repro.core.runtime_guard`)
  over the whole evolution: NaN/Inf or norm collapse in any einsumsvd
  truncation and fidelity-floor violations in the full update retry under
  the escalation ladder; the structured :class:`GuardReport` lands in
  ``ITEResult.guard``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gates as G
from repro.core import planner
from repro.core import runtime_guard
from repro.core import statevector as sv
from repro.core.bmps import BMPS
from repro.core.environments import row_environments
from repro.core.expectation import expectation
from repro.core.observable import Observable
from repro.core.peps import (FullUpdate, PEPS, apply_operator, check_update,
                             normalize_sites)


def trotter_moments(obs: Observable, tau: float):
    """One first-order Trotter step: [(gate, sites), ...] for exp(-tau*H)."""
    moments = []
    for term in obs:
        g = G.trotter_gate(term.coeff * term.matrix, tau)
        moments.append((g, list(term.sites)))
    return moments


@dataclasses.dataclass
class ITEResult:
    state: PEPS
    energies: List[float]
    steps: List[int]
    # planner cache counters over the run (path/fused hit rates) — the
    # evolution loop re-applies the same Trotter moments every step, so
    # after step 1 the einsumsvd engine should be all cache hits.  For a
    # resumed run this covers the WHOLE logical run: the checkpointed
    # counter delta of the earlier process plus this process's delta.
    planner_stats: Optional[dict] = None
    # FullUpdate only: per measurement point, the worst (minimum) bond
    # truncation fidelity observed since the previous measurement — the
    # cheap environment-metric estimate |<ab|E|theta>|^2 normalized (see
    # repro.core.full_update).  None for QRUpdate/DirectUpdate runs.
    fidelities: Optional[List[float]] = None
    # Runtime-guard report (guard= runs only): every detected failure and
    # recovery over the evolution.  None when no guard was active.
    guard: Optional[runtime_guard.GuardReport] = None
    # The checkpoint step this run resumed from, or None for a fresh run.
    resumed_from: Optional[int] = None


# ---------------------------------------------------------------------------
# Checkpoint encode/decode (flat {path: array} trees; see CheckpointManager)
# ---------------------------------------------------------------------------

def _ite_snapshot(state: PEPS, key, energies, measured_at, fidelities,
                  fid_window, since_refresh, envs, planner_delta,
                  next_step: int) -> dict:
    """The complete ITE loop state as one flat checkpointable tree.

    ``log_scale`` is a PEPS *aux* field (not a pytree leaf), the cached
    environments and ``since_refresh`` decide future PRNG-key consumption,
    and the fidelity window is mid-measurement state — all of it must ride
    in the snapshot for the resume to be bit-identical."""
    tree = {}
    for i in range(state.nrow):
        for j in range(state.ncol):
            tree[f"sites/{i}_{j}"] = state.sites[i][j]
    tree["log_scale"] = jnp.asarray(state.log_scale)
    tree["key"] = key
    tree["energies"] = np.asarray(energies, dtype=np.float64)
    tree["measured_at"] = np.asarray(measured_at, dtype=np.int64)
    tree["since_refresh"] = np.asarray(since_refresh, dtype=np.int64)
    if fidelities is not None:
        tree["fidelities"] = np.asarray(fidelities, dtype=np.float64)
        tree["fid_window"] = np.asarray(fid_window, dtype=np.float64)
    if envs is not None:
        top, bottom = envs
        for lvl, mps in enumerate(top):
            for c, t in enumerate(mps):
                tree[f"envs_top/{lvl}/{c}"] = t
        for lvl, mps in enumerate(bottom):
            for c, t in enumerate(mps):
                tree[f"envs_bot/{lvl}/{c}"] = t
    meta = {"next_step": next_step, "planner_delta": planner_delta}
    tree["meta_json"] = np.array(json.dumps(meta))
    return tree


def _decode_env_levels(flat: dict, prefix: str):
    levels: dict = {}
    for k, v in flat.items():
        if not k.startswith(prefix):
            continue
        _, lvl, c = k.split("/")
        levels.setdefault(int(lvl), {})[int(c)] = jnp.asarray(v)
    return [[levels[l][c] for c in sorted(levels[l])]
            for l in sorted(levels)] or None


def _ite_restore(flat: dict, nrow: int, ncol: int):
    """Invert :func:`_ite_snapshot` -> (state, key, loop-state dict)."""
    sites = [[jnp.asarray(flat[f"sites/{i}_{j}"]) for j in range(ncol)]
             for i in range(nrow)]
    state = PEPS(sites, jnp.asarray(flat["log_scale"]))
    key = jnp.asarray(flat["key"])
    meta = json.loads(str(flat["meta_json"][()]))
    top = _decode_env_levels(flat, "envs_top/")
    bot = _decode_env_levels(flat, "envs_bot/")
    return state, key, {
        "energies": [float(e) for e in flat["energies"]],
        "measured_at": [int(s) for s in flat["measured_at"]],
        "fidelities": ([float(f) for f in flat["fidelities"]]
                       if "fidelities" in flat else None),
        "fid_window": ([float(f) for f in flat["fid_window"]]
                       if "fid_window" in flat else []),
        "since_refresh": int(flat["since_refresh"]),
        "envs": (top, bot) if top is not None else None,
        "next_step": int(meta["next_step"]),
        "planner_delta": meta.get("planner_delta") or {},
    }


def _merge_planner_stats(prior: dict, current: dict) -> dict:
    """Whole-logical-run counters: sum the deltas, keep current cache sizes."""
    out = dict(current)
    for k, v in prior.items():
        if k.endswith("_cache_size"):
            continue
        out[k] = out.get(k, 0) + v
    return out


def ite_run(
    state: PEPS,
    obs: Observable,
    tau: float,
    steps: int,
    update,
    contract: BMPS,
    measure_every: int = 10,
    key=None,
    callback: Optional[Callable] = None,
    *,
    guard=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    checkpoint_keep: int = 3,
    resume: bool = True,
) -> ITEResult:
    """Run TEBD imaginary time evolution on a PEPS.

    ``update`` selects the two-site truncation tier: :class:`QRUpdate`
    (simple update), :class:`DirectUpdate`, or :class:`FullUpdate`
    (environment-aware; row environments are cached and refreshed every
    ``update.env_refresh_every`` gate applications).

    ``guard`` activates the runtime guard for the whole run (``True`` for
    defaults, or a :class:`~repro.core.runtime_guard.GuardConfig`).

    ``checkpoint_dir`` + ``checkpoint_every=N`` snapshot the full loop
    state every N steps (async, atomic); with ``resume=True`` (default) a
    re-invocation picks up from the latest checkpoint in the directory and
    reproduces the uninterrupted run bit-identically (see module
    docstring).  ``checkpoint_keep`` is the GC retention."""
    check_update(update)
    if key is None:
        key = jax.random.PRNGKey(2020)
    moments = trotter_moments(obs, tau)
    energies, measured_at = [], []
    planner_before = planner.stats()
    prior_planner_delta: dict = {}

    is_full = isinstance(update, FullUpdate)
    fidelities: Optional[List[float]] = [] if is_full else None
    envs = None
    since_refresh = 0
    if is_full:
        from repro.core import full_update as _fu
        _fu.drain_fidelities()  # start the log window fresh

    manager = None
    start_step = 0
    resumed_from = None
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(checkpoint_dir, keep=checkpoint_keep)
        latest = manager.latest_step() if resume else None
        if latest is not None:
            state, key, loop = _ite_restore(manager.load(latest),
                                            state.nrow, state.ncol)
            energies, measured_at = loop["energies"], loop["measured_at"]
            since_refresh = loop["since_refresh"]
            envs = loop["envs"]
            start_step = loop["next_step"]
            prior_planner_delta = loop["planner_delta"]
            resumed_from = latest
            if is_full:
                fidelities = loop["fidelities"] or []
                _fu.restore_fidelities(loop["fid_window"])

    active_guard = runtime_guard.resolve(guard)
    with runtime_guard.maybe(active_guard):
        for step in range(start_step, steps):
            for g, sites in moments:
                key, sub = jax.random.split(key)
                if is_full and len(sites) == 2:
                    s0, s1 = state.coords(sites[0]), state.coords(sites[1])
                    if (envs is None
                            or since_refresh >= update.env_refresh_every
                            or not _fu.envs_compatible(state, s0, s1, envs)):
                        key, ek = jax.random.split(key)
                        envs = row_environments(state, _fu.env_option(update),
                                                ek)
                        since_refresh = 0
                state = apply_operator(state, g, sites, update, key=sub,
                                       envs=envs)
                since_refresh += 1
            # environments survive normalize_sites (the positive-fixed metric
            # is invariant under uniform rescales) and step boundaries — only
            # the refresh cadence and bond-dimension growth invalidate them
            state = normalize_sites(state)
            if (step + 1) % measure_every == 0 or step == steps - 1:
                key, sub = jax.random.split(key)
                e = float(jnp.real(expectation(state, obs, contract,
                                               use_cache=True, key=sub)))
                energies.append(e)
                measured_at.append(step + 1)
                if is_full:
                    window = _fu.drain_fidelities()
                    fidelities.append(min(window) if window else float("nan"))
                if callback is not None:
                    callback(step + 1, e, state)
            if manager is not None and checkpoint_every > 0 \
                    and (step + 1) % checkpoint_every == 0:
                manager.save(step + 1, _ite_snapshot(
                    state, key, energies, measured_at, fidelities,
                    _fu.pending_fidelities() if is_full else [],
                    since_refresh, envs,
                    _merge_planner_stats(prior_planner_delta,
                                         planner.stats_since(planner_before)),
                    next_step=step + 1))
    if manager is not None:
        manager.wait()
    return ITEResult(
        state, energies, measured_at,
        _merge_planner_stats(prior_planner_delta,
                             planner.stats_since(planner_before)),
        fidelities,
        guard=active_guard.report if active_guard is not None else None,
        resumed_from=resumed_from)


def ite_statevector(nrow: int, ncol: int, obs: Observable, tau: float,
                    steps: int) -> Tuple[jnp.ndarray, float]:
    """Reference: the same Trotterized ITE applied to the exact statevector.

    This is the paper's \"state vector simulation after 1000 ITE steps\"
    baseline for Fig. 13."""
    vec = sv.zeros(nrow * ncol)
    moments = trotter_moments(obs, tau)
    for _ in range(steps):
        for g, sites in moments:
            vec = sv.apply_gate(vec, g, sites)
        vec = sv.normalize(vec)
    energy = float(jnp.real(sv.expectation(vec, obs.as_tuples())))
    return vec, energy
