"""Expectation values of local-term observables on PEPS (Eq. 5, Section IV-B).

``expectation(state, obs, option, use_cache=True)`` evaluates
``<psi|H|psi> / <psi|psi>`` for ``H = sum_i c_i H_i``:

* with caching (paper Section IV-B): two full environment sweeps, then one
  strip contraction per term;
* without caching: each term pays its own partial two-layer contractions
  (the baseline the paper's Fig. 9 compares against).

Two-site terms are split ``G = sum_k L_k (x) R_k`` (an exact operator-SVD
with bond kappa <= 4) so any geometry — horizontal, vertical, or diagonal
within two adjacent rows — reduces to a uniform column sweep.

Differentiability: the whole evaluation is traceable — the only numpy in
the hot path (:func:`split_two_site`, the key folding) operates on the
*constant* observable matrices and site indices, never on traced state
tensors, so ``jax.grad`` of an energy w.r.t. circuit parameters flows
through :func:`expectation` unimpeded (the einsumsvd truncations inside the
environment sweeps differentiate via :mod:`repro.core.svd_grad`).  See
``docs/vqe.md`` and :func:`repro.core.vqe.vqe_energy_and_grad`.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner
from repro.core.bmps import BMPS
from repro.core.environments import row_environments, top_environments, \
    trivial_env, _flip_rows
from repro.core.observable import Observable


def split_two_site(gate_tensor: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Exact SVD split of a (2,2,2,2) gate tensor G[x,y,p,q] into
    L[x,p,kappa], R[y,q,kappa] with G = sum_kappa L (x) R."""
    g = np.asarray(gate_tensor).reshape(2, 2, 2, 2)
    gt = g.transpose(0, 2, 1, 3).reshape(4, 4)  # (x p),(y q)
    u, s, vh = np.linalg.svd(gt)
    k = max(1, int((s > 1e-12 * max(s[0], 1e-300)).sum()))
    left = (u[:, :k] * np.sqrt(s[:k])).reshape(2, 2, k)
    right = (np.sqrt(s[:k])[:, None] * vh[:k]).reshape(k, 2, 2).transpose(1, 2, 0)
    return left, right


def strip_value(top_env: List[jnp.ndarray], bottom_env: List[jnp.ndarray],
                bra_rows: List[List[jnp.ndarray]],
                ket_rows: List[List[jnp.ndarray]]) -> jnp.ndarray:
    """Exactly contract [top_env; strip rows; bottom_env] left to right.

    ``bra_rows``/``ket_rows`` contain (p,u,l,d,r) site tensors; ket tensors
    may carry one extra trailing "kappa" axis from a split two-site operator
    — the two kappa axes in the strip are contracted with each other.  The
    bra is conjugated here.  Exact (no truncation): the strip is at most 2
    rows high, so the column transfer stays polynomial.
    """
    ncol = len(top_env)
    nstrip = len(bra_rows)
    counter = [0]

    def fresh() -> int:
        counter[0] += 1
        return counter[0]

    # v core bonds: [top] + [bra, ket]*nstrip + [bottom]; kappa tracked aside
    v_core = [fresh() for _ in range(2 * nstrip + 2)]
    kappa_open = False
    kappa_label: Optional[int] = None
    v = jnp.ones((1,) * len(v_core), dtype=top_env[0].dtype)

    for j in range(ncol):
        in_labels = list(v_core) + ([kappa_label] if kappa_open else [])
        args = [v, in_labels]
        a_new = fresh()
        f1, f2 = fresh(), fresh()
        args += [top_env[j], [v_core[0], f1, f2, a_new]]
        out_core: List[int] = [a_new]
        up_bra, up_ket = f1, f2
        n_kappa_here = 0
        for t in range(nstrip):
            p = fresh()
            d_bra, d_ket = fresh(), fresh()
            k_bra, k_ket = fresh(), fresh()
            args += [bra_rows[t][j].conj(),
                     [p, up_bra, v_core[1 + 2 * t], d_bra, k_bra]]
            ket_lab = [p, up_ket, v_core[2 + 2 * t], d_ket, k_ket]
            ket_t = ket_rows[t][j]
            if ket_t.ndim == 6:  # carries a split-operator kappa axis
                if kappa_label is None:
                    kappa_label = fresh()
                ket_lab.append(kappa_label)
                n_kappa_here += 1
            args += [ket_t, ket_lab]
            out_core.extend([k_bra, k_ket])
            up_bra, up_ket = d_bra, d_ket
        b_new = fresh()
        args += [bottom_env[j], [v_core[-1], up_bra, up_ket, b_new]]
        out_core.append(b_new)
        # kappa stays open iff exactly one of its two sites is absorbed so far
        open_after = (kappa_open and n_kappa_here == 0) or \
                     (not kappa_open and n_kappa_here == 1)
        out_labels = out_core + ([kappa_label] if open_after else [])
        args.append(out_labels)
        # plan-cached: every column of every strip with the same shape class
        # shares one contraction path (see planner.int_einsum)
        v = planner.int_einsum(*args)
        v_core, kappa_open = out_core, open_after

    return v.reshape(())


def _apply_term_to_ket(strip_ket: List[List[jnp.ndarray]], term, i0: int,
                       ncol: int) -> List[List[jnp.ndarray]]:
    """Insert the term's operator into the ket strip (kappa-split form)."""
    out = [[t for t in row] for row in strip_ket]
    dtype = out[0][0].dtype
    if len(term.sites) == 1:
        (s,) = term.sites
        r, c = divmod(s, ncol)
        m = jnp.asarray(term.matrix, dtype=dtype)
        out[r - i0][c] = jnp.einsum("xp,puldr->xuldr", m, out[r - i0][c])
        return out
    sa, sb = term.sites
    ra, ca = divmod(sa, ncol)
    rb, cb = divmod(sb, ncol)
    lt, rt = split_two_site(term.gate_tensor())
    lt = jnp.asarray(lt, dtype=dtype)
    rt = jnp.asarray(rt, dtype=dtype)
    out[ra - i0][ca] = jnp.einsum("xpk,puldr->xuldrk", lt, out[ra - i0][ca])
    out[rb - i0][cb] = jnp.einsum("xpk,puldr->xuldrk", rt, out[rb - i0][cb])
    return out


def term_rows(term, ncol: int) -> Tuple[int, int]:
    rows = [s // ncol for s in term.sites]
    return min(rows), max(rows)


def _term_value(state, term, top_env, bottom_env) -> jnp.ndarray:
    i0, i1 = term_rows(term, state.ncol)
    bra_strip = [state.sites[i] for i in range(i0, i1 + 1)]
    ket_strip = [list(state.sites[i]) for i in range(i0, i1 + 1)]
    ket_strip = _apply_term_to_ket(ket_strip, term, i0, state.ncol)
    return strip_value(top_env, bottom_env, bra_strip, ket_strip)


def norm_from_envs(state, top, bottom) -> jnp.ndarray:
    """<psi|psi> from cached environments (one strip contraction)."""
    i = state.nrow - 1
    return strip_value(top[i], bottom[i], [state.sites[i]], [state.sites[i]])


#: Seed of the PRNG key :func:`expectation` uses when called with
#: ``key=None``.  The serving engine (:mod:`repro.core.serving`) builds its
#: cached per-state row environments from the same default so a served
#: observable query reproduces the direct call exactly.
DEFAULT_EXPECTATION_KEY_SEED = 5


def expectation_from_envs(state, obs: Observable, top, bottom) -> jnp.ndarray:
    """<psi|H|psi>/<psi|psi> from precomputed row environments.

    ``(top, bottom)`` are the :func:`repro.core.environments.row_environments`
    of ``state`` — fully query-independent, so callers serving many
    observables against one state (the serving engine's cache) pay the two
    environment sweeps once and each query only the per-term strip
    contractions."""
    total = 0.0
    for term in obs:
        i0, i1 = term_rows(term, state.ncol)
        if i1 - i0 > 1:
            raise NotImplementedError("terms spanning >2 rows need SWAP routing")
        total = total + term.coeff * _term_value(state, term, top[i0], bottom[i1])
    return total / norm_from_envs(state, top, bottom)


def expectation(state, obs: Observable, option: BMPS, use_cache: bool = True,
                key=None) -> jnp.ndarray:
    """<psi|H|psi>/<psi|psi> for an Observable H of 1-/2-site terms."""
    if key is None:
        key = jax.random.PRNGKey(DEFAULT_EXPECTATION_KEY_SEED)
    nrow, ncol = state.nrow, state.ncol
    if use_cache:
        top, bottom = row_environments(state, option, key)
        return expectation_from_envs(state, obs, top, bottom)

    # -- no cache: each term pays its own environment contractions ----------
    total = 0.0
    norm = None
    for term in obs:
        i0, i1 = term_rows(term, ncol)
        if i1 - i0 > 1:
            raise NotImplementedError("terms spanning >2 rows need SWAP routing")
        key, k1, k2 = jax.random.split(key, 3)
        top_env = (trivial_env(ncol, state.dtype) if i0 == 0 else
                   top_environments(state.sites[:i0], state.sites[:i0],
                                    option, k1)[i0])
        if i1 == nrow - 1:
            bot_env = trivial_env(ncol, state.dtype)
        else:
            sub = state.sites[i1 + 1:]
            bot_env = top_environments(_flip_rows(sub), _flip_rows(sub),
                                       option, k2)[len(sub)]
        if norm is None:
            bra_strip = [state.sites[i] for i in range(i0, i1 + 1)]
            norm = strip_value(top_env, bot_env, bra_strip, bra_strip)
        total = total + term.coeff * _term_value(state, term, top_env, bot_env)
    return total / norm


def expectation_trotter(state, obs: Observable, option: BMPS, tau: float = 1e-3,
                        update=None, key=None) -> jnp.ndarray:
    """Paper Eq. (6): <H> ~ (<psi|prod_j e^{tau H_j}|psi> - <psi|psi>) / tau.

    One two-layer contraction instead of two, at the price of applying an
    extra (bond-growing, truncated) Trotter step to a copy of the ket.
    O(tau) bias by construction — benchmarked against Eq. (5) in tests.
    """
    import jax as _jax
    from repro.core.bmps import inner, norm_squared
    from repro.core.gates import trotter_gate
    from repro.core.peps import QRUpdate, apply_operator

    if key is None:
        key = _jax.random.PRNGKey(21)
    if update is None:
        update = QRUpdate(rank=max(4, state.max_bond()))
    phi = state
    for term in obs:
        key, sub = _jax.random.split(key)
        g = trotter_gate(-term.coeff * term.matrix, tau)  # exp(+tau c H)
        phi = apply_operator(phi, g, list(term.sites), update, key=sub)
    num = inner(state, phi, option)
    den = norm_squared(state, option)
    return (num - den) / (tau * den)
