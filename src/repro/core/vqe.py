"""Variational quantum eigensolver simulation (paper Section VI-D2).

The ansatz is the paper's: layers of Ry rotations on every qubit followed by
CNOTs on all nearest-neighbour pairs; the optimizer is SLSQP (as in the
paper, via scipy) over the PEPS-simulated energy
``E(theta) = <psi(theta)|H|psi(theta)>``.  An SPSA optimizer is provided as
a derivative-free alternative.

Production hardening (see ``docs/robustness.md``):

* ``checkpoint_dir=``/``checkpoint_every=`` (in energy *evaluations*)
  snapshot the optimizer state through
  :class:`repro.checkpoint.manager.CheckpointManager`.  SPSA resumes
  **bit-identically**: the checkpoint carries the parameter vector, the
  iteration index, the history, and the full numpy Generator state (as a
  JSON leaf), so the perturbation stream continues exactly where the
  killed run left it.  SLSQP keeps its state inside scipy, so its resume
  is a documented *warm restart*: the optimizer restarts from the best
  checkpointed parameters (energies re-converge; the eval trace is not
  replayed bit-for-bit).
* ``guard=`` activates the runtime guard over every energy evaluation —
  each evaluation contracts hundreds of einsumsvd truncations; the
  structured :class:`GuardReport` lands in ``VQEResult.guard``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner, runtime_guard
from repro.core import statevector as sv
from repro.core.bmps import BMPS
from repro.core.circuits import apply_circuit_peps, apply_circuit_statevector, vqe_ansatz
from repro.core.expectation import expectation
from repro.core.observable import Observable
from repro.core.peps import QRUpdate, computational_zeros


def vqe_energy_peps(thetas, nrow: int, ncol: int, obs: Observable,
                    update: QRUpdate, contract: BMPS, key=None) -> float:
    """Energy of the ansatz state simulated with PEPS."""
    if key is None:
        key = jax.random.PRNGKey(77)
    circuit = vqe_ansatz(nrow, ncol, np.asarray(thetas))
    state = computational_zeros(nrow, ncol)
    state = apply_circuit_peps(state, circuit, update, key)
    return float(jnp.real(expectation(state, obs, contract, use_cache=True)))


def vqe_energy_statevector(thetas, nrow: int, ncol: int, obs: Observable) -> float:
    circuit = vqe_ansatz(nrow, ncol, np.asarray(thetas))
    vec = apply_circuit_statevector(sv.zeros(nrow * ncol), circuit)
    return float(jnp.real(sv.expectation(vec, obs.as_tuples())))


@dataclasses.dataclass
class VQEResult:
    thetas: np.ndarray
    energy: float
    history: List[float]
    n_evals: int
    # planner cache counters over the run (for a resumed run: summed with
    # the checkpointed delta of the earlier process — the whole logical run)
    planner_stats: Optional[dict] = None
    # runtime-guard report (guard= runs only)
    guard: Optional[runtime_guard.GuardReport] = None
    # the checkpoint step (evaluation count) this run resumed from, or None
    resumed_from: Optional[int] = None


def _vqe_snapshot(x: np.ndarray, k: int, history: List[float],
                  rng: Optional[np.random.Generator],
                  planner_delta: dict) -> dict:
    tree = {
        "x": np.asarray(x, dtype=np.float64),
        "k": np.asarray(k, dtype=np.int64),
        "history": np.asarray(history, dtype=np.float64),
        "meta_json": np.array(json.dumps({"planner_delta": planner_delta})),
    }
    if rng is not None:
        # the full Generator state as JSON: restoring it continues the
        # SPSA perturbation stream exactly (bit-identical resume)
        tree["rng_state_json"] = np.array(
            json.dumps(rng.bit_generator.state))
    return tree


def run_vqe(
    nrow: int,
    ncol: int,
    obs: Observable,
    n_layers: int,
    max_bond: int,
    contract_bond: Optional[int] = None,
    maxiter: int = 100,
    seed: int = 0,
    backend: str = "peps",
    method: str = "SLSQP",
    svd: Optional[object] = None,
    *,
    guard=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    checkpoint_keep: int = 3,
    resume: bool = True,
    callback: Optional[Callable] = None,
) -> VQEResult:
    """Minimize the PEPS-simulated (or statevector) energy over the ansatz.

    ``max_bond`` is the PEPS evolution bond dimension (paper's \"maximum
    bond dimension\"); ``contract_bond`` the contraction chi (default 2x).
    ``svd`` selects the einsumsvd engine for both evolution and contraction
    (e.g. ``RandomizedSVD()`` for the fused implicit path — every energy
    evaluation replays the same network signatures, so the planner cache
    amortizes compilation across the whole optimization); default DirectSVD.

    ``guard`` activates the runtime guard (see module docstring);
    ``checkpoint_dir`` + ``checkpoint_every=N`` (counted in energy
    evaluations) snapshot the optimizer state, and ``resume=True`` picks up
    from the latest checkpoint (SPSA bit-identical, SLSQP warm restart).
    ``callback(n_evals, energy, x)`` fires after every evaluation.
    """
    from scipy import optimize

    n = nrow * ncol
    rng = np.random.default_rng(seed)
    x0 = rng.uniform(-0.1, 0.1, size=n_layers * n)
    history: List[float] = []
    planner_before = planner.stats()
    prior_planner_delta: dict = {}
    chi = contract_bond or max(2 * max_bond, 4)
    if svd is None:
        update = QRUpdate(rank=max_bond)
        contract = BMPS(chi)
    else:
        update = QRUpdate(rank=max_bond, svd=svd)
        contract = BMPS(chi, svd=svd)

    is_spsa = method.lower() == "spsa"
    manager = None
    resumed_from = None
    start_k = 0
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(checkpoint_dir, keep=checkpoint_keep)
        latest = manager.latest_step() if resume else None
        if latest is not None:
            flat = manager.load(latest)
            x0 = np.asarray(flat["x"], dtype=np.float64)
            start_k = int(flat["k"])
            history = [float(e) for e in flat["history"]]
            meta = json.loads(str(flat["meta_json"][()]))
            prior_planner_delta = meta.get("planner_delta") or {}
            if "rng_state_json" in flat:
                rng.bit_generator.state = json.loads(
                    str(flat["rng_state_json"][()]))
            resumed_from = latest

    def current_delta() -> dict:
        now = planner.stats_since(planner_before)
        out = dict(now)
        for pk, pv in prior_planner_delta.items():
            if pk.endswith("_cache_size"):
                continue
            out[pk] = out.get(pk, 0) + pv
        return out

    def objective(x):
        if backend == "peps":
            e = vqe_energy_peps(x, nrow, ncol, obs, update, contract)
        else:
            e = vqe_energy_statevector(x, nrow, ncol, obs)
        history.append(e)
        if callback is not None:
            callback(len(history), e, np.asarray(x))
        return e

    active_guard = runtime_guard.resolve(guard)

    def finish(x, e) -> VQEResult:
        if manager is not None:
            manager.wait()
        return VQEResult(
            np.asarray(x), float(e), history, len(history),
            planner_stats=current_delta(),
            guard=(active_guard.report if active_guard is not None else None),
            resumed_from=resumed_from)

    with runtime_guard.maybe(active_guard):
        if is_spsa:
            x = x0.copy()
            a0, c0 = 0.15, 0.12
            for k in range(start_k, maxiter):
                ak = a0 / (1 + k) ** 0.602
                ck = c0 / (1 + k) ** 0.101
                delta = rng.choice([-1.0, 1.0], size=x.shape)
                gplus = objective(x + ck * delta)
                gminus = objective(x - ck * delta)
                ghat = (gplus - gminus) / (2 * ck) * delta
                x = x - ak * ghat
                if manager is not None and checkpoint_every > 0 \
                        and (k + 1) % checkpoint_every == 0:
                    # saved AFTER iteration k: resume continues at k+1 with
                    # the Generator mid-stream -> bit-identical trajectory
                    manager.save(k + 1, _vqe_snapshot(
                        x, k + 1, history, rng, current_delta()))
            e = objective(x)
            return finish(x, e)

        evals_at_save = [len(history)]

        def slsqp_checkpoint(x):
            # scipy owns SLSQP's internal state, so the snapshot carries
            # only (x, history): resume is a warm restart, not a replay
            if manager is not None and checkpoint_every > 0 \
                    and len(history) - evals_at_save[0] >= checkpoint_every:
                evals_at_save[0] = len(history)
                manager.save(len(history), _vqe_snapshot(
                    x, len(history), history, None, current_delta()))

        res = optimize.minimize(
            objective, x0, method=method,
            callback=slsqp_checkpoint if manager is not None else None,
            options={"maxiter": maxiter, "ftol": 1e-9})
        return finish(res.x, float(res.fun))
