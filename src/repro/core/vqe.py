"""Variational quantum eigensolver simulation (paper Section VI-D2).

The ansatz is the paper's: layers of Ry rotations on every qubit followed by
CNOTs on all nearest-neighbour pairs.  Three optimizer families drive the
PEPS-simulated energy ``E(theta) = <psi(theta)|H|psi(theta)>``:

* **SLSQP** (the paper's choice, via scipy) — one sequential, host-round-trip
  energy evaluation at a time;
* **SPSA** — derivative-free; sequential (``ensemble=1``, the historical
  numpy-Generator driver, bit-identical resume) or **vmapped**
  (``ensemble=k``: k perturbation pairs advance in one compiled program);
* **adam** (``method="adam"``) — first-order gradient descent on the *exact*
  JAX gradient of the PEPS energy, powered by :mod:`repro.optim.adamw`;
  always batched (``ensemble`` parameter sets advance in one compiled
  vmapped program, ``ensemble=1`` is the same program with a unit batch).

Differentiability (this file's beyond-paper core): :func:`vqe_energy_peps`
is a pure, traceable JAX function — ``jax.grad``/``jit``/``vmap`` compose
through the ansatz gates, every einsumsvd truncation (the regularized SVD
gradient of :mod:`repro.core.svd_grad`), and the boundary contraction.
:func:`vqe_energy_and_grad` is the jit-compiled ``value_and_grad``, cached
per network signature in the planner's fused cache.  See ``docs/vqe.md``
for the differentiability contract and the optimizer decision table.

Ensembles compose with device meshes: pass ``mesh=peps_mesh(cols, batch)``
(or any mesh) and the member axis of a batched run is sharded across the
mesh's devices (:func:`repro.core.sharding.shard_ensemble`) — many circuits
x many devices in one compiled program.

Production hardening (see ``docs/robustness.md``):

* ``checkpoint_dir=``/``checkpoint_every=`` snapshot the optimizer state
  through :class:`repro.checkpoint.manager.CheckpointManager`.  Sequential
  SPSA resumes **bit-identically** (the snapshot carries the full numpy
  Generator state); batched adam/SPSA runs also resume bit-identically —
  their PRNG streams are *stateless* (keys derived from ``(seed,
  iteration, member)``), so the snapshot only needs parameters, moments
  and the iteration index.  SLSQP keeps its state inside scipy, so its
  resume is a documented *warm restart*.
* ``guard=`` activates the runtime guard.  Host-driven evaluations
  (SLSQP/sequential SPSA) guard every einsumsvd solve individually;
  gradient-mode and vmapped evaluations cannot host-sync per solve, so
  they guard at **evaluation granularity**: the traced step runs with the
  per-solve stack suspended, its output is host-checked, and a non-finite
  energy/gradient replays the whole evaluation one escalation-ladder rung
  more conservative (exact SVD -> exact precision -> dense kernels) —
  a fault injected inside a grad-mode evaluation escalates instead of
  surfacing as a NaN gradient (``tests/test_runtime_guard.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults, planner, runtime_guard
from repro.core import statevector as sv
from repro.core.bmps import BMPS
from repro.core.circuits import apply_circuit_peps, apply_circuit_statevector, vqe_ansatz
from repro.core.expectation import expectation
from repro.core.observable import Observable
from repro.core.peps import QRUpdate, computational_zeros
from repro.optim.adamw import OptConfig, adamw_update

#: Seed of the PRNG key the energy functions use when called with
#: ``key=None`` (the einsumsvd sketch stream of the circuit application).
DEFAULT_VQE_KEY_SEED = 77


def vqe_energy_peps(thetas, nrow: int, ncol: int, obs: Observable,
                    update: QRUpdate, contract: BMPS, key=None) -> jnp.ndarray:
    """Energy of the ansatz state simulated with PEPS.

    Pure and traceable: ``thetas`` may be a numpy array (concrete gates,
    the historical path) or any JAX array/tracer — ``jax.grad``, ``jit``
    and ``vmap`` compose through the whole evaluation.  Returns a real
    scalar ``jnp.ndarray`` (host-cast, if wanted, is the caller's job —
    :func:`run_vqe` does it at its API boundary)."""
    if key is None:
        key = jax.random.PRNGKey(DEFAULT_VQE_KEY_SEED)
    circuit = vqe_ansatz(nrow, ncol, thetas)
    state = computational_zeros(nrow, ncol)
    state = apply_circuit_peps(state, circuit, update, key)
    return jnp.real(expectation(state, obs, contract, use_cache=True))


def vqe_energy_statevector(thetas, nrow: int, ncol: int,
                           obs: Observable) -> jnp.ndarray:
    """Exact statevector reference energy — traceable like the PEPS path
    (the exact-chi gradient oracle of ``tests/test_vqe_grad.py``)."""
    circuit = vqe_ansatz(nrow, ncol, thetas)
    vec = apply_circuit_statevector(sv.zeros(nrow * ncol), circuit)
    return jnp.real(sv.expectation(vec, obs.as_tuples()))


# ---------------------------------------------------------------------------
# The differentiable seam: jit-compiled value_and_grad, guarded evaluations
# ---------------------------------------------------------------------------

def _obs_signature(obs: Observable) -> tuple:
    """Hashable identity of an observable for the fused-cache key."""
    return tuple((tuple(t.sites), np.asarray(t.matrix).tobytes(),
                  complex(t.coeff)) for t in obs)


def _grad_signature(nrow: int, ncol: int, n_params: int, obs: Observable,
                    update, contract) -> tuple:
    """Every trace-time decision of a gradient evaluation: the lattice, the
    parameter count, the observable, the (frozen-dataclass) option configs,
    the kernel-dispatch state and the device backend."""
    from repro.kernels import dispatch
    return (nrow, ncol, n_params, _obs_signature(obs), repr(update),
            repr(contract), dispatch.backend_signature(),
            jax.default_backend())


def _all_finite(tree) -> bool:
    """Host-side finiteness check over a pytree of arrays (one sync)."""
    for leaf in jax.tree_util.tree_leaves(tree):
        if not bool(np.all(np.isfinite(np.asarray(leaf)))):
            return False
    return True


def _grad_ladder(update: QRUpdate, contract: BMPS):
    """Evaluation-granularity escalation rungs: ``(rung, update, contract,
    force_dense)``, cumulative — the grad-path mirror of
    :func:`repro.core.runtime_guard._ladder` (which escalates per *solve*;
    a traced evaluation must swap options for the whole re-trace)."""
    from repro.core.einsumsvd import DirectSVD, RandomizedSVD
    from repro.core.precision import PrecisionWrapped

    def base(opt):
        return opt.inner if isinstance(opt, PrecisionWrapped) else opt

    rungs = []
    upd, con = update, contract
    u_base, c_base = base(upd.svd), base(con.svd)
    if isinstance(u_base, RandomizedSVD) or isinstance(c_base, RandomizedSVD):
        def to_direct(b):
            return DirectSVD(cutoff=getattr(b, "cutoff", 0.0)) \
                if isinstance(b, RandomizedSVD) else b
        upd = dataclasses.replace(
            upd, svd=(PrecisionWrapped(to_direct(u_base), upd.svd.policy)
                      if isinstance(upd.svd, PrecisionWrapped)
                      else to_direct(u_base)))
        con = dataclasses.replace(con, svd=to_direct(c_base))
        rungs.append(("exact_svd", upd, con, False))
    if isinstance(upd.svd, PrecisionWrapped) or \
            isinstance(con.svd, PrecisionWrapped):
        upd = dataclasses.replace(upd, svd=base(upd.svd))
        con = dataclasses.replace(con, svd=base(con.svd), precision="exact")
        rungs.append(("exact_precision", upd, con, False))
    rungs.append(("dense_kernel", upd, con, True))
    return rungs


def _escalate(run: Callable, active_guard, update: QRUpdate, contract: BMPS,
              site: str = "vqe_grad"):
    """Run ``run(update, contract, force_dense)`` under the evaluation-level
    guard: return its output when finite (or unguarded), else walk the
    ladder — same counters/report/exhaustion contract as the per-solve
    guard, at whole-evaluation granularity."""
    out = run(update, contract, False)
    if active_guard is None or _all_finite(out):
        return out
    config, report = active_guard.config, active_guard.report
    report.tick("guard_nan_events")
    report.record(runtime_guard.GuardEvent(site, "nan", 0, "detected"))
    rungs = _grad_ladder(update, contract)
    attempts = 0
    for rung, upd, con, force_dense in rungs[:config.max_retries]:
        attempts += 1
        report.tick("guard_retries")
        report.tick(f"guard_rung_{rung}")
        report.record(runtime_guard.GuardEvent(site, "nan", attempts,
                                               f"retry:{rung}"))
        out = run(upd, con, force_dense)
        if _all_finite(out):
            report.tick("guard_recovered")
            report.record(runtime_guard.GuardEvent(site, "nan", attempts,
                                                   f"recovered:{rung}"))
            return out
    report.tick("guard_exhausted")
    report.record(runtime_guard.GuardEvent(site, "nan", attempts,
                                           "exhausted"))
    raise runtime_guard.GuardExhaustedError(site, "nan", attempts,
                                            list(active_guard.report.events))


def vqe_energy_and_grad(thetas, nrow: int, ncol: int, obs: Observable,
                        update: QRUpdate, contract: BMPS, key=None, *,
                        guard=None):
    """``(E(theta), dE/dtheta)`` of the PEPS energy — jit + ``jax.grad``.

    The fast path compiles ``jax.value_and_grad(vqe_energy_peps)`` once per
    network signature and replays it from the planner's fused cache (the
    whole optimization loop reuses one executable).  With a guard active
    (``guard=`` or an ambient :class:`repro.core.runtime_guard.RuntimeGuard`)
    or faults armed, evaluations run eagerly — a fresh trace per call, so
    fault sites are consulted per evaluation and never baked into a cached
    executable — and are guarded at evaluation granularity (module
    docstring): a non-finite energy/gradient escalates through the ladder
    instead of propagating NaN.  Unguarded with faults armed, the
    corruption propagates (the documented unguarded contract)."""
    if key is None:
        key = jax.random.PRNGKey(DEFAULT_VQE_KEY_SEED)
    thetas = jnp.asarray(thetas, dtype=jnp.float64)
    active = runtime_guard.resolve(guard) or runtime_guard.current()
    if active is None and not faults.active():
        sig = _grad_signature(nrow, ncol, int(thetas.shape[0]), obs,
                              update, contract)

        def build():
            def f(th, k):
                return vqe_energy_peps(th, nrow, ncol, obs, update,
                                       contract, key=k)
            return jax.jit(jax.value_and_grad(f))
        return planner.fused_fn("vqe_grad", sig, build)(thetas, key)

    def run(upd, con, force_dense):
        from repro.kernels import dispatch

        def f(th):
            return vqe_energy_peps(th, nrow, ncol, obs, upd, con, key=key)
        with runtime_guard.suspended():
            ctx = dispatch.forced_dense() if force_dense \
                else contextlib.nullcontext()
            with ctx:
                return jax.value_and_grad(f)(thetas)
    return _escalate(run, active, update, contract)


# ---------------------------------------------------------------------------
# Results / checkpoint snapshots
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VQEResult:
    thetas: np.ndarray
    energy: float
    history: List[float]
    n_evals: int
    # planner cache counters over the run (for a resumed run: summed with
    # the checkpointed delta of the earlier process — the whole logical run)
    planner_stats: Optional[dict] = None
    # runtime-guard report (guard= runs only)
    guard: Optional[runtime_guard.GuardReport] = None
    # the checkpoint step (evaluation count) this run resumed from, or None
    resumed_from: Optional[int] = None
    # batched runs only (method="adam" or SPSA with ensemble>1): final
    # parameters (ensemble, P), final energies (ensemble,), and the
    # per-iteration per-member energy trace (iterations, ensemble).
    # ``thetas``/``energy``/``history`` then hold the best member / the
    # per-iteration best, so sequential consumers read batched results
    # unchanged.
    ensemble_thetas: Optional[np.ndarray] = None
    ensemble_energies: Optional[np.ndarray] = None
    ensemble_history: Optional[np.ndarray] = None


def _vqe_snapshot(x: np.ndarray, k: int, history: List[float],
                  rng: Optional[np.random.Generator],
                  planner_delta: dict) -> dict:
    tree = {
        "x": np.asarray(x, dtype=np.float64),
        "k": np.asarray(k, dtype=np.int64),
        "history": np.asarray(history, dtype=np.float64),
        "meta_json": np.array(json.dumps({"planner_delta": planner_delta})),
    }
    if rng is not None:
        # the full Generator state as JSON: restoring it continues the
        # SPSA perturbation stream exactly (bit-identical resume)
        tree["rng_state_json"] = np.array(
            json.dumps(rng.bit_generator.state))
    return tree


def _batched_snapshot(state: dict, k: int, ehist: List[np.ndarray],
                      ensemble: int, planner_delta: dict) -> dict:
    """Snapshot of a batched run.  No RNG state: the PRNG streams are
    stateless (keys derived from ``(seed, iteration, member)``), so the
    parameters + adam moments + the iteration index replay the trajectory
    bit-identically."""
    hist = (np.asarray(ehist, dtype=np.float64) if ehist
            else np.zeros((0, ensemble), dtype=np.float64))
    return {
        "x": np.asarray(state["x"], dtype=np.float64),
        "mu": np.asarray(state["mu"], dtype=np.float64),
        "nu": np.asarray(state["nu"], dtype=np.float64),
        "count": np.asarray(state["count"], dtype=np.int32),
        "k": np.asarray(k, dtype=np.int64),
        "ehist": hist.reshape(len(ehist), ensemble),
        "meta_json": np.array(json.dumps(
            {"planner_delta": planner_delta, "format": "batched-v1"})),
    }


# ---------------------------------------------------------------------------
# The batched drivers (vmapped adam / SPSA ensembles)
# ---------------------------------------------------------------------------

#: SPSA gain schedule (shared by the sequential and the batched driver):
#: a_k = a0/(1+k)^0.602, c_k = c0/(1+k)^0.101 (Spall's standard exponents).
SPSA_GAINS = (0.15, 0.12)


def _member_init(seed: int, ensemble: int, n_params: int) -> jnp.ndarray:
    """Member-keyed initial angles: member ``i`` draws from
    ``fold_in(PRNGKey(seed), i)`` — independent of the ensemble size, so
    member i of any ensemble starts identically (the shared PRNG
    contract)."""
    base = jax.random.PRNGKey(seed)

    def one(i):
        return jax.random.uniform(jax.random.fold_in(base, i),
                                  (n_params,), jnp.float64, -0.1, 0.1)
    return jax.vmap(one)(jnp.arange(ensemble))


def _build_batched_step(method: str, nrow: int, ncol: int, obs: Observable,
                        update: QRUpdate, contract: BMPS, seed: int,
                        cfg: OptConfig):
    """One optimizer iteration advancing every ensemble member, as a pure
    function ``step(state, k) -> (state, energies)`` suitable for jit.

    ``state`` is ``{"x": (ens, P), "mu": (ens, P), "nu": (ens, P),
    "count": (ens,)}`` (SPSA carries zero moments so both methods share one
    checkpoint layout).  ``k`` is the *global* iteration index, traced — one
    compiled program serves every iteration, and the SPSA perturbation key
    ``fold_in(fold_in(spsa_base, k), member)`` depends only on (seed, k,
    member): resume and ensemble-size changes never shift a member's
    stream."""
    energy_key = jax.random.PRNGKey(DEFAULT_VQE_KEY_SEED)

    def energy(th):
        return vqe_energy_peps(th, nrow, ncol, obs, update, contract,
                               key=energy_key)

    if method == "adam":
        vg = jax.value_and_grad(energy)

        def member(xi, mi, vi, ci, k, i):
            del k, i
            e, g = vg(xi)
            st = {"mu": mi, "nu": vi, "count": ci}
            nx, nst, _ = adamw_update(g, st, xi, cfg)
            return nx, nst["mu"], nst["nu"], nst["count"], e
    else:  # vmapped SPSA
        a0, c0 = SPSA_GAINS
        spsa_base = jax.random.fold_in(jax.random.PRNGKey(seed), 0x5B5A)

        def member(xi, mi, vi, ci, k, i):
            kk = jax.random.fold_in(jax.random.fold_in(spsa_base, k), i)
            delta = jax.random.rademacher(
                kk, xi.shape, jnp.int32).astype(jnp.float64)
            kf = k.astype(jnp.float64)
            ak = a0 / (1.0 + kf) ** 0.602
            ck = c0 / (1.0 + kf) ** 0.101
            gplus = energy(xi + ck * delta)
            gminus = energy(xi - ck * delta)
            ghat = (gplus - gminus) / (2.0 * ck) * delta
            return xi - ak * ghat, mi, vi, ci + 1, 0.5 * (gplus + gminus)

    def step(state, k):
        idx = jnp.arange(state["x"].shape[0])
        nx, nm, nv, nc, e = jax.vmap(
            member, in_axes=(0, 0, 0, 0, None, 0))(
                state["x"], state["mu"], state["nu"], state["count"], k, idx)
        return {"x": nx, "mu": nm, "nu": nv, "count": nc}, e
    return step


def _run_batched(nrow, ncol, obs, n_layers, maxiter, seed, method, update,
                 contract, ensemble, mesh, cfg, active_guard, manager,
                 checkpoint_every, resume, callback, current_delta,
                 prior_delta_box):
    """Drive a batched (vmapped, optionally mesh-sharded) adam/SPSA run.

    Returns ``(state, ehist, start_k, resumed_from)`` after ``maxiter``
    iterations; the caller turns it into a :class:`VQEResult`."""
    n_params = n_layers * nrow * ncol
    x0 = _member_init(seed, ensemble, n_params)
    zeros = jnp.zeros((ensemble, n_params), jnp.float64)
    state = {"x": x0, "mu": zeros, "nu": jnp.zeros_like(zeros),
             "count": jnp.zeros((ensemble,), jnp.int32)}
    ehist: List[np.ndarray] = []
    start_k = 0
    resumed_from = None
    if manager is not None and resume:
        latest = manager.latest_step()
        if latest is not None:
            flat = manager.load(latest)
            if "ehist" not in flat:
                raise ValueError(
                    f"checkpoint step {latest} is not from a batched VQE "
                    f"run (sequential SPSA/SLSQP snapshot?) — pass "
                    f"resume=False or a fresh checkpoint_dir")
            state = {"x": jnp.asarray(flat["x"]),
                     "mu": jnp.asarray(flat["mu"]),
                     "nu": jnp.asarray(flat["nu"]),
                     "count": jnp.asarray(flat["count"])}
            start_k = int(flat["k"])
            ehist = [np.asarray(row) for row in flat["ehist"]]
            meta = json.loads(str(flat["meta_json"][()]))
            prior_delta_box.update(meta.get("planner_delta") or {})
            resumed_from = latest

    if mesh is not None:
        from repro.core.sharding import shard_ensemble
        state = shard_ensemble(state, mesh, ensemble)

    fast = active_guard is None and not faults.active()
    if fast:
        sig = ("step", method, ensemble, seed, repr(cfg),
               ) + _grad_signature(nrow, ncol, n_params, obs, update,
                                   contract)
        step = planner.fused_fn(
            "vqe_batched", sig,
            lambda: jax.jit(_build_batched_step(
                method, nrow, ncol, obs, update, contract, seed, cfg)))
    else:
        # Guard/fault mode: eager steps (fresh trace per call — fault sites
        # consulted per evaluation, nothing corrupt is baked into a cached
        # executable), escalated at evaluation granularity via _escalate.
        def step(st, k):
            def run(upd, con, force_dense):
                from repro.kernels import dispatch
                fn = _build_batched_step(method, nrow, ncol, obs, upd, con,
                                         seed, cfg)
                with runtime_guard.suspended():
                    ctx = dispatch.forced_dense() if force_dense \
                        else contextlib.nullcontext()
                    with ctx:
                        return fn(st, k)
            return _escalate(run, active_guard, update, contract)

    for k in range(start_k, maxiter):
        state, e = step(state, jnp.asarray(k, jnp.int32))
        e_host = np.asarray(e, dtype=np.float64)
        ehist.append(e_host)
        if callback is not None:
            best = int(np.argmin(e_host))
            callback(len(ehist), float(e_host[best]),
                     np.asarray(state["x"][best]))
        if manager is not None and checkpoint_every > 0 \
                and (k + 1) % checkpoint_every == 0:
            # saved AFTER iteration k: resume continues at k+1; stateless
            # (seed, iteration, member)-keyed PRNG -> bit-identical replay
            manager.save(k + 1, _batched_snapshot(
                {kk: np.asarray(v) for kk, v in state.items()},
                k + 1, ehist, ensemble, current_delta()))
    return state, ehist, start_k, resumed_from


# ---------------------------------------------------------------------------
# run_vqe: the public driver
# ---------------------------------------------------------------------------

def run_vqe(
    nrow: int,
    ncol: int,
    obs: Observable,
    n_layers: int,
    max_bond: int,
    contract_bond: Optional[int] = None,
    maxiter: int = 100,
    seed: int = 0,
    backend: str = "peps",
    method: str = "SLSQP",
    svd: Optional[object] = None,
    *,
    ensemble: int = 1,
    mesh=None,
    lr: float = 0.05,
    guard=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    checkpoint_keep: int = 3,
    resume: bool = True,
    callback: Optional[Callable] = None,
) -> VQEResult:
    """Minimize the PEPS-simulated (or statevector) energy over the ansatz.

    ``max_bond`` is the PEPS evolution bond dimension (paper's \"maximum
    bond dimension\"); ``contract_bond`` the contraction chi (default 2x).
    ``svd`` selects the einsumsvd engine for both evolution and contraction
    (e.g. ``RandomizedSVD()`` for the fused implicit path — every energy
    evaluation replays the same network signatures, so the planner cache
    amortizes compilation across the whole optimization); default DirectSVD.

    ``method`` selects the optimizer: ``"SLSQP"`` (scipy, the paper's),
    ``"spsa"``, or ``"adam"`` (exact JAX gradient + :mod:`repro.optim.adamw`
    with ``weight_decay=0`` and learning rate ``lr``).  ``ensemble=k``
    (adam, or SPSA with k>1) advances k parameter sets in one compiled
    vmapped program — member ``i``'s PRNG streams depend only on ``(seed,
    iteration, i)``, so any member of any ensemble size replays the
    ``ensemble=1`` run of the same member index.  ``mesh=`` shards the
    member axis across devices (e.g. ``launch.mesh.peps_mesh(cols, batch)``)
    — ``checkpoint_*``/``resume`` snapshot and bit-identically resume
    batched runs too (counted in optimizer *iterations*).

    ``guard`` activates the runtime guard (see module docstring);
    ``checkpoint_dir`` + ``checkpoint_every=N`` (counted in energy
    evaluations for the sequential drivers) snapshot the optimizer state,
    and ``resume=True`` picks up from the latest checkpoint (SPSA/batched
    bit-identical, SLSQP warm restart).  ``callback(n_evals, energy, x)``
    fires after every evaluation (batched: after every iteration, with the
    best member's energy/parameters).
    """
    n = nrow * ncol
    history: List[float] = []
    planner_before = planner.stats()
    prior_planner_delta: dict = {}
    chi = contract_bond or max(2 * max_bond, 4)
    if svd is None:
        update = QRUpdate(rank=max_bond)
        contract = BMPS(chi)
    else:
        update = QRUpdate(rank=max_bond, svd=svd)
        contract = BMPS(chi, svd=svd)

    method_l = method.lower()
    is_spsa = method_l == "spsa"
    is_adam = method_l == "adam"
    batched = is_adam or (is_spsa and ensemble > 1)
    if ensemble > 1 and not batched:
        raise ValueError(
            f"ensemble={ensemble} needs a batched driver — method='adam' "
            f"or 'spsa' (got method={method!r})")
    if batched and backend != "peps":
        raise ValueError("batched drivers optimize the PEPS energy "
                         f"(got backend={backend!r})")

    manager = None
    resumed_from = None
    start_k = 0
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(checkpoint_dir, keep=checkpoint_keep)

    def current_delta() -> dict:
        now = planner.stats_since(planner_before)
        out = dict(now)
        for pk, pv in prior_planner_delta.items():
            if pk.endswith("_cache_size"):
                continue
            out[pk] = out.get(pk, 0) + pv
        return out

    active_guard = runtime_guard.resolve(guard)

    def finish(x, e) -> VQEResult:
        if manager is not None:
            manager.wait()
        return VQEResult(
            np.asarray(x), float(e), history, len(history),
            planner_stats=current_delta(),
            guard=(active_guard.report if active_guard is not None else None),
            resumed_from=resumed_from)

    # ---------------------------------------------------------- batched path
    if batched:
        cfg = OptConfig(lr=lr, b1=0.9, b2=0.95, eps=1e-8,
                        weight_decay=0.0, grad_clip=10.0)
        with runtime_guard.maybe(active_guard):
            state, ehist, start_k, resumed_from = _run_batched(
                nrow, ncol, obs, n_layers, maxiter, seed, method_l, update,
                contract, ensemble, mesh, cfg, active_guard, manager,
                checkpoint_every, resume, callback, current_delta,
                prior_planner_delta)
            # final exact energies at the final parameters, one vmapped eval
            energy_key = jax.random.PRNGKey(DEFAULT_VQE_KEY_SEED)

            def run_final(upd, con, force_dense):
                from repro.kernels import dispatch

                def e_fn(th):
                    return vqe_energy_peps(th, nrow, ncol, obs, upd, con,
                                           key=energy_key)
                with runtime_guard.suspended():
                    ctx = dispatch.forced_dense() if force_dense \
                        else contextlib.nullcontext()
                    with ctx:
                        return jax.vmap(e_fn)(state["x"])
            finals = np.asarray(
                _escalate(run_final, active_guard, update, contract),
                dtype=np.float64)
        ehist_arr = (np.asarray(ehist, dtype=np.float64).reshape(
            len(ehist), ensemble) if ehist
            else np.zeros((0, ensemble), dtype=np.float64))
        history.extend(float(r.min()) for r in ehist_arr)
        history.append(float(finals.min()))
        best = int(np.argmin(finals))
        res = finish(np.asarray(state["x"][best]), float(finals[best]))
        res.ensemble_thetas = np.asarray(state["x"], dtype=np.float64)
        res.ensemble_energies = finals
        res.ensemble_history = ehist_arr
        return res

    # ------------------------------------------------------- sequential path
    rng = np.random.default_rng(seed)
    x0 = rng.uniform(-0.1, 0.1, size=n_layers * n)
    if manager is not None:
        latest = manager.latest_step() if resume else None
        if latest is not None:
            flat = manager.load(latest)
            x0 = np.asarray(flat["x"], dtype=np.float64)
            start_k = int(flat["k"])
            history = [float(e) for e in flat["history"]]
            meta = json.loads(str(flat["meta_json"][()]))
            prior_planner_delta.update(meta.get("planner_delta") or {})
            if "rng_state_json" in flat:
                rng.bit_generator.state = json.loads(
                    str(flat["rng_state_json"][()]))
            resumed_from = latest

    def objective(x):
        if backend == "peps":
            e = vqe_energy_peps(x, nrow, ncol, obs, update, contract)
        else:
            e = vqe_energy_statevector(x, nrow, ncol, obs)
        # the one host cast of the optimization loop: scipy/numpy drivers
        # consume floats, the energy itself stays a traceable jnp scalar
        e = float(e)
        history.append(e)
        if callback is not None:
            callback(len(history), e, np.asarray(x))
        return e

    with runtime_guard.maybe(active_guard):
        if is_spsa:
            x = x0.copy()
            a0, c0 = SPSA_GAINS
            for k in range(start_k, maxiter):
                ak = a0 / (1 + k) ** 0.602
                ck = c0 / (1 + k) ** 0.101
                delta = rng.choice([-1.0, 1.0], size=x.shape)
                gplus = objective(x + ck * delta)
                gminus = objective(x - ck * delta)
                ghat = (gplus - gminus) / (2 * ck) * delta
                x = x - ak * ghat
                if manager is not None and checkpoint_every > 0 \
                        and (k + 1) % checkpoint_every == 0:
                    # saved AFTER iteration k: resume continues at k+1 with
                    # the Generator mid-stream -> bit-identical trajectory
                    manager.save(k + 1, _vqe_snapshot(
                        x, k + 1, history, rng, current_delta()))
            e = objective(x)
            return finish(x, e)

        from scipy import optimize

        evals_at_save = [len(history)]

        def slsqp_checkpoint(x):
            # scipy owns SLSQP's internal state, so the snapshot carries
            # only (x, history): resume is a warm restart, not a replay
            if manager is not None and checkpoint_every > 0 \
                    and len(history) - evals_at_save[0] >= checkpoint_every:
                evals_at_save[0] = len(history)
                manager.save(len(history), _vqe_snapshot(
                    x, len(history), history, None, current_delta()))

        res = optimize.minimize(
            objective, x0, method=method,
            callback=slsqp_checkpoint if manager is not None else None,
            options={"maxiter": maxiter, "ftol": 1e-9})
        return finish(res.x, float(res.fun))
