"""Variational quantum eigensolver simulation (paper Section VI-D2).

The ansatz is the paper's: layers of Ry rotations on every qubit followed by
CNOTs on all nearest-neighbour pairs; the optimizer is SLSQP (as in the
paper, via scipy) over the PEPS-simulated energy
``E(theta) = <psi(theta)|H|psi(theta)>``.  An SPSA optimizer is provided as
a derivative-free alternative.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import statevector as sv
from repro.core.bmps import BMPS
from repro.core.circuits import apply_circuit_peps, apply_circuit_statevector, vqe_ansatz
from repro.core.expectation import expectation
from repro.core.observable import Observable
from repro.core.peps import QRUpdate, computational_zeros


def vqe_energy_peps(thetas, nrow: int, ncol: int, obs: Observable,
                    update: QRUpdate, contract: BMPS, key=None) -> float:
    """Energy of the ansatz state simulated with PEPS."""
    if key is None:
        key = jax.random.PRNGKey(77)
    circuit = vqe_ansatz(nrow, ncol, np.asarray(thetas))
    state = computational_zeros(nrow, ncol)
    state = apply_circuit_peps(state, circuit, update, key)
    return float(jnp.real(expectation(state, obs, contract, use_cache=True)))


def vqe_energy_statevector(thetas, nrow: int, ncol: int, obs: Observable) -> float:
    circuit = vqe_ansatz(nrow, ncol, np.asarray(thetas))
    vec = apply_circuit_statevector(sv.zeros(nrow * ncol), circuit)
    return float(jnp.real(sv.expectation(vec, obs.as_tuples())))


@dataclasses.dataclass
class VQEResult:
    thetas: np.ndarray
    energy: float
    history: List[float]
    n_evals: int


def run_vqe(
    nrow: int,
    ncol: int,
    obs: Observable,
    n_layers: int,
    max_bond: int,
    contract_bond: Optional[int] = None,
    maxiter: int = 100,
    seed: int = 0,
    backend: str = "peps",
    method: str = "SLSQP",
    svd: Optional[object] = None,
) -> VQEResult:
    """Minimize the PEPS-simulated (or statevector) energy over the ansatz.

    ``max_bond`` is the PEPS evolution bond dimension (paper's \"maximum
    bond dimension\"); ``contract_bond`` the contraction chi (default 2x).
    ``svd`` selects the einsumsvd engine for both evolution and contraction
    (e.g. ``RandomizedSVD()`` for the fused implicit path — every energy
    evaluation replays the same network signatures, so the planner cache
    amortizes compilation across the whole optimization); default DirectSVD.
    """
    from scipy import optimize

    n = nrow * ncol
    rng = np.random.default_rng(seed)
    x0 = rng.uniform(-0.1, 0.1, size=n_layers * n)
    history: List[float] = []
    chi = contract_bond or max(2 * max_bond, 4)
    if svd is None:
        update = QRUpdate(rank=max_bond)
        contract = BMPS(chi)
    else:
        update = QRUpdate(rank=max_bond, svd=svd)
        contract = BMPS(chi, svd=svd)

    def objective(x):
        if backend == "peps":
            e = vqe_energy_peps(x, nrow, ncol, obs, update, contract)
        else:
            e = vqe_energy_statevector(x, nrow, ncol, obs)
        history.append(e)
        return e

    if method.lower() == "spsa":
        x = x0.copy()
        a0, c0 = 0.15, 0.12
        for k in range(maxiter):
            ak = a0 / (1 + k) ** 0.602
            ck = c0 / (1 + k) ** 0.101
            delta = rng.choice([-1.0, 1.0], size=x.shape)
            gplus = objective(x + ck * delta)
            gminus = objective(x - ck * delta)
            ghat = (gplus - gminus) / (2 * ck) * delta
            x = x - ak * ghat
        e = objective(x)
        return VQEResult(x, e, history, len(history))

    res = optimize.minimize(objective, x0, method=method,
                            options={"maxiter": maxiter, "ftol": 1e-9})
    return VQEResult(res.x, float(res.fun), history, len(history))
