"""Distributed PEPS ensembles: Cyclops-style tensor distribution on a JAX mesh.

The paper distributes every big site tensor over all MPI processes; the JAX
analogue shards each site tensor's bond axes over the ``model`` axis while an
*ensemble* batch axis (independent PEPS evolutions — the VQE/ITE parameter
sweeps of Section VI-D) shards over ``pod``+``data``.  Contractions across
sharded bonds lower to GSPMD collectives; the Gram orthogonalization keeps
factorizations local (paper Alg. 5) — exactly the trade this module exists
to measure in the dry-run.

Scope: this module parallelizes *many independent states* (and,
cyclops-mode, the axes of individual big tensors).  Contracting **one**
state too large for a single device is the job of
:mod:`repro.core.distributed`, which shards the lattice's *columns*
block-cyclically and pipelines the boundary-MPS sweep with halo exchanges
(paper Section V).  Site tensors everywhere follow the canonical
``(p, u, l, d, r)`` leg ordering — see the diagram in
:mod:`repro.core.peps`.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bmps import BMPS, contract_twolayer
from repro.core.einsumsvd import RandomizedSVD
from repro.core.peps import (FullUpdate, PEPS, QRUpdate,
                             _apply_two_site_adjacent, random_peps)
from repro.core import gates as G


@dataclasses.dataclass(frozen=True)
class PEPSConfig:
    name: str = "peps-rqc"
    nrow: int = 8
    ncol: int = 8
    bond: int = 16            # evolution bond dimension r (RQC initial bond)
    chi: int = 64             # contraction bond dimension m
    ensemble: int = 32        # independent PEPS (VQE-style parameter sweep)
    dtype: object = jnp.complex64


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def site_sharding(mesh: Mesh, shape, batched: bool,
                  mode: str = "cyclops") -> NamedSharding:
    """(B, p, u, l, d, r) sharding.

    * ``cyclops``  — paper-style: one bond axis of every site tensor sharded
      over 'model'; the ensemble over pod+data.  Contractions across the
      sharded bond lower to collectives (the trade the paper's Alg. 5
      exists to manage).
    * ``ensemble`` — pure ensemble parallelism: members replicated over
      'model', zero intra-tensor collectives, redundant compute on the
      model axis (the VQE/ITE parameter-sweep regime).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_ok = lambda d: "model" in sizes and d % sizes["model"] == 0 and d > 1
    parts: List = []
    offset = 0
    if batched:
        baxes = _batch_axes(mesh)
        n = 1
        for a in baxes:
            n *= sizes[a]
        parts.append(baxes if shape[0] % n == 0 else None)
        offset = 1
    # physical axis: never sharded
    parts.append(None)
    used_model = mode != "cyclops"
    for d in shape[offset + 1:]:
        if not used_model and model_ok(d):
            parts.append("model")
            used_model = True
        else:
            parts.append(None)
    return NamedSharding(mesh, P(*parts))


def peps_shardings(state_or_specs, mesh: Mesh, batched: bool = True,
                   mode: str = "cyclops"):
    """Pytree of NamedShardings matching a (possibly vmapped) PEPS pytree."""
    return jax.tree_util.tree_map(
        lambda t: site_sharding(mesh, t.shape, batched, mode), state_or_specs)


def ensemble_sharding(mesh: Mesh, ensemble: int, ndim: int) -> NamedSharding:
    """Sharding of an ``(ensemble, ...)`` member-batched array.

    Shards the leading member axis over **all** mesh axes when ``ensemble``
    is divisible by the total device count (the pure data-parallel regime of
    a vmapped VQE/ITE ensemble — e.g. ``peps_mesh(cols, batch)`` with
    ``ensemble == cols * batch``); otherwise over the trailing mesh axis
    that divides it; otherwise fully replicated.  Trailing array axes are
    never sharded — each member's parameter vector lives whole on its
    device, only the member axis is split."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for s in sizes.values():
        total *= s
    rest = [None] * (ndim - 1)
    if total > 1 and ensemble % total == 0:
        return NamedSharding(mesh, P(tuple(mesh.axis_names), *rest))
    for a in reversed(mesh.axis_names):
        if sizes[a] > 1 and ensemble % sizes[a] == 0:
            return NamedSharding(mesh, P(a, *rest))
    return NamedSharding(mesh, P(*([None] * ndim)))


def shard_ensemble(tree, mesh: Mesh, ensemble: int):
    """``device_put`` every ``(ensemble, ...)`` leaf of an optimizer-state
    pytree with :func:`ensemble_sharding` — jit/GSPMD propagates the member
    partitioning through the vmapped step, so ``run_vqe(..., ensemble=k,
    mesh=...)`` advances many circuits x many devices in one program."""
    return jax.tree_util.tree_map(
        lambda v: jax.device_put(
            v, ensemble_sharding(mesh, ensemble, max(v.ndim, 1))), tree)


def abstract_ensemble(cfg: PEPSConfig):
    """ShapeDtypeStruct PEPS ensemble (no allocation) for the dry-run."""
    proto = random_peps(cfg.nrow, cfg.ncol, cfg.bond, jax.random.PRNGKey(0),
                        dtype=cfg.dtype)

    def widen(t):
        return jax.ShapeDtypeStruct((cfg.ensemble,) + t.shape, cfg.dtype)

    return jax.tree_util.tree_map(widen, proto)


# ---------------------------------------------------------------------------
# The two dry-run step functions (assignment: the paper's own technique)
# ---------------------------------------------------------------------------

def _evolve_layer(state: PEPS, key, upd, envs_fn=None) -> PEPS:
    """iSWAP on all horizontal then vertical neighbour pairs with ``upd``.

    ``envs_fn(state, key)``, when given, produces cached row environments
    once per sweep direction (they go cluster-style stale within the sweep;
    bond growth forces a per-bond refresh via ``envs_compatible``)."""
    g = jnp.asarray(G.ISWAP, dtype=state.dtype)
    nrow, ncol = state.nrow, state.ncol
    for pairs in (
        [((i, j), (i, j + 1)) for i in range(nrow)
         for j in range(0, ncol - 1, 2)],
        [((i, j), (i + 1, j)) for j in range(ncol)
         for i in range(0, nrow - 1, 2)],
    ):
        envs = None
        if envs_fn is not None:
            key, ek = jax.random.split(key)
            envs = envs_fn(state, ek)
        for s0, s1 in pairs:
            key, sub = jax.random.split(key)
            state = _apply_two_site_adjacent(state, g, s0, s1, upd, sub, envs)
    return state


def evolve_step(state: PEPS, key) -> PEPS:
    """One TEBD layer: iSWAP on all horizontal + vertical neighbour pairs,
    QR-SVD simple update with Gram orthogonalization (Alg. 1 + Alg. 5)."""
    cfgd = state.sites[1][1].shape[4]  # interior bond dim
    upd = QRUpdate(rank=cfgd, svd=RandomizedSVD(niter=1, oversample=4))
    return _evolve_layer(state, key, upd)


def evolve_step_full(state: PEPS, key, chi_env: int = 8) -> PEPS:
    """One TEBD layer with the environment-aware :class:`FullUpdate`.

    Same gate pattern as :func:`evolve_step`, but every bond truncation is
    ALS-optimized against the two-site neighborhood environment, which is
    extracted from (possibly sharded) site tensors by plain einsum
    contractions — GSPMD lowers contractions across sharded bonds to
    collectives, so distributed sites feed the environment extraction with
    no re-layout.  Row environments are computed once per sweep direction
    and reused across the direction's bonds.  Safe under ``vmap`` (ensemble
    axis): the fidelity log is skipped while tracing."""
    from repro.core import full_update as _fu

    bond = state.sites[1][1].shape[4]
    upd = FullUpdate(rank=bond, chi=chi_env,
                     svd=RandomizedSVD(niter=1, oversample=4),
                     als_iters=2)
    from repro.core.environments import row_environments
    envs_fn = lambda s, k: row_environments(s, _fu.env_option(upd), k)
    return _evolve_layer(state, key, upd, envs_fn)


def batched_evolve_full(states: PEPS, keys, chi_env: int = 8) -> PEPS:
    return jax.vmap(lambda s, k: evolve_step_full(s, k, chi_env))(states, keys)


def carry_model_constraint(mesh: Mesh):
    """Shard the zip-up carry's truncated bond over 'model' (hillclimb C2)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)

    def fn(v):
        if m <= 1 or v.shape[0] % m != 0:
            return v
        parts = ["model"] + [None] * (v.ndim - 1)
        return jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, P(*parts)))
    return fn


def contract_step(state: PEPS, chi: int, key, gram_final: bool = False,
                  constrain_carry=None) -> jnp.ndarray:
    """<psi|psi> via two-layer IBMPS (the paper's headline algorithm)."""
    option = BMPS(chi, RandomizedSVD(niter=1, oversample=4,
                                     gram_final=gram_final),
                  constrain_carry=constrain_carry)
    return contract_twolayer(state.sites, state.sites, option, key)


def batched_evolve(states: PEPS, keys) -> PEPS:
    return jax.vmap(evolve_step)(states, keys)


def batched_contract(states: PEPS, chi: int, keys, gram_final: bool = False,
                     constrain_carry=None) -> jnp.ndarray:
    return jax.vmap(lambda s, k: contract_step(s, chi, k, gram_final,
                                               constrain_carry))(states, keys)
