"""Observables: sums of local (1- and 2-site) Hermitian terms.

An :class:`Observable` is a list of ``Term(sites, matrix, coeff)``.  Sites are
flat qubit indices (row-major over the PEPS grid).  Two-site matrices are
stored as (4, 4); they are converted to (2,2,2,2) gate-tensor layout at
application time.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence, Tuple

import numpy as np

from repro.core import gates as G


@dataclasses.dataclass(frozen=True)
class Term:
    sites: Tuple[int, ...]
    matrix: np.ndarray  # (2,2) or (4,4), Hermitian
    coeff: float = 1.0

    def gate_tensor(self) -> np.ndarray:
        """Matrix in gate-tensor layout ((2,2) or (2,2,2,2))."""
        if len(self.sites) == 2:
            return G.two_site_gate(self.matrix)
        return np.asarray(self.matrix)


class Observable:
    """Weighted sum of local Pauli terms, e.g. ``Observable.ZZ(3,4) + 0.2*Observable.X(1)``."""

    def __init__(self, terms: Sequence[Term] = ()):
        self.terms = list(terms)

    # -- constructors -------------------------------------------------------
    @classmethod
    def one_site(cls, pauli: str, site: int, coeff: float = 1.0) -> "Observable":
        return cls([Term((site,), G.pauli_term(pauli), coeff)])

    @classmethod
    def two_site(cls, paulis: str, s0: int, s1: int, coeff: float = 1.0) -> "Observable":
        assert len(paulis) == 2
        return cls([Term((s0, s1), G.pauli_term(paulis), coeff)])

    @classmethod
    def X(cls, site: int) -> "Observable":
        return cls.one_site("X", site)

    @classmethod
    def Y(cls, site: int) -> "Observable":
        return cls.one_site("Y", site)

    @classmethod
    def Z(cls, site: int) -> "Observable":
        return cls.one_site("Z", site)

    @classmethod
    def XX(cls, s0: int, s1: int) -> "Observable":
        return cls.two_site("XX", s0, s1)

    @classmethod
    def YY(cls, s0: int, s1: int) -> "Observable":
        return cls.two_site("YY", s0, s1)

    @classmethod
    def ZZ(cls, s0: int, s1: int) -> "Observable":
        return cls.two_site("ZZ", s0, s1)

    # -- algebra ------------------------------------------------------------
    def __add__(self, other: "Observable") -> "Observable":
        return Observable(self.terms + other.terms)

    def __rmul__(self, c: float) -> "Observable":
        return Observable([dataclasses.replace(t, coeff=t.coeff * c) for t in self.terms])

    __mul__ = __rmul__

    def __iter__(self) -> Iterator[Term]:
        return iter(self.terms)

    def __len__(self) -> int:
        return len(self.terms)

    def as_tuples(self):
        """(sites, gate_tensor, coeff) triples — the statevector oracle format."""
        return [(t.sites, t.gate_tensor(), t.coeff) for t in self.terms]


# ---------------------------------------------------------------------------
# Model Hamiltonians used by the paper's application studies
# ---------------------------------------------------------------------------

def _flat(i: int, j: int, ncol: int) -> int:
    return i * ncol + j


def tfi_hamiltonian(nrow: int, ncol: int, jz: float = -1.0, hx: float = -3.5) -> Observable:
    """Transverse-field Ising model, Eq. (8): H = sum Jz Z_i Z_j + sum hx X_i."""
    obs = Observable()
    for i in range(nrow):
        for j in range(ncol):
            if j + 1 < ncol:
                obs = obs + jz * Observable.ZZ(_flat(i, j, ncol), _flat(i, j + 1, ncol))
            if i + 1 < nrow:
                obs = obs + jz * Observable.ZZ(_flat(i, j, ncol), _flat(i + 1, j, ncol))
            obs = obs + hx * Observable.X(_flat(i, j, ncol))
    return obs


def j1j2_hamiltonian(
    nrow: int,
    ncol: int,
    j1: Sequence[float] = (1.0, 1.0, 1.0),
    j2: Sequence[float] = (0.5, 0.5, 0.5),
    h: Sequence[float] = (0.2, 0.2, 0.2),
) -> Observable:
    """Spin-1/2 J1-J2 Heisenberg model with field, Eq. (7)."""
    obs = Observable()
    paulis = ("XX", "YY", "ZZ")
    singles = ("X", "Y", "Z")
    for i in range(nrow):
        for j in range(ncol):
            s = _flat(i, j, ncol)
            # nearest neighbours
            for (di, dj) in ((0, 1), (1, 0)):
                ii, jj = i + di, j + dj
                if ii < nrow and jj < ncol:
                    for p, c in zip(paulis, j1):
                        if c != 0.0:
                            obs = obs + c * Observable.two_site(p, s, _flat(ii, jj, ncol))
            # diagonal neighbours
            for (di, dj) in ((1, 1), (1, -1)):
                ii, jj = i + di, j + dj
                if 0 <= ii < nrow and 0 <= jj < ncol:
                    for p, c in zip(paulis, j2):
                        if c != 0.0:
                            obs = obs + c * Observable.two_site(p, s, _flat(ii, jj, ncol))
            for p, c in zip(singles, h):
                if c != 0.0:
                    obs = obs + c * Observable.one_site(p, s)
    return obs
