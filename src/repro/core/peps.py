"""PEPS state and operator application (paper Sections II-C, III-A, IV-A).

Site tensor layout: ``(p, u, l, d, r)`` — physical, up, left, down, right.
This module holds the **canonical leg-ordering diagram** for the whole
library; other modules (``bmps``, ``sharding``, ``distributed``, docs)
reference it rather than restating it::

                 (u)
                  |
           (l) --[T]-- (r)        T[p, u, l, d, r]
                  | \\
                 (d) (p)          p = physical leg (dim 2 for qubits)

    Grid, row-major; site (i, j) holds qubit i*ncol + j:

        (0,0) --- (0,1) --- (0,2)        u of row 0 and l of column 0
          |         |         |          are dim-1 boundary bonds; r/d
        (1,0) --- (1,1) --- (1,2)        bonds of interior sites carry
          |         |         |          the variational bond dimension.
        (2,0) --- (2,1) --- (2,2)

Boundary bonds have dimension 1.  Grid site ``(i, j)`` (row-major) holds the
qubit ``i*ncol + j``.

Two-site operator application implements three accuracy tiers:
* ``DirectUpdate`` — contract the full theta and einsumsvd it (Eq. 4),
* ``QRUpdate``    — Alg. 1: QR both sites first (via the reshape-avoiding
  Gram factorization of Alg. 5, or LAPACK QR), einsumsvd the small Rs, and
  re-absorb the Q factors.  This is the O(d^2 r^5) path.
* ``FullUpdate``  — environment-aware truncation (Lubasch et al.,
  arXiv:1405.3259): the bond is ALS-optimized in the metric of the cached
  two-site neighborhood environment (see :mod:`repro.core.full_update`).

A scalar ``log_scale`` rides along with the state so that imaginary-time
evolution can renormalize site tensors without losing track of amplitudes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.einsumsvd import DirectSVD, RandomizedSVD, einsumsvd
from repro.core.orthogonalize import gram_qr, reshape_qr
from repro.core import gates as _gates


@jax.tree_util.register_pytree_node_class
class PEPS:
    """An nrow x ncol grid of site tensors (p, u, l, d, r)."""

    def __init__(self, sites: List[List[jnp.ndarray]], log_scale: float = 0.0):
        self.sites = sites
        self.log_scale = log_scale

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        flat = [t for row in self.sites for t in row]
        aux = (self.nrow, self.ncol, self.log_scale)
        return flat, aux

    @classmethod
    def tree_unflatten(cls, aux, flat):
        nrow, ncol, log_scale = aux
        it = iter(flat)
        sites = [[next(it) for _ in range(ncol)] for _ in range(nrow)]
        return cls(sites, log_scale)

    # -- basics ---------------------------------------------------------------
    @property
    def nrow(self) -> int:
        return len(self.sites)

    @property
    def ncol(self) -> int:
        return len(self.sites[0])

    @property
    def nsites(self) -> int:
        return self.nrow * self.ncol

    @property
    def dtype(self):
        return self.sites[0][0].dtype

    def copy(self) -> "PEPS":
        return PEPS([[t for t in row] for row in self.sites], self.log_scale)

    def site(self, flat_idx: int) -> jnp.ndarray:
        return self.sites[flat_idx // self.ncol][flat_idx % self.ncol]

    def coords(self, flat_idx: int) -> Tuple[int, int]:
        return flat_idx // self.ncol, flat_idx % self.ncol

    def max_bond(self) -> int:
        return max(max(t.shape[1:]) for row in self.sites for t in row)

    def conj(self) -> "PEPS":
        return PEPS([[t.conj() for t in row] for row in self.sites], self.log_scale)


def computational_zeros(nrow: int, ncol: int, dtype=jnp.complex128) -> PEPS:
    """|0...0> as a bond-dimension-1 PEPS."""
    t = np.zeros((2, 1, 1, 1, 1), dtype=dtype)
    t[0] = 1.0
    t = jnp.asarray(t)
    return PEPS([[t for _ in range(ncol)] for _ in range(nrow)])


def computational_basis(bits: np.ndarray, dtype=jnp.complex128) -> PEPS:
    bits = np.asarray(bits)
    nrow, ncol = bits.shape
    sites = []
    for i in range(nrow):
        row = []
        for j in range(ncol):
            t = np.zeros((2, 1, 1, 1, 1), dtype=dtype)
            t[int(bits[i, j])] = 1.0
            row.append(jnp.asarray(t))
        sites.append(row)
    return PEPS(sites)


def random_peps(nrow: int, ncol: int, bond: int, key, phys: int = 2,
                dtype=jnp.complex128) -> PEPS:
    """Random PEPS with uniform interior bond dimension (edges are 1)."""
    sites = []
    for i in range(nrow):
        row = []
        for j in range(ncol):
            u = 1 if i == 0 else bond
            d = 1 if i == nrow - 1 else bond
            l = 1 if j == 0 else bond
            r = 1 if j == ncol - 1 else bond
            key, k1, k2 = jax.random.split(key, 3)
            shape = (phys, u, l, d, r)
            if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
                t = (jax.random.normal(k1, shape) + 1j * jax.random.normal(k2, shape))
            else:
                t = jax.random.normal(k1, shape)
            row.append(t.astype(dtype) / np.sqrt(np.prod(shape)))
        sites.append(row)
    return PEPS(sites)


def random_onelayer(nrow: int, ncol: int, bond: int, key,
                    dtype=jnp.complex128) -> List[List[jnp.ndarray]]:
    """Random PEPS *without physical indices* — (u, l, d, r) tensors.

    Used by the contraction benchmarks (paper Fig. 8 generates these
    directly to get more bond-dimension data points)."""
    p = random_peps(nrow, ncol, bond, key, phys=1, dtype=dtype)
    return [[t[0] for t in row] for row in p.sites]


# ---------------------------------------------------------------------------
# Update options
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DirectUpdate:
    """Contract full theta then einsumsvd (Eq. 4). O(d^3 r^9)-ish, baseline."""
    rank: int
    svd: object = DirectSVD()


@dataclasses.dataclass(frozen=True)
class QRUpdate:
    """Alg. 1 (QR-SVD), O(d^2 r^5). ``gram=True`` uses Alg. 5 orthogonalization
    (reshape-avoiding); ``gram=False`` uses matricize+LAPACK QR."""
    rank: int
    svd: object = DirectSVD()
    gram: bool = True


@dataclasses.dataclass(frozen=True)
class FullUpdate:
    """Environment-aware full update (Lubasch et al., arXiv:1405.3259).

    The bond truncation is optimized in the metric of the two-site
    neighborhood environment, extracted from cached row environments plus a
    left/right strip contraction (see :mod:`repro.core.full_update`).  More
    accurate than :class:`QRUpdate` at equal bond dimension; costs one
    environment contraction + a jit-fused ALS per bond.

    Parameters
    ----------
    rank:       truncated bond dimension.
    svd:        einsumsvd engine for the ALS seed split (the simple-update
                answer in the reduced gauge).
    chi:        boundary bond dimension of the row environments.
    env_svd:    einsumsvd engine for the environment sweeps.
    als_iters:  fixed number of ALS sweeps (static; part of the jit
                signature).
    als_eps:    relative Tikhonov regularization of the ALS normal matrices.
    positive:   hermitize + eigenvalue-clamp the bond environment (the
                gauge/positive fix; strongly recommended).
    env_refresh_every: in ``ite.ite_run``, refresh the cached row
                environments every N gate applications (1 = before every
                two-site gate; larger values reuse staler environments,
                cluster-update style, for speed).  Independently of the
                cadence, environments are always refreshed when a bond
                dimension has grown since the cached sweep (see
                ``full_update.envs_compatible``).
    env_contract: full contraction option for the environment sweeps,
                overriding ``(chi, env_svd)`` when set.  Pass a
                :class:`repro.core.distributed.DistributedBMPS` to run the
                row-environment sweeps column-sharded across devices —
                this is how full-update ITE picks up intra-state
                distribution (values match single-device to rounding).
    """
    rank: int
    svd: object = DirectSVD()
    chi: int = 16
    env_svd: object = DirectSVD()
    als_iters: int = 6
    als_eps: float = 1e-12
    positive: bool = True
    env_refresh_every: int = 1
    env_contract: object = None


def check_update(update) -> None:
    """Validate a two-site update option (single source of the accepted set)."""
    if not isinstance(update, (DirectUpdate, QRUpdate, FullUpdate)):
        raise TypeError(
            f"unknown two-site update option {type(update).__name__!r}: "
            "expected DirectUpdate, QRUpdate, or FullUpdate")


# ---------------------------------------------------------------------------
# Operator application
# ---------------------------------------------------------------------------

def apply_single(state: PEPS, g, flat_site: int) -> PEPS:
    """One-site operator (Eq. 3) — contraction with the physical index."""
    i, j = state.coords(flat_site)
    g = jnp.asarray(g, dtype=state.dtype)
    new = state.copy()
    new.sites[i][j] = jnp.einsum("pq,quldr->puldr", g, state.sites[i][j])
    return new


def _two_site_horizontal(a, b, g, update, key):
    """Core update for neighbouring sites in a row. a:(p,u,l,d,k) b:(q,U,k,D,R).

    Returns (new_a, new_b) with the shared bond truncated to update.rank.
    """
    rank = update.rank
    if isinstance(update, DirectUpdate):
        # theta_{x u l d, y U D R} — einsumsvd over the 3-tensor network.
        left, right = einsumsvd(
            update.svd,
            [g, a, b],
            ["xypq", "puldk", "qUkDR"],
            row="xuld", col="yUDR",
            rank=rank, absorb="both", key=key,
        )
        new_a = left                                 # (x,u,l,d,m) == (p,u,l,d,r)
        new_b = jnp.moveaxis(right, 0, 2)            # (m,y,U,D,R) -> (y,U,m,D,R)
        return new_a, new_b

    if not isinstance(update, QRUpdate):
        check_update(update)  # FullUpdate never reaches here; reject the rest
        raise TypeError(f"{type(update).__name__} cannot be applied without "
                        "the whole-state context (internal dispatch error)")
    qr = gram_qr if update.gram else reshape_qr
    # Bring the small modes (p, k) last; QR over them.
    a_t = jnp.transpose(a, (1, 2, 3, 0, 4))          # (u,l,d,p,k)
    b_t = jnp.transpose(b, (1, 3, 4, 0, 2))          # (U,D,R,q,k)
    qa, ra = qr(a_t, 2)                               # qa:(u,l,d,α,β) ra:(α,β,p,k)
    qb, rb = qr(b_t, 2)                               # qb:(U,D,R,γ,δ) rb:(γ,δ,q,k)
    # einsumsvd on the small network {G, Ra, Rb} (paper step (2)->(4)).
    left, right = einsumsvd(
        update.svd,
        [jnp.asarray(g, dtype=a.dtype), ra, rb],
        ["xypq", "abpk", "cdqk"],
        row="xab", col="ycd",
        rank=rank, absorb="both", key=key,
    )
    # Reabsorb the Q factors (steps (4)->(5)).
    new_a = jnp.einsum("uldab,xabm->xuldm", qa, left)
    new_b = jnp.einsum("UDRcd,mycd->yUmDR", qb, right)
    return new_a, new_b


def _apply_two_site_adjacent(state: PEPS, g, s0: Tuple[int, int],
                             s1: Tuple[int, int], update, key,
                             envs=None) -> PEPS:
    if isinstance(update, FullUpdate):
        # the neighborhood environment is orientation-specific, so the full
        # update handles both orientations itself (no transpose trick)
        from repro.core import full_update as _fu
        return _fu.full_update_bond(state, g, s0, s1, update, key, envs=envs)
    (i0, j0), (i1, j1) = s0, s1
    g = jnp.asarray(g, dtype=state.dtype)
    new = state.copy()
    if i0 == i1 and j1 == j0 + 1:                     # horizontal, left-right
        a, b = state.sites[i0][j0], state.sites[i1][j1]
        na, nb = _two_site_horizontal(a, b, g, update, key)
        new.sites[i0][j0], new.sites[i1][j1] = na, nb
    elif i0 == i1 and j1 == j0 - 1:                   # horizontal, reversed
        gt = jnp.transpose(g, (1, 0, 3, 2))           # swap the two qubits
        return _apply_two_site_adjacent(state, gt, s1, s0, update, key, envs)
    elif j0 == j1 and i1 == i0 + 1:                   # vertical, top-bottom
        # Conjugate by axis swaps: a's (d<->r), b's (u<->l) turn the vertical
        # bond into the canonical horizontal layout.
        a = jnp.transpose(state.sites[i0][j0], (0, 1, 2, 4, 3))
        b = jnp.transpose(state.sites[i1][j1], (0, 2, 1, 3, 4))
        na, nb = _two_site_horizontal(a, b, g, update, key)
        new.sites[i0][j0] = jnp.transpose(na, (0, 1, 2, 4, 3))
        new.sites[i1][j1] = jnp.transpose(nb, (0, 2, 1, 3, 4))
    elif j0 == j1 and i1 == i0 - 1:                   # vertical, reversed
        gt = jnp.transpose(g, (1, 0, 3, 2))
        return _apply_two_site_adjacent(state, gt, s1, s0, update, key, envs)
    else:
        raise ValueError(f"sites {s0}, {s1} are not adjacent")
    return new


def _swap_path(s0: Tuple[int, int], s1: Tuple[int, int]) -> List[Tuple[int, int]]:
    """Lattice path from s1's position to a neighbour of s0 (row then column)."""
    (i0, j0), (i1, j1) = s0, s1
    path = [(i1, j1)]
    i, j = i1, j1
    # walk rows: to row i0 (if columns differ) or to the adjacent row
    while (i != i0) if j != j0 else (abs(i - i0) > 1):
        i += 1 if i0 > i else -1
        path.append((i, j))
    # walk columns until horizontally adjacent
    while abs(j - j0) > 1:
        j += 1 if j0 > j else -1
        path.append((i, j))
    return path


def apply_operator(state: PEPS, g, flat_sites: Sequence[int],
                   update: Optional[object] = None, key=None,
                   envs=None) -> PEPS:
    """Apply a 1- or 2-site operator on arbitrary sites.

    Non-adjacent two-site operators are routed with SWAP chains (paper
    Section II-C1); each SWAP uses the same truncating update.

    ``envs`` (FullUpdate only): cached ``(top, bottom)`` row environments to
    truncate against; omitted, they are recomputed from the current state
    per bond.  Along a SWAP chain the same environments are reused — they go
    slightly stale as the chain progresses (cluster-update trade-off).
    """
    if key is None:
        key = jax.random.PRNGKey(np.bitwise_xor.reduce(
            np.asarray([17, *flat_sites], dtype=np.uint32)))
    if len(flat_sites) == 1:
        return apply_single(state, g, flat_sites[0])
    if len(flat_sites) != 2:
        raise ValueError("only 1- and 2-site operators are supported")
    if update is None:
        update = QRUpdate(rank=max(4, state.max_bond()))
    check_update(update)

    s0, s1 = state.coords(flat_sites[0]), state.coords(flat_sites[1])
    if _adjacent(s0, s1):
        return _apply_two_site_adjacent(state, g, s0, s1, update, key, envs)

    # SWAP-chain routing: walk s1 next to s0, apply, walk back.
    path = _swap_path(s0, s1)
    swap = jnp.asarray(_gates.SWAP, dtype=state.dtype)
    keys = jax.random.split(key, 2 * len(path) + 1)
    ki = 0
    for a, b in zip(path[:-1], path[1:]):
        state = _apply_two_site_adjacent(state, swap, a, b, update, keys[ki],
                                         envs); ki += 1
    state = _apply_two_site_adjacent(state, g, s0, path[-1], update, keys[ki],
                                     envs); ki += 1
    for a, b in zip(reversed(path[1:]), reversed(path[:-1])):
        state = _apply_two_site_adjacent(state, swap, a, b, update, keys[ki],
                                         envs); ki += 1
    return state


def _adjacent(s0, s1) -> bool:
    return abs(s0[0] - s1[0]) + abs(s0[1] - s1[1]) == 1


def normalize_sites(state: PEPS) -> PEPS:
    """Rescale every site tensor to unit max-|entry|, tracking log_scale.

    Keeps ITE numerically bounded; amplitudes are recovered by multiplying
    contraction results with exp(log_scale)."""
    new_sites = []
    log_scale = state.log_scale
    for row in state.sites:
        new_row = []
        for t in row:
            s = jnp.max(jnp.abs(t))
            s = jnp.where(s == 0, 1.0, s)
            new_row.append(t / s)
            log_scale = log_scale + jnp.log(s)
        new_sites.append(new_row)
    return PEPS(new_sites, log_scale)


# ---------------------------------------------------------------------------
# Exact contraction (reference paths for small grids)
# ---------------------------------------------------------------------------

def to_statevector(state: PEPS) -> jnp.ndarray:
    """Exact contraction to a (2,)*n state tensor (small grids only)."""
    nrow, ncol = state.nrow, state.ncol
    # boundary: axes = [phys... (row-major so far)] + [down bond per column]
    bound = jnp.ones((1,) * ncol, dtype=state.dtype)
    n_phys = 0
    for i in range(nrow):
        # insert l_run (dim 1) before the u-block:
        # axes now: [phys (n_phys)] + [l_run] + [u_0..u_{ncol-1}]
        bound = bound.reshape(bound.shape[:n_phys] + (1,) + bound.shape[n_phys:])
        for j in range(ncol):
            t = state.sites[i][j]  # (p,u,l,d,r)
            # axes: [phys (n_phys=base+j)] + [d_new (j)] + [l_run] + [u_j..]
            l_ax = n_phys + j
            u_ax = l_ax + 1
            bound = jnp.tensordot(bound, t, axes=[[l_ax, u_ax], [2, 1]])
            # result axes: [phys][d_new]*j [u_{j+1}..] + (p,d,r)
            # move (p, d, r): p -> phys block end... simpler: move p,d,r into place
            nb = bound.ndim
            p_ax, d_ax, r_ax = nb - 3, nb - 2, nb - 1
            # target: [phys.. p] [d_new.. d] [r_run] [u_{j+1}..]
            bound = jnp.moveaxis(bound, (p_ax, d_ax, r_ax),
                                 (n_phys, n_phys + 1 + j, n_phys + 2 + j))
            n_phys += 1
        # after the row: axes [phys][d_0..d_{ncol-1}][r_run(dim1)]
        bound = bound.reshape(bound.shape[:-1])  # drop r_run (dim 1)
    # drop the final down bonds (all dim 1)
    bound = bound.reshape(bound.shape[:n_phys])
    return bound * jnp.exp(state.log_scale).astype(bound.dtype)


def amplitude_exact(state: PEPS, bits: np.ndarray) -> jnp.ndarray:
    """<bits|psi> by exact one-layer boundary contraction (no truncation)."""
    bits = np.asarray(bits).reshape(state.nrow, state.ncol)
    nrow, ncol = state.nrow, state.ncol
    # project physical indices
    rows = []
    for i in range(nrow):
        row = []
        for j in range(ncol):
            row.append(state.sites[i][j][int(bits[i, j])])  # (u,l,d,r)
        rows.append(row)
    # boundary vector over down bonds
    bound = jnp.ones((1,) * ncol, dtype=state.dtype)
    for i in range(nrow):
        bound = bound.reshape((1,) + bound.shape)  # l_run axis in front
        for j in range(ncol):
            t = rows[i][j]  # (u,l,d,r)
            # bound axes: [l_run] ... wait keep: [d_new_0..d_new_{j-1}, l_run, u_j..]
            bound = jnp.tensordot(bound, t, axes=[[j, j + 1], [1, 0]])
            # appended axes (d, r) -> put d at position j, r at j+1 (new l_run)
            nb = bound.ndim
            bound = jnp.moveaxis(bound, (nb - 2, nb - 1), (j, j + 1))
        bound = bound.reshape(bound.shape[:-1])  # drop r_run (dim 1)
    val = bound.reshape(())
    return val * jnp.exp(state.log_scale).astype(val.dtype)
