"""Boundary-MPS contraction of PEPS (paper Alg. 2/3, Section III-B, IV-A).

Three contraction pipelines, all reducing a 2D network to a scalar through
a pluggable **boundary engine** (:mod:`repro.core.engines`):

* ``contract_onelayer``   — Alg. 2 on a PEPS with no physical indices.
  With ``DirectSVD`` this is the paper's **BMPS**; with ``RandomizedSVD``
  it is **IBMPS** (theta never materialized).
* ``contract_twolayer``   — <bra|ket> keeping the two layers implicit
  (**two-layer IBMPS** when randomized).  The pair bonds of the MPO rows are
  never merged; only the *boundary* carries merged/truncated bonds.
* ``contract_exact_onelayer`` — no-truncation boundary contraction
  (exponential; reference for small grids).

Leg ordering
------------
PEPS site tensors follow the canonical ``(p, u, l, d, r)`` convention — see
the ASCII diagram in :mod:`repro.core.peps` (the single source of truth for
leg ordering).  Boundary-MPS tensors produced here are

* one-layer: ``(l, d, r)`` — left bond, down (dangling), right bond;
* two-layer: ``(l, d_bra, d_ket, r)`` — the bra/ket pair axes stay separate.

Boundary engines
----------------
How a row is absorbed at fixed chi is the job of the **engine** named by
the option's ``engine`` field (default ``"zipup"``):

* ``"zipup"`` (:mod:`repro.core.engines.zipup`) — the paper's zip-up: one
  einsumsvd per column, greedy truncation.  Its row absorption decomposes
  into shard-local *column-block kernels* (:func:`zipup_block` /
  :func:`zipup_block_twolayer`, re-exported here): each absorbs a
  contiguous block of columns, taking the running carry tensor V from the
  block to its left and returning the carry for the block to its right.
  ``_zipup_row*`` run a whole row as one block (``first=last=True``);
  :mod:`repro.core.distributed` composes the same kernels across a device
  mesh with host-issued halos, and :mod:`repro.core.spmd` composes them
  column-at-a-time inside a compiled ``shard_map`` superstep with
  ``ppermute`` halos (chi-saturated rows).  Because the kernels are
  per-site identical to the single-device sweep — same einsumsvd
  subnetworks, same PRNG keys — every execution mode reproduces
  single-device values to rounding and replays the same planner cache
  entries (docs/contraction.md walks the full stack).
* ``"variational"`` (:mod:`repro.core.engines.variational`) — ALS-fitted
  fixed-chi boundary MPS (zip-up-seeded), globally optimal at fixed chi
  where zip-up is greedy; more accurate per chi at a constant-factor FLOP
  premium.  Row-global (no block kernels): distributed sweeps run it
  row-local, and the SPMD wavefront rejects it.

High-level entry points (``amplitude``/``norm_squared``/``inner`` and the
``contract_*`` functions) accept either a :class:`BMPS` option or a
:class:`repro.core.distributed.DistributedBMPS` option and dispatch
accordingly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.einsumsvd import DirectSVD, RandomizedSVD
from repro.core.engines import get_engine
# Re-exports: the zip-up machinery moved to repro.core.engines.zipup in the
# engine-layer refactor; these names are part of this module's public
# surface (distributed/spmd compose the block kernels, tests import the
# row/scalar helpers) and stay importable from here indefinitely.
from repro.core.engines.zipup import (  # noqa: F401
    _init_twolayer_boundary,
    _keys,
    _mps_to_scalar,
    _twolayer_final_scalar,
    _zipup_row,
    _zipup_row_twolayer,
    trivial_twolayer_boundary,
    zipup_block,
    zipup_block_twolayer,
)


@dataclasses.dataclass(frozen=True)
class BMPS:
    """Contraction option: boundary-MPS with the given einsumsvd engine.

    ``svd=DirectSVD()`` reproduces the paper's BMPS; ``svd=RandomizedSVD()``
    gives IBMPS / two-layer IBMPS.  ``chi`` is the truncation bond dim m.
    ``constrain_carry`` (distributed runs): callable applied to the zip-up
    carry V between einsumsvd steps — used to pin its sharding.
    ``engine`` selects the boundary-absorption strategy: a registered name
    (``"zipup"`` — the default greedy truncation — or ``"variational"``,
    the ALS-fitted boundary) or a :class:`~repro.core.engines.BoundaryEngine`
    instance for non-default hyper-parameters.

    All interior sites of a zip-up row share one network signature, so with
    the (default) fused RandomizedSVD the whole sweep reuses a single
    jit-compiled refactorization per row position class — the planner cache
    (repro.core.planner) turns the per-site einsumsvd into a compiled-call
    replay across sites, rows, and sweeps.  The variational engine's local
    updates live in the same cache regime (``planner.fused_fn``).

    ``precision`` selects the numerical policy (:mod:`repro.core.precision`):
    ``"exact"`` (default — bit-identical to the pre-policy code) or
    ``"mixed"`` (one-tier storage demotion around each einsumsvd solve,
    bf16 multiplicands in the Pallas kernel sites, f32 accumulation).  The
    ``svd`` option is wrapped at construction, so engines, the distributed
    halo pipeline, the SPMD superstep, and the full update all inherit the
    policy with no signature changes.
    """
    chi: int
    svd: object = DirectSVD()
    constrain_carry: object = None
    engine: object = "zipup"
    precision: object = "exact"

    def __post_init__(self):
        get_engine(self.engine)  # fail fast on unknown engines
        from repro.core.precision import resolve_precision, wrap_svd
        policy = resolve_precision(self.precision)  # fail fast on bad names
        object.__setattr__(self, "svd", wrap_svd(self.svd, policy))

    @classmethod
    def randomized(cls, chi: int, niter: int = 4, oversample: int = 8,
                   fused: bool = True, **kw) -> "BMPS":
        """IBMPS / two-layer IBMPS option with the fused implicit engine."""
        return cls(chi, svd=RandomizedSVD(niter=niter, oversample=oversample,
                                          fused=fused), **kw)


def _distributed_module(option):
    """Return :mod:`repro.core.distributed` iff ``option`` is distributed.

    The import is lazy (distributed composes this module's kernels);
    anything that is neither a :class:`BMPS` nor a ``DistributedBMPS`` is a
    caller bug and raises immediately — a ``TypeError`` naming the accepted
    option types and the registered boundary engines (the repo's
    option-dispatch convention) — instead of failing deep in a sweep."""
    if isinstance(option, BMPS):
        return None
    from repro.core import distributed
    if isinstance(option, distributed.DistributedBMPS):
        return distributed
    from repro.core.engines import registered_engines
    raise TypeError(
        f"unknown contraction option {type(option).__name__!r}: expected a "
        f"BMPS or DistributedBMPS (engine= one of "
        f"{sorted(registered_engines())}), got {option!r}")


# ---------------------------------------------------------------------------
# One-layer: PEPS without physical indices, site tensors (u, l, d, r)
# ---------------------------------------------------------------------------

def contract_onelayer(rows: Sequence[Sequence[jnp.ndarray]], option: BMPS,
                      key=None) -> jnp.ndarray:
    """Alg. 2: contract an (u,l,d,r)-site PEPS to a scalar."""
    dist = _distributed_module(option)
    if dist is not None:
        return dist.contract_onelayer(rows, option, key)
    eng = get_engine(option.engine)
    nrow = len(rows)
    keys = _keys(key, max(nrow, 2))
    # initial boundary MPS = row 0 with u squeezed: (l, d, r)
    svec = [t.reshape(t.shape[1], t.shape[2], t.shape[3]) for t in rows[0]]
    for i in range(1, nrow):
        svec = eng.absorb_onelayer(svec, rows[i], option.chi, option.svd,
                                   keys[i])
    return eng.final_scalar_onelayer(svec)


def contract_exact_onelayer(rows: Sequence[Sequence[jnp.ndarray]]) -> jnp.ndarray:
    """Exact (no truncation) boundary contraction — exponential bond growth."""
    bound = jnp.ones((1,) * len(rows[0]), dtype=rows[0][0].dtype)
    for row in rows:
        bound = bound.reshape((1,) + bound.shape)  # l_run in front
        for j, t in enumerate(row):
            bound = jnp.tensordot(bound, t, axes=[[j, j + 1], [1, 0]])
            nb = bound.ndim
            bound = jnp.moveaxis(bound, (nb - 2, nb - 1), (j, j + 1))
        bound = bound.reshape(bound.shape[:-1])
    return bound.reshape(())


def merge_layers(bra_rows, ket_rows) -> List[List[jnp.ndarray]]:
    """Explicitly merge <bra| and |ket> into a one-layer PEPS with pair bonds.

    This is the memory-hungry O(r1^4 r2^4) object the two-layer algorithms
    avoid; exposed for baselines and tests."""
    out = []
    for bra_row, ket_row in zip(bra_rows, ket_rows):
        row = []
        for tb, tk in zip(bra_row, ket_row):
            pair = jnp.einsum("puldr,pULDR->uUlLdDrR", tb.conj(), tk)
            s = pair.shape
            row.append(pair.reshape(s[0] * s[1], s[2] * s[3], s[4] * s[5], s[6] * s[7]))
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# Two-layer: <bra|ket> with layers kept implicit (two-layer IBMPS)
# ---------------------------------------------------------------------------

def contract_twolayer(bra_rows, ket_rows, option: BMPS, key=None) -> jnp.ndarray:
    """<bra|ket> keeping the two layers implicit.

    ``bra_rows``/``ket_rows`` are grids of (p,u,l,d,r) site tensors.  The bra
    is conjugated internally.  The sweep starts from a trivial boundary so the
    FIRST row is truncated as well — the boundary bond never exceeds chi
    (the merged-pair r^4 init the naive path would carry is avoided)."""
    dist = _distributed_module(option)
    if dist is not None:
        return dist.contract_twolayer(bra_rows, ket_rows, option, key)
    eng = get_engine(option.engine)
    nrow = len(bra_rows)
    keys = _keys(key, max(nrow, 2))
    svec = trivial_twolayer_boundary(len(bra_rows[0]), bra_rows[0][0].dtype)
    for i in range(nrow):
        svec = eng.absorb_twolayer(svec, bra_rows[i], ket_rows[i],
                                   option.chi, option.svd, keys[i],
                                   option.constrain_carry)
    return eng.final_scalar_twolayer(svec)


# ---------------------------------------------------------------------------
# High-level entry points on PEPS states
# ---------------------------------------------------------------------------

def amplitude(state, bits, option: BMPS, key=None) -> jnp.ndarray:
    """<bits|psi> via approximate one-layer contraction (BMPS/IBMPS)."""
    import numpy as np
    bits = np.asarray(bits).reshape(state.nrow, state.ncol)
    rows = [[state.sites[i][j][int(bits[i, j])] for j in range(state.ncol)]
            for i in range(state.nrow)]
    val = contract_onelayer(rows, option, key)
    return val * jnp.exp(state.log_scale).astype(val.dtype)


# ---------------------------------------------------------------------------
# Batched amplitudes: shared boundary prefix + vmapped final-row close
# ---------------------------------------------------------------------------
#
# The one-layer <x|psi> network of an nrow-row PEPS depends on the bits of
# row i only from the absorption of row i onwards, so queries that share
# the bits of rows 0..nrow-2 share the entire boundary sweep — only the
# final row differs.  And because the final row's dangling (down) bonds all
# have dimension 1, absorbing it never needs truncation: the einsumsvd
# matrices have row dimension <= chi, so the zip-up at the last row is
# rank-lossless and the closing scalar equals the *exact* transfer-matrix
# product of the boundary MPS with the selected final-row tensors.  That
# exact product is a chain of small einsums with no SVDs — trivially
# batchable over the queries' final-row bits.  This pair of facts is the
# serving engine's "environment prefix cache" contract
# (:mod:`repro.core.serving`, docs/serving.md).

def final_row_amplitudes(env, row_sites, bits, log_scale=0.0) -> jnp.ndarray:
    """Batched exact close of a boundary MPS against final-row selections.

    ``env`` is the one-layer boundary MPS after absorbing rows
    ``0..nrow-2`` (tensors ``(l, d, r)``, the "prefix" environment);
    ``row_sites`` the final row's ``(p, u, l, d, r)`` site tensors (their
    down bonds must be dim 1); ``bits`` an integer array ``(B, ncol)`` of
    final-row bit selections.  Returns the ``(B,)`` amplitudes, including
    the state's ``exp(log_scale)`` factor.

    The whole chain — per-column physical-index gather + batched transfer
    einsums — is one jit-compiled function per ``(shapes, B)`` signature
    via :func:`repro.core.planner.fused_fn`, so a serving loop that pads
    batches to a fixed bucket size replays a single compiled executable.
    """
    from repro.core import planner
    bits = jnp.asarray(bits, dtype=jnp.int32)
    if bits.ndim != 2:
        raise ValueError(f"bits must be (B, ncol), got shape {bits.shape}")
    B = int(bits.shape[0])
    ncol = len(env)
    dtype = row_sites[0].dtype
    for t in row_sites:
        if t.shape[3] != 1:
            raise ValueError(
                "final_row_amplitudes needs a bottom row (down bonds dim 1); "
                f"got down bond {t.shape[3]}")
    sig = ("serve_close", ncol, B,
           tuple(tuple(t.shape) for t in env),
           tuple(tuple(t.shape) for t in row_sites),
           jnp.dtype(dtype).name, jax.default_backend())

    def build():
        @jax.jit
        def run(env_ts, site_ts, bits_arr, log_scale_arr):
            acc = jnp.ones((B, 1, 1), dtype=dtype)
            for j in range(ncol):
                sel = jnp.take(site_ts[j], bits_arr[:, j], axis=0)
                sel = sel[:, :, :, 0, :]  # (B, u, l, r): squeeze the dim-1 down bond
                # acc (x=batch, b=env bond, c=row bond) x env_j (b, u, r')
                # x sel (x, u, c, s) -> (x, r', s); plan-cached per shape class.
                acc = planner.cached_einsum("xbc,bur,xucs->xrs",
                                            acc, env_ts[j], sel)
            vals = acc.reshape(B)
            return vals * jnp.exp(log_scale_arr).astype(vals.dtype)
        return run

    fn = planner.fused_fn("serve_close", sig, build)
    return fn(list(env), list(row_sites), bits,
              jnp.asarray(log_scale, dtype=jnp.float64))


def amplitudes(state, bits_batch, option: BMPS, key=None) -> jnp.ndarray:
    """Batched <x|psi>: one boundary sweep per shared row prefix.

    ``bits_batch`` is ``(B, nrow*ncol)`` or ``(B, nrow, ncol)``.  Queries
    are grouped by the bits of rows ``0..nrow-2``; each group pays one
    boundary-MPS prefix sweep (identical keys/engine/einsumsvd sequence to
    per-query :func:`amplitude`), then one batched exact final-row close
    (:func:`final_row_amplitudes`).  Per query this matches
    ``amplitude(state, bits, option, key)`` to rounding.

    This is the uncached batched entry point; :mod:`repro.core.serving`
    adds the LRU environment prefix cache, batch bucketing and the
    request queue on top of the same primitives.
    """
    import numpy as np
    from repro.core.environments import onelayer_prefix_environment
    if _distributed_module(option) is not None:
        raise TypeError("batched amplitudes serve single-device BMPS options")
    bits_arr = np.asarray(bits_batch)
    B = bits_arr.shape[0]
    bits_arr = bits_arr.reshape(B, state.nrow, state.ncol)
    groups: dict = {}
    for idx in range(B):
        prefix = tuple(tuple(int(b) for b in row) for row in bits_arr[idx][:-1])
        groups.setdefault(prefix, []).append(idx)
    vals = [None] * B
    for prefix, idxs in groups.items():
        env = onelayer_prefix_environment(state, prefix, option, key)
        fb = jnp.asarray(bits_arr[idxs, -1, :].astype(np.int32))
        out = final_row_amplitudes(env, state.sites[-1], fb, state.log_scale)
        for k, i in enumerate(idxs):
            vals[i] = out[k]
    return jnp.stack(vals)


def norm_squared(state, option: BMPS, key=None) -> jnp.ndarray:
    """<psi|psi> via two-layer contraction."""
    val = contract_twolayer(state.sites, state.sites, option, key)
    return val * jnp.exp(2.0 * state.log_scale).astype(val.dtype)


def inner(bra, ket, option: BMPS, key=None) -> jnp.ndarray:
    """<bra|ket> via two-layer contraction (both PEPS)."""
    val = contract_twolayer(bra.sites, ket.sites, option, key)
    scale = jnp.exp(bra.log_scale + ket.log_scale)
    return val * scale.astype(val.dtype)
