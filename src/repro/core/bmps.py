"""Boundary-MPS contraction of PEPS (paper Alg. 2/3, Section III-B, IV-A).

Three contraction pipelines, all built on the zip-up ``einsumsvd``:

* ``contract_onelayer``   — Alg. 2 on a PEPS with no physical indices.
  With ``DirectSVD`` this is the paper's **BMPS**; with ``RandomizedSVD``
  it is **IBMPS** (theta never materialized).
* ``contract_twolayer``   — <bra|ket> keeping the two layers implicit
  (**two-layer IBMPS** when randomized).  The pair bonds of the MPO rows are
  never merged; only the *boundary* carries merged/truncated bonds.
* ``contract_exact_onelayer`` — no-truncation boundary contraction
  (exponential; reference for small grids).

Leg ordering
------------
PEPS site tensors follow the canonical ``(p, u, l, d, r)`` convention — see
the ASCII diagram in :mod:`repro.core.peps` (the single source of truth for
leg ordering).  Boundary-MPS tensors produced here are

* one-layer: ``(l, d, r)`` — left bond, down (dangling), right bond;
* two-layer: ``(l, d_bra, d_ket, r)`` — the bra/ket pair axes stay separate.

Shard-local kernels
-------------------
A zip-up row absorption is built from :func:`zipup_block` /
:func:`zipup_block_twolayer`: each absorbs a *contiguous block of columns*
into the boundary, taking the running carry tensor V from the block to its
left and returning the carry for the block to its right.  ``_zipup_row*``
run a whole row as one block (``first=last=True``);
:mod:`repro.core.distributed` composes the same kernels across a device
mesh with host-issued halos, and :mod:`repro.core.spmd` composes them
column-at-a-time inside a compiled ``shard_map`` superstep with
``ppermute`` halos (chi-saturated rows).  Because the kernels are per-site
identical to the single-device sweep — same einsumsvd subnetworks, same
PRNG keys — every execution mode reproduces single-device values to
rounding and replays the same planner cache entries
(docs/contraction.md walks the full stack).

High-level entry points (``amplitude``/``norm_squared``/``inner`` and the
``contract_*`` functions) accept either a :class:`BMPS` option or a
:class:`repro.core.distributed.DistributedBMPS` option and dispatch
accordingly.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.einsumsvd import DirectSVD, RandomizedSVD, einsumsvd


@dataclasses.dataclass(frozen=True)
class BMPS:
    """Contraction option: boundary-MPS with the given einsumsvd engine.

    ``svd=DirectSVD()`` reproduces the paper's BMPS; ``svd=RandomizedSVD()``
    gives IBMPS / two-layer IBMPS.  ``chi`` is the truncation bond dim m.
    ``constrain_carry`` (distributed runs): callable applied to the zip-up
    carry V between einsumsvd steps — used to pin its sharding.

    All interior sites of a zip-up row share one network signature, so with
    the (default) fused RandomizedSVD the whole sweep reuses a single
    jit-compiled refactorization per row position class — the planner cache
    (repro.core.planner) turns the per-site einsumsvd into a compiled-call
    replay across sites, rows, and sweeps.
    """
    chi: int
    svd: object = DirectSVD()
    constrain_carry: object = None

    @classmethod
    def randomized(cls, chi: int, niter: int = 4, oversample: int = 8,
                   fused: bool = True, **kw) -> "BMPS":
        """IBMPS / two-layer IBMPS option with the fused implicit engine."""
        return cls(chi, svd=RandomizedSVD(niter=niter, oversample=oversample,
                                          fused=fused), **kw)


def _keys(key, n):
    if key is None:
        key = jax.random.PRNGKey(0)
    return jax.random.split(key, n)


def _distributed_module(option):
    """Return :mod:`repro.core.distributed` iff ``option`` is distributed.

    The import is lazy (distributed composes this module's kernels);
    anything that is neither a :class:`BMPS` nor a ``DistributedBMPS`` is a
    caller bug and raises immediately instead of failing deep in a sweep."""
    if isinstance(option, BMPS):
        return None
    from repro.core import distributed
    if isinstance(option, distributed.DistributedBMPS):
        return distributed
    raise TypeError(
        f"expected BMPS or DistributedBMPS contraction option, got {option!r}")


# ---------------------------------------------------------------------------
# One-layer: PEPS without physical indices, site tensors (u, l, d, r)
# ---------------------------------------------------------------------------

def zipup_block(v: Optional[jnp.ndarray], svec_block: Sequence[jnp.ndarray],
                row_block: Sequence[jnp.ndarray], chi: int, svd,
                keys: Sequence, first: bool, last: bool):
    """Shard-local one-layer zip-up kernel over a contiguous column block.

    Absorbs ``row_block`` (an MPO slice) into the matching boundary slice
    ``svec_block``, threading the carry tensor ``v`` (axes ``(a, e, b, c)``:
    truncated bond, dangling, boundary bond, MPO bond) through the block.
    ``first`` blocks initialize the carry from column 0 (no truncation);
    ``last`` blocks close it into the final boundary tensor.

    Returns ``(out, carry)``: the einsumsvd at block-local column ``j``
    emits the *output boundary tensor of the previous column*, so a block
    covering columns ``[lo, hi)`` returns tensors for columns
    ``[lo-1, hi-1)`` (plus column ``hi-1`` when ``last``) and the carry for
    column ``hi`` (``None`` when ``last``).  ``keys[j]`` must be the row's
    per-column key for the block's ``j``-th column — the orchestration
    (single-device or distributed) slices one row-level key split so both
    execute identical arithmetic.
    """
    out: List[jnp.ndarray] = []
    j0 = 0
    if first:
        # V0: contract S_0 (b,f,g) with O_0 (f,c,h,k); left bonds b,c are dim 1.
        s0, o0 = svec_block[0], row_block[0]
        v = jnp.einsum("bfg,fchk->bchgk", s0, o0)
        b, c = v.shape[0], v.shape[1]
        v = v.reshape(b * c, v.shape[2], v.shape[3], v.shape[4])  # (a, e, b', c')
        j0 = 1
    for j in range(j0, len(svec_block)):
        sj, oj = svec_block[j], row_block[j]
        left, right = einsumsvd(
            svd,
            [v, sj, oj],
            ["aebc", "bfg", "fchk"],
            row="ae", col="hgk",
            rank=chi, absorb="right", key=keys[j],
        )
        out.append(left)                       # (a, e, m) == (l, d, r)
        # right: (m, h, g, k) == next V's (a, e, b, c)
        v = right
    if last:
        # last V: right bonds g,k are dim 1
        m, h = v.shape[0], v.shape[1]
        out.append(v.reshape(m, h, v.shape[2] * v.shape[3]))
        v = None
    return out, v


def _zipup_row(svec: List[jnp.ndarray], row: Sequence[jnp.ndarray], chi: int,
               svd, key) -> List[jnp.ndarray]:
    """Alg. 3: approximately apply one PEPS row (as an MPO) to the boundary
    MPS ``svec``; zip-up with einsumsvd, truncating to ``chi``."""
    out, _ = zipup_block(None, svec, row, chi, svd, _keys(key, len(svec)),
                         first=True, last=True)
    return out


def _mps_to_scalar(svec: List[jnp.ndarray]) -> jnp.ndarray:
    """Contract an MPS whose dangling (d) indices are all dim 1."""
    acc = jnp.ones((1,), dtype=svec[0].dtype)
    for t in svec:
        mat = t.reshape(t.shape[0], t.shape[2])
        acc = acc @ mat
    return acc.reshape(())


def contract_onelayer(rows: Sequence[Sequence[jnp.ndarray]], option: BMPS,
                      key=None) -> jnp.ndarray:
    """Alg. 2: contract an (u,l,d,r)-site PEPS to a scalar."""
    dist = _distributed_module(option)
    if dist is not None:
        return dist.contract_onelayer(rows, option, key)
    nrow = len(rows)
    keys = _keys(key, max(nrow, 2))
    # initial boundary MPS = row 0 with u squeezed: (l, d, r)
    svec = [t.reshape(t.shape[1], t.shape[2], t.shape[3]) for t in rows[0]]
    for i in range(1, nrow):
        svec = _zipup_row(svec, rows[i], option.chi, option.svd, keys[i])
    return _mps_to_scalar(svec)


def contract_exact_onelayer(rows: Sequence[Sequence[jnp.ndarray]]) -> jnp.ndarray:
    """Exact (no truncation) boundary contraction — exponential bond growth."""
    bound = jnp.ones((1,) * len(rows[0]), dtype=rows[0][0].dtype)
    for row in rows:
        bound = bound.reshape((1,) + bound.shape)  # l_run in front
        for j, t in enumerate(row):
            bound = jnp.tensordot(bound, t, axes=[[j, j + 1], [1, 0]])
            nb = bound.ndim
            bound = jnp.moveaxis(bound, (nb - 2, nb - 1), (j, j + 1))
        bound = bound.reshape(bound.shape[:-1])
    return bound.reshape(())


def merge_layers(bra_rows, ket_rows) -> List[List[jnp.ndarray]]:
    """Explicitly merge <bra| and |ket> into a one-layer PEPS with pair bonds.

    This is the memory-hungry O(r1^4 r2^4) object the two-layer algorithms
    avoid; exposed for baselines and tests."""
    out = []
    for bra_row, ket_row in zip(bra_rows, ket_rows):
        row = []
        for tb, tk in zip(bra_row, ket_row):
            pair = jnp.einsum("puldr,pULDR->uUlLdDrR", tb.conj(), tk)
            s = pair.shape
            row.append(pair.reshape(s[0] * s[1], s[2] * s[3], s[4] * s[5], s[6] * s[7]))
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# Two-layer: <bra|ket> with layers kept implicit (two-layer IBMPS)
# ---------------------------------------------------------------------------

def zipup_block_twolayer(v: Optional[jnp.ndarray],
                         svec_block: Sequence[jnp.ndarray],
                         bra_block, ket_block, chi: int, svd,
                         keys: Sequence, first: bool, last: bool,
                         constrain_carry=None):
    """Shard-local two-layer zip-up kernel over a contiguous column block.

    The two-layer sibling of :func:`zipup_block`; identical block/carry
    semantics, with carry axes ``(a, e1, e2, b, c1, c2)`` (truncated bond,
    bra/ket dangling, boundary bond, bra/ket pair bonds).  Boundary tensors
    are truncated; the row's pair bonds (c1,c2 / k1,k2) stay separate — the
    implicit structure that gives two-layer IBMPS its complexity edge
    (Table II).  The carry is the only tensor a distributed sweep ships
    between neighboring shards (the forward halo)."""
    out: List[jnp.ndarray] = []
    j0 = 0
    if first:
        tb0, tk0 = bra_block[0].conj(), ket_block[0]
        s0 = svec_block[0]
        # S_0:(b,f1,f2,g), bra:(p,f1,c1,h1,k1), ket:(p,f2,c2,h2,k2); b,c1,c2 dim 1
        v = jnp.einsum("bfFg,pfchk,pFCHK->bcChHgkK", s0, tb0, tk0,
                       optimize="optimal")
        sh = v.shape
        v = v.reshape(sh[0] * sh[1] * sh[2], sh[3], sh[4], sh[5], sh[6], sh[7])
        # v: (a, e1, e2, b, c1, c2)
        j0 = 1
    for j in range(j0, len(svec_block)):
        sj = svec_block[j]
        tb, tk = bra_block[j].conj(), ket_block[j]
        left, right = einsumsvd(
            svd,
            [v, sj, tb, tk],
            ["aeEbcC", "bfFg", "pfchk", "pFCHK"],
            row="aeE", col="hHgkK",
            rank=chi, absorb="right", key=keys[j],
        )
        out.append(left)                       # (a, e1, e2, m)
        v = right                              # (m, h1, h2, g, k1, k2)
        if constrain_carry is not None:
            v = constrain_carry(v)
    if last:
        m = v.shape[0]
        out.append(v.reshape(m, v.shape[1], v.shape[2],
                             v.shape[3] * v.shape[4] * v.shape[5]))
        v = None
    return out, v


def _zipup_row_twolayer(svec: List[jnp.ndarray], bra_row, ket_row, chi, svd,
                        key, constrain_carry=None) -> List[jnp.ndarray]:
    """One full row absorption = :func:`zipup_block_twolayer` as one block."""
    out, _ = zipup_block_twolayer(None, svec, bra_row, ket_row, chi, svd,
                                  _keys(key, len(svec)), first=True, last=True,
                                  constrain_carry=constrain_carry)
    return out


def _init_twolayer_boundary(bra_row, ket_row) -> List[jnp.ndarray]:
    """First-row boundary: merge only the horizontal pair bonds."""
    out = []
    for tb, tk in zip(bra_row, ket_row):
        # (p,1,l1,d1,r1)* x (p,1,l2,d2,r2) -> (l1 l2, d1, d2, r1 r2)
        pair = jnp.einsum("puldr,pULDR->lLdDrR", tb.conj(), tk)
        s = pair.shape
        out.append(pair.reshape(s[0] * s[1], s[2], s[3], s[4] * s[5]))
    return out


def _twolayer_final_scalar(svec: List[jnp.ndarray]) -> jnp.ndarray:
    acc = jnp.ones((1,), dtype=svec[0].dtype)
    for t in svec:
        mat = t.reshape(t.shape[0], t.shape[-1])
        acc = acc @ mat
    return acc.reshape(())


def trivial_twolayer_boundary(ncol: int, dtype) -> List[jnp.ndarray]:
    one = jnp.ones((1, 1, 1, 1), dtype=dtype)
    return [one for _ in range(ncol)]


def contract_twolayer(bra_rows, ket_rows, option: BMPS, key=None) -> jnp.ndarray:
    """<bra|ket> keeping the two layers implicit.

    ``bra_rows``/``ket_rows`` are grids of (p,u,l,d,r) site tensors.  The bra
    is conjugated internally.  The sweep starts from a trivial boundary so the
    FIRST row is zip-up-truncated as well — the boundary bond never exceeds
    chi (the merged-pair r^4 init the naive path would carry is avoided)."""
    dist = _distributed_module(option)
    if dist is not None:
        return dist.contract_twolayer(bra_rows, ket_rows, option, key)
    nrow = len(bra_rows)
    keys = _keys(key, max(nrow, 2))
    svec = trivial_twolayer_boundary(len(bra_rows[0]), bra_rows[0][0].dtype)
    for i in range(nrow):
        svec = _zipup_row_twolayer(svec, bra_rows[i], ket_rows[i],
                                   option.chi, option.svd, keys[i],
                                   option.constrain_carry)
    return _twolayer_final_scalar(svec)


# ---------------------------------------------------------------------------
# High-level entry points on PEPS states
# ---------------------------------------------------------------------------

def amplitude(state, bits, option: BMPS, key=None) -> jnp.ndarray:
    """<bits|psi> via approximate one-layer contraction (BMPS/IBMPS)."""
    import numpy as np
    bits = np.asarray(bits).reshape(state.nrow, state.ncol)
    rows = [[state.sites[i][j][int(bits[i, j])] for j in range(state.ncol)]
            for i in range(state.nrow)]
    val = contract_onelayer(rows, option, key)
    return val * jnp.exp(state.log_scale).astype(val.dtype)


def norm_squared(state, option: BMPS, key=None) -> jnp.ndarray:
    """<psi|psi> via two-layer contraction."""
    val = contract_twolayer(state.sites, state.sites, option, key)
    return val * jnp.exp(2.0 * state.log_scale).astype(val.dtype)


def inner(bra, ket, option: BMPS, key=None) -> jnp.ndarray:
    """<bra|ket> via two-layer contraction (both PEPS)."""
    val = contract_twolayer(bra.sites, ket.sites, option, key)
    scale = jnp.exp(bra.log_scale + ket.log_scale)
    return val * scale.astype(val.dtype)
