"""Numerical health checks with graceful degradation (the runtime guard).

A multi-hour ITE sweep or VQE optimization dies in one of a few well-known
ways: a randomized SVD degenerates on an ill-conditioned implicit operator
(Halko et al. arXiv:0909.4061 — the power iteration amplifies garbage when
the sketch loses rank), a mixed-precision solve underflows the f32 Gram
clamp, a Pallas kernel crashes on one device, or a boundary row collapses
to exact zero.  Without a guard the failure surfaces steps later as a NaN
energy — or worse, never surfaces and the run silently returns garbage.

This module wraps the library's single truncation seam
(:func:`repro.core.einsumsvd.einsumsvd` routes every solve through
:func:`guarded_solve`) with a **detect -> escalate -> retry** loop:

* **Detection** — after each solve the factors are checked for NaN/Inf
  (``check_finite``) and spectrum collapse (``norm_floor``: the largest
  singular value at or below the floor means the boundary row lost all
  weight).  Exceptions from the solve (kernel faults, compile failures)
  are failures too.  :mod:`repro.core.full_update` additionally checks the
  ALS output and the bond truncation fidelity against ``fidelity_floor``.
* **Escalation ladder** — the retry replays the *same* solve (same
  operands, same key) on a strictly more conservative configuration, one
  rung per attempt, cumulative:

  1. ``exact_svd``   — RandomizedSVD -> DirectSVD (deterministic LAPACK
     path; no sketch, no power iteration to go wrong);
  2. ``exact_precision`` — a mixed-policy wrapper is removed, so the solve
     runs in the operand's full storage dtype;
  3. ``dense_kernel`` — every kernel-dispatch site is forced dense for the
     retry (``repro.kernels.dispatch.forced_dense``).

  When the failure was an *exception* (kernel faults raise; numerical
  garbage doesn't) the ``dense_kernel`` rung is tried first — the crash
  almost certainly came from a kernel, and falling back to dense keeps the
  cheaper randomized solver.
* **Bounded retries** — ``max_retries`` caps the ladder.  An exhausted
  ladder raises :class:`GuardExhaustedError` (structured: site, cause,
  attempts, the event trail) — the guard *never* lets NaN escape as a
  result.

Every detection and recovery ticks process-global counters (surfaced
through ``planner.stats()`` next to the cache and dispatch counters) and
appends a :class:`GuardEvent` to the active guard's :class:`GuardReport`,
which ``ite_run`` / ``run_vqe`` attach to their results.

The guard is opt-in (``ite_run(..., guard=True)`` or a
:class:`GuardConfig`): with no guard active, :func:`guarded_solve` adds
one dict lookup to the hot path and failures propagate exactly as before.
Fault injection (:mod:`repro.core.faults`) makes every rung of the ladder
deterministically testable on CPU; the recovery contract is measured in
``tests/test_runtime_guard.py`` against the ``core/precision.py`` budgets.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import faults

# ---------------------------------------------------------------------------
# Process-global counters (merged into planner.stats())
# ---------------------------------------------------------------------------

_COUNTERS: Dict[str, int] = {
    "guard_nan_events": 0,         # NaN/Inf detected in a solve's factors
    "guard_collapse_events": 0,    # spectrum collapsed below norm_floor
    "guard_exception_events": 0,   # the solve raised (kernel fault, ...)
    "guard_fidelity_events": 0,    # full-update fidelity below the floor
    "guard_retries": 0,            # total retry attempts
    "guard_rung_exact_svd": 0,
    "guard_rung_exact_precision": 0,
    "guard_rung_dense_kernel": 0,
    "guard_recovered": 0,          # failures that a ladder rung fixed
    "guard_degraded_accepted": 0,  # fidelity floor missed, run continued
    "guard_exhausted": 0,          # ladders that ran out -> structured raise
}


def global_counters() -> Dict[str, int]:
    """Process-global guard counters (a copy; planner.stats() merges these)."""
    return dict(_COUNTERS)


def reset_global_counters() -> None:
    for k in _COUNTERS:
        _COUNTERS[k] = 0


# ---------------------------------------------------------------------------
# Config / report structures
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """What the guard checks and how far it escalates.

    ``max_retries``     caps ladder attempts per failing unit.
    ``check_finite``    NaN/Inf detection on every guarded solve.
    ``norm_floor``      collapse threshold: largest singular value <= floor
                        counts as a failure (0.0 = only exact zero).
    ``fidelity_floor``  full update only: bond truncation fidelity below
                        this retries the bond with an exact seed (0.0 = off).
    ``fidelity_strict`` raise when the fidelity floor is still missed after
                        the retry; default records + warns and continues
                        (a low fidelity is degraded accuracy, not
                        corruption — unlike NaN it is a judgement call).
    """
    max_retries: int = 3
    check_finite: bool = True
    norm_floor: float = 0.0
    fidelity_floor: float = 0.0
    fidelity_strict: bool = False


@dataclasses.dataclass
class GuardEvent:
    """One detection or recovery, in causal order."""
    site: str       # "einsumsvd" | "full_update"
    cause: str      # "nan" | "collapse" | "exception" | "fidelity"
    attempt: int    # 0 = initial detection, 1.. = retry attempts
    action: str     # "detected" | "retry:<rung>" | "recovered:<rung>"
                    # | "degraded_accepted" | "exhausted"
    detail: str = ""


@dataclasses.dataclass
class GuardReport:
    """The structured trail a guarded run attaches to its result."""
    events: List[GuardEvent] = dataclasses.field(default_factory=list)
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)

    def record(self, event: GuardEvent) -> None:
        self.events.append(event)

    def tick(self, counter: str) -> None:
        _COUNTERS[counter] += 1
        self.counters[counter] = self.counters.get(counter, 0) + 1

    @property
    def ok(self) -> bool:
        """No failure was left unrecovered (degraded-accepted still counts
        as ok — the result is finite, only less accurate than asked)."""
        return not any(e.action == "exhausted" for e in self.events)

    def causes(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            if e.attempt == 0:
                out[e.cause] = out.get(e.cause, 0) + 1
        return out


class GuardExhaustedError(RuntimeError):
    """The escalation ladder ran out without producing a healthy result.

    Structured: ``site``/``cause``/``attempts`` plus the event trail, so a
    service can log exactly which unit failed and what was tried — instead
    of propagating NaN into a caller-visible energy."""

    def __init__(self, site: str, cause: str, attempts: int,
                 events: List[GuardEvent]):
        rungs = [e.action for e in events if e.action.startswith("retry:")]
        super().__init__(
            f"runtime guard exhausted at site {site!r}: cause={cause!r} "
            f"survived {attempts} escalation attempts ({', '.join(rungs)})")
        self.site = site
        self.cause = cause
        self.attempts = attempts
        self.events = events


# ---------------------------------------------------------------------------
# The active-guard stack
# ---------------------------------------------------------------------------

_STACK: List["RuntimeGuard"] = []


class RuntimeGuard:
    """An activated guard: config + report, installed via ``with``."""

    def __init__(self, config: Optional[GuardConfig] = None):
        self.config = config or GuardConfig()
        self.report = GuardReport()

    def __enter__(self) -> "RuntimeGuard":
        _STACK.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _STACK.remove(self)


def current() -> Optional[RuntimeGuard]:
    """The innermost active guard, or None (the unguarded fast path)."""
    return _STACK[-1] if _STACK else None


def resolve(guard) -> Optional[RuntimeGuard]:
    """Normalize the ``guard=`` argument of ite_run/run_vqe.

    ``None``/``False`` -> no guard; ``True`` -> defaults; a
    :class:`GuardConfig` or :class:`RuntimeGuard` is used as-is."""
    if guard is None or guard is False:
        return None
    if guard is True:
        return RuntimeGuard(GuardConfig())
    if isinstance(guard, GuardConfig):
        return RuntimeGuard(guard)
    if isinstance(guard, RuntimeGuard):
        return guard
    raise TypeError(
        f"guard must be None/bool/GuardConfig/RuntimeGuard, got {guard!r}")


# ---------------------------------------------------------------------------
# Failure detection
# ---------------------------------------------------------------------------

def _corrupt(s: jnp.ndarray, action: str) -> jnp.ndarray:
    """Apply an injected einsumsvd.result corruption to the spectrum."""
    if action == "nan":
        return s * jnp.nan
    if action == "inf":
        return s * jnp.inf
    if action == "zero":
        return jnp.zeros_like(s)
    raise ValueError(f"unknown einsumsvd.result fault action {action!r}")


def _detect_svd(config: GuardConfig, u, s, v) -> Optional[str]:
    """One host sync: NaN/Inf anywhere in the factors, or collapsed s."""
    if not config.check_finite:
        return None
    bad = ((~jnp.isfinite(s)).any() | (~jnp.isfinite(u)).any()
           | (~jnp.isfinite(v)).any())
    smax = jnp.max(jnp.abs(s))
    flags = np.asarray(jnp.stack([bad.astype(jnp.float32),
                                  smax.astype(jnp.float32)]))
    if flags[0]:
        return "nan"
    if not np.isfinite(flags[1]) or flags[1] <= config.norm_floor:
        return "collapse"
    return None


# ---------------------------------------------------------------------------
# The escalation ladder
# ---------------------------------------------------------------------------

def _ladder(option, exception_first: bool) -> List[Tuple[str, object, bool]]:
    """Cumulative ``(rung_name, svd_option, force_dense)`` escalation steps.

    Each rung keeps every previous rung's downgrade: the precision unwrap
    retries with the exact SVD *and* full precision; the dense rung adds
    forced-dense kernels on top of both."""
    from repro.core.einsumsvd import DirectSVD, RandomizedSVD
    from repro.core.precision import PrecisionWrapped

    rungs: List[Tuple[str, object, bool]] = []
    cur = option
    base = cur.inner if isinstance(cur, PrecisionWrapped) else cur
    if isinstance(base, RandomizedSVD):
        exact = DirectSVD(cutoff=base.cutoff)
        cur = (PrecisionWrapped(exact, cur.policy)
               if isinstance(cur, PrecisionWrapped) else exact)
        rungs.append(("exact_svd", cur, False))
    if isinstance(cur, PrecisionWrapped):
        cur = cur.inner
        rungs.append(("exact_precision", cur, False))
    rungs.append(("dense_kernel", cur, True))
    if exception_first:
        # A raising solve is a kernel/compile problem, not a numerical one:
        # fall back to dense first and keep the cheaper solver if that heals.
        rungs.insert(0, ("dense_kernel", option, True))
    return rungs


def _run_solve(option, op, rank, key, force_dense: bool):
    from repro.kernels import dispatch
    if force_dense:
        with dispatch.forced_dense():
            return option(op, rank, key)
    return option(op, rank, key)


def guarded_solve(option, op, rank: int, key=None):
    """Run an einsumsvd option under the active guard (the library seam).

    With no guard active this is ``option(op, rank, key)`` plus the
    ``einsumsvd.result`` fault hook (so tests can show the *unguarded*
    behavior: corruption propagates).  With a guard: detect, escalate,
    retry — see the module docstring."""
    guard = current()
    spec = faults.should_fire("einsumsvd.result")
    err = None
    try:
        u, s, v = _run_solve(option, op, rank, key, False)
        if spec is not None:
            s = _corrupt(s, spec.action)
        if guard is None:
            return u, s, v
        cause = _detect_svd(guard.config, u, s, v)
    except Exception as e:  # noqa: BLE001 — every solve failure is guardable
        if guard is None:
            raise
        err = e
        cause = "exception"
    if cause is None:
        return u, s, v

    config, report = guard.config, guard.report
    report.tick(f"guard_{cause}_events")
    report.record(GuardEvent("einsumsvd", cause, 0, "detected",
                             detail=repr(err) if err else ""))
    rungs = _ladder(option, exception_first=(cause == "exception"))
    attempts = 0
    for rung, opt, force_dense in rungs[:config.max_retries]:
        attempts += 1
        report.tick("guard_retries")
        report.tick(f"guard_rung_{rung}")
        report.record(GuardEvent("einsumsvd", cause, attempts,
                                 f"retry:{rung}"))
        retry_spec = faults.should_fire("einsumsvd.result")
        try:
            u, s, v = _run_solve(opt, op, rank, key, force_dense)
            if retry_spec is not None:
                s = _corrupt(s, retry_spec.action)
            recheck = _detect_svd(config, u, s, v)
        except Exception as e:  # noqa: BLE001
            err = e
            recheck = "exception"
        if recheck is None:
            report.tick("guard_recovered")
            report.record(GuardEvent("einsumsvd", cause, attempts,
                                     f"recovered:{rung}"))
            return u, s, v
        cause = recheck
    report.tick("guard_exhausted")
    report.record(GuardEvent("einsumsvd", cause, attempts, "exhausted",
                             detail=repr(err) if err else ""))
    raise GuardExhaustedError("einsumsvd", cause, attempts,
                              list(report.events))


# ---------------------------------------------------------------------------
# Full-update bond guard (called from repro.core.full_update)
# ---------------------------------------------------------------------------

def check_bond(guard: RuntimeGuard, ar, br, fid) -> Optional[str]:
    """Failure cause of a full-update ALS result, or None when healthy.

    ``"nan"`` when the optimized pair is non-finite, ``"fidelity"`` when
    the bond truncation fidelity misses the configured floor (NaN fidelity
    counts — it means the metric itself degenerated)."""
    config = guard.config
    if config.check_finite:
        bad = ((~jnp.isfinite(ar)).any() | (~jnp.isfinite(br)).any())
        if bool(np.asarray(bad)):
            return "nan"
    if config.fidelity_floor > 0.0:
        f = float(np.asarray(jnp.real(fid)))
        if not f >= config.fidelity_floor:   # NaN compares False -> fails
            return "fidelity"
    return None


def bond_failure(guard: RuntimeGuard, cause: str, retried: bool,
                 detail: str = "") -> None:
    """Record the outcome of a full-update bond failure.

    First detection (``retried=False``) ticks the cause counters; the
    post-retry call either raises (NaN after an exact retry is exhausted;
    fidelity raises only under ``fidelity_strict``) or records the bond as
    degraded-but-accepted."""
    report = guard.report
    if not retried:
        report.tick(f"guard_{cause}_events")
        report.tick("guard_retries")
        report.tick("guard_rung_exact_svd")
        report.record(GuardEvent("full_update", cause, 0, "detected", detail))
        report.record(GuardEvent("full_update", cause, 1, "retry:exact_svd"))
        return
    if cause == "fidelity" and not guard.config.fidelity_strict:
        report.tick("guard_degraded_accepted")
        report.record(GuardEvent("full_update", cause, 1,
                                 "degraded_accepted", detail))
        warnings.warn(
            f"full-update bond fidelity below floor after exact retry "
            f"({detail}); continuing degraded (fidelity_strict=False)",
            RuntimeWarning)
        return
    report.tick("guard_exhausted")
    report.record(GuardEvent("full_update", cause, 1, "exhausted", detail))
    raise GuardExhaustedError("full_update", cause, 1, list(report.events))


def bond_recovered(guard: RuntimeGuard, cause: str) -> None:
    guard.report.tick("guard_recovered")
    guard.report.record(GuardEvent("full_update", cause, 1,
                                   "recovered:exact_svd"))


@contextlib.contextmanager
def suspended():
    """Temporarily deactivate the per-solve guard stack.

    The per-solve detector (:func:`_detect_svd`) host-syncs the factors,
    which is illegal inside ``jax.grad``/``jit``/``vmap`` tracing — so the
    gradient path (:func:`repro.core.vqe.vqe_energy_and_grad` and the
    batched drivers) traces its evaluations with the stack suspended and
    guards at *evaluation* granularity instead: host-check the (energy,
    gradient) output, replay the whole evaluation one ladder rung more
    conservative on failure.  The stack is restored on exit, so per-solve
    guarding of any surrounding host-driven code is untouched."""
    saved = _STACK[:]
    del _STACK[:]
    try:
        yield
    finally:
        _STACK[:] = saved


@contextlib.contextmanager
def maybe(guard: Optional[RuntimeGuard]):
    """``with maybe(resolve(guard)):`` — nullcontext when guard is None."""
    if guard is None:
        yield None
    else:
        with guard:
            yield guard
