"""Randomized SVD with an implicit tensor-network operator (paper Alg. 4).

The operator ``A : C^{col} -> C^{row}`` is given as an *uncontracted* tensor
network; ``A q`` and ``A* p`` are evaluated by attaching the sketch to the
network and contracting with an optimal path — the dense A is never formed.
This is what turns BMPS into IBMPS (and two-layer BMPS into two-layer IBMPS):
the asymptotic win of Table II comes purely from never materializing theta.
"""
from __future__ import annotations

import string
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.core.orthogonalize import orthogonalize_cols, tall_project
from repro.core.svd_grad import svd_reg

_ALL_LABELS = string.ascii_letters


class ImplicitOperator:
    """A linear operator defined by a tensor network.

    Parameters
    ----------
    tensors:     the network's tensors.
    subscripts:  one label string per tensor (a la einsum), e.g. ``"aebc"``.
    row:         labels of the operator's output (row) modes.
    col:         labels of the operator's input (column) modes.

    Every label that appears in ``subscripts`` but not in ``row``/``col`` is
    summed over.  ``row`` and ``col`` must be dangling (appear exactly once).
    """

    def __init__(self, tensors: Sequence[jnp.ndarray], subscripts: Sequence[str],
                 row: str, col: str):
        assert len(tensors) == len(subscripts)
        self.tensors = list(tensors)
        self.subscripts = list(subscripts)
        self.row = row
        self.col = col
        label_dims = {}
        for t, sub in zip(self.tensors, self.subscripts):
            assert t.ndim == len(sub), (t.shape, sub)
            for ax, ch in enumerate(sub):
                d = t.shape[ax]
                if ch in label_dims and label_dims[ch] != d:
                    raise ValueError(f"label {ch}: dim mismatch {label_dims[ch]} vs {d}")
                label_dims[ch] = d
        self.label_dims = label_dims
        used = set("".join(self.subscripts))
        free = [c for c in _ALL_LABELS if c not in used]
        if not free:
            raise ValueError("ran out of einsum labels")
        self._sketch = free[0]
        self.dtype = jnp.result_type(*[t.dtype for t in self.tensors])

    @property
    def row_shape(self) -> Tuple[int, ...]:
        return tuple(self.label_dims[c] for c in self.row)

    @property
    def col_shape(self) -> Tuple[int, ...]:
        return tuple(self.label_dims[c] for c in self.col)

    @property
    def row_size(self) -> int:
        n = 1
        for s in self.row_shape:
            n *= s
        return n

    @property
    def col_size(self) -> int:
        n = 1
        for s in self.col_shape:
            n *= s
        return n

    def _einsum(self, extra_subs: List[str], extra_tensors: List[jnp.ndarray],
                out: str, conjugate: bool = False) -> jnp.ndarray:
        subs = self.subscripts + extra_subs
        tensors = [t.conj() for t in self.tensors] if conjugate else self.tensors
        tensors = tensors + extra_tensors
        expr = ",".join(subs) + "->" + out
        # Plan-cached path: the optimal-path search runs once per distinct
        # (expr, shapes) instead of once per matvec (see core/planner.py).
        return planner.cached_einsum(expr, *tensors)

    def dense(self) -> jnp.ndarray:
        """Materialize A as a tensor of shape row_shape + col_shape."""
        return self._einsum([], [], self.row + self.col)

    def matvecs(self, q: jnp.ndarray) -> jnp.ndarray:
        """A @ q for a sketch q of shape col_shape + (k,)."""
        z = self._sketch
        return self._einsum([self.col + z], [q], self.row + z)

    def rmatvecs(self, p: jnp.ndarray) -> jnp.ndarray:
        """A^H @ p for a sketch p of shape row_shape + (k,)."""
        z = self._sketch
        return self._einsum([self.row + z], [p], self.col + z, conjugate=True)


def _random_sketch(key, shape, dtype):
    if jnp.issubdtype(dtype, jnp.complexfloating):
        kr, ki = jax.random.split(key)
        real_dt = jnp.float64 if dtype == jnp.complex128 else jnp.float32
        re = jax.random.uniform(kr, shape, real_dt, -1.0, 1.0)
        im = jax.random.uniform(ki, shape, real_dt, -1.0, 1.0)
        return (re + 1j * im).astype(dtype)
    return jax.random.uniform(key, shape, dtype, -1.0, 1.0)


def randomized_svd(
    op: ImplicitOperator,
    rank: int,
    n_iter: int = 4,
    oversample: int = 8,
    key=None,
    gram_final: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Alg. 4: truncated SVD of an implicit operator, single pass over tensors.

    Returns ``(u, s, v)`` with ``u``: row_shape+(rank,), ``s``: (rank,),
    ``v``: (rank,)+col_shape, such that ``A ~= u @ diag(s) @ v``.

    ``gram_final`` (beyond-paper, EXPERIMENTS.md SSPerf): the paper's Alg. 4
    line 7 runs a dense SVD of the k x Ncol matrix ``P^H A`` — on a
    distributed backend that is a large matricized factorization, exactly
    what Alg. 5 exists to avoid.  Instead we Gram-QR the tall ``T = A^H P``
    (all-GEMM, reshape-free) and SVD only its k x k R factor locally:
    ``A ~= P (Q_t R_t)^H = (P U) S (Q_t V)^H``.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    max_rank = min(op.row_size, op.col_size)
    rank = min(rank, max_rank)
    k = min(rank + oversample, max_rank)

    # Differentiation contract: the random sketch is a PRNG constant
    # (stop_gradient territory by construction — it carries no dependence
    # on the operator), but the power iteration itself IS differentiated:
    # every orthogonalization routes through the regularized Gram-QR chain
    # (eigh_reg + the eps clamp in core/orthogonalize.py), so the tangent
    # of the converged range basis P tracks how A's row space rotates under
    # dA.  Stopping the gradient at P instead would amputate exactly the
    # rank-growing components of dA (the part of the perturbation that
    # leaves the captured range) — measured as a 100% loss on some VQE
    # gradient components (see docs/vqe.md and tests/test_vqe_grad.py).
    q = _random_sketch(key, op.col_shape + (k,), op.dtype)
    p = orthogonalize_cols(op.matvecs(q))
    for _ in range(n_iter):
        q = orthogonalize_cols(op.rmatvecs(p))
        p = orthogonalize_cols(op.matvecs(q))

    # B = P^H A  (as a k x col matrix), computed via A^H P (one more pass).
    t = op.rmatvecs(p)                               # col_shape + (k,)
    if gram_final:
        from repro.core.orthogonalize import gram_qr
        q_t, r_t = gram_qr(t, 1)                     # q_t: col+(k,), r_t: (k,k)
        # A ~= P T^H = P (q_t r_t)^H = P r_t^H q_t^H
        # (svd_reg == jnp.linalg.svd forward; regularized JVP.)
        u_small, s, vh_small = svd_reg(r_t.conj().T)          # k x k, local
        u_small, s, vh_small = u_small[:, :rank], s[:rank], vh_small[:rank]
        # Final projections: tall operand x small matrix — the tall-apply
        # kernel site (dense path is the exact pre-kernel tensordot).
        u = tall_project(p, u_small, 1)              # row_shape+(rank,)
        # v = (q_t @ vh_small^H)^H: rank x col
        v = tall_project(q_t.conj(), vh_small.T, 1)  # col_shape+(rank,)
        v = jnp.moveaxis(v, -1, 0)
        return u, s, v
    b = t.conj().reshape(op.col_size, k).T           # (k, ncol)
    u_small, s, vh = svd_reg(b)
    u_small, s, vh = u_small[:, :rank], s[:rank], vh[:rank]
    u = tall_project(p, u_small, 1)                  # row_shape+(rank,)
    v = vh.reshape((rank,) + op.col_shape)
    return u, s, v
