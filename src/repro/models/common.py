"""Shared model machinery: param specs, logical-axis sharding rules, norms,
rotary embeddings (RoPE and M-RoPE), and the model Config dataclass.

Params are declared as trees of :class:`P_` leaves carrying logical axis
names; a rules table maps logical axes to mesh axes with automatic
divisibility fallback (an arch with 15 heads simply replicates its attention
weights over the ``model`` axis instead of failing).  Hillclimbing sharding
= editing the rules and re-lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Version-compat shard_map lives with the mesh utilities so the PEPS SPMD
# superstep (repro.core.spmd) can share it without importing the LM stack;
# re-exported here because every models/ call site historically uses it.
from repro.launch.mesh import shard_map  # noqa: F401


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class P_:
    """A parameter leaf: shape + logical axis names + init."""
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small
    dtype: Any = None             # defaults to cfg param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_leaf(x):
    return isinstance(x, P_)


def tree_map_specs(fn: Callable[[P_], Any], specs):
    return jax.tree_util.tree_map(fn, specs, is_leaf=_is_leaf)


def init_params(specs, key, param_dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_leaf)
    keys = jax.random.split(key, max(len(leaves), 2))
    out = []
    for spec, k in zip(leaves, keys):
        dtype = spec.dtype or param_dtype
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            scale = 0.02 if spec.init == "normal" else 0.006
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * scale)
                       .astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(specs, param_dtype=jnp.float32):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype), specs)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

# default rules: FSDP over 'data' (embed axis of weights), TP/EP over 'model'
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": "data",          # FSDP / ZeRO-3 weight sharding
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "expert": "model",
    "expert_mlp": None,
    "vocab": "model",
    "seq": None,
    "kv_seq": None,
    "layers": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_bc": None,
    "conv": None,
    "frames": None,
    # activation-only axes
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
}


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def resolve_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
                 mesh: Mesh, rules: Dict[str, Any]) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback."""
    used = set()
    parts = []
    for dim, name in zip(shape, logical):
        choice = None
        rule = rules.get(name) if name else None
        if rule is not None:
            axes = (rule,) if isinstance(rule, str) else tuple(rule)
            axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
            if axes and dim % _axes_size(mesh, axes) == 0:
                choice = axes if len(axes) > 1 else axes[0]
                used.update(axes)
        parts.append(choice)
    return P(*parts)


def param_shardings(specs, mesh: Mesh, rules=None):
    rules = rules or DEFAULT_RULES
    return tree_map_specs(
        lambda s: NamedSharding(mesh, resolve_spec(s.shape, s.logical, mesh, rules)),
        specs)


def manual_axes() -> set:
    """Mesh axes currently under manual control (inside a shard_map)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return set()
        return {n for n, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)}
    except Exception:  # noqa: BLE001 — old JAX / no tracing context
        pass
    # Legacy JAX: inside shard_map the manual axes are exactly the named
    # axes bound in the axis environment.
    try:
        from jax._src import core as _core
        return set(_core.get_axis_env().axis_sizes)
    except Exception:  # noqa: BLE001
        return set()


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    skip = manual_axes()
    return tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and a not in skip)


def sharding_constraint(x: jnp.ndarray, mesh: Mesh, spec) -> jnp.ndarray:
    """``with_sharding_constraint`` that degrades to a no-op where unsafe.

    On legacy JAX (no ``jax.shard_map``), emitting a full-mesh sharding
    constraint inside a partial-auto shard_map trips XLA's
    ``IsManualSubgroup`` check and aborts compilation; the constraint is
    only a placement hint, so inside legacy manual regions we drop it.
    """
    if manual_axes() and not hasattr(jax, "shard_map"):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain(x: jnp.ndarray, mesh: Mesh, logical: Sequence[Optional[str]],
              rules=None) -> jnp.ndarray:
    """with_sharding_constraint via logical axes (activations).

    Axes currently under manual shard_map control are dropped from the spec
    (mixing Manual with Auto in one PartitionSpec is an error)."""
    rules = dict(rules or DEFAULT_RULES)
    skip = manual_axes()
    if skip:
        rules = {k: (tuple(a for a in ((v,) if isinstance(v, str) else v)
                           if a not in skip) or None) if v else v
                 for k, v in rules.items()}
        rules = {k: (v[0] if isinstance(v, tuple) and len(v) == 1 else v)
                 for k, v in rules.items()}
    spec = resolve_spec(x.shape, logical, mesh, rules)
    return sharding_constraint(x, mesh, spec)


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab: int = 32000
    qk_norm: bool = False
    rope_theta: float = 1e4
    attn_impl: str = "chunked"            # chunked (flash-style) | naive
    attn_chunk: int = 2048
    # dry-run accounting knobs: XLA cost_analysis counts a scanned body once,
    # so the dry-run compiles with layer_unroll in {1, k} and extrapolates.
    layer_unroll: int = 1
    group_unroll: int = 1                 # hybrid: outer (group) scan unroll
    attn_unroll: bool = False             # unroll the kv-chunk scan (<=16 steps)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # vlm
    # MoE
    moe_impl: str = "fsdp_gather"         # fsdp_gather | expert_tp (inference)
    moe_psum_dtype: str = "f32"           # f32 | bf16 (combine all-reduce)
    n_experts: int = 0
    top_k: int = 0
    d_expert_ff: int = 0
    moe_dense_residual: bool = False        # arctic
    capacity_factor: float = 1.25
    norm_topk: bool = True
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_chunk: int = 128                  # SSD chunk length (perf knob)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    # hybrid (zamba2)
    hybrid_group: int = 6                   # 1 shared attn per group
    # encdec (whisper)
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # numerics / training
    param_dtype: Any = jnp.float32
    act_dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "nothing"         # nothing | dots
    # notes for the dry-run table
    sub_quadratic: bool = False             # supports long_500k decode

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


# ---------------------------------------------------------------------------
# Norms / activations / rotary embeddings
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with f32 accumulation but NO f32 materialization of x.

    The sum of squares is computed as a dot with f32 accumulation
    (``preferred_element_type``), so the input is read in its own dtype.
    The naive ``x.astype(f32)`` formulation makes the saved residual's
    first backward use a convert — which XLA hoists out of the scan as a
    whole-stack bf16->f32 materialization of ALL saved activations
    (measured: +38 GiB temp and ~2x memory-roofline term on granite-8b;
    see EXPERIMENTS.md §Perf)."""
    sumsq = jnp.einsum("...d,...d->...", x, x,
                       preferred_element_type=jnp.float32)
    rs = jax.lax.rsqrt(sumsq / x.shape[-1] + eps)
    return x * rs[..., None].astype(x.dtype) * scale.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))


def _rope_freqs(d_half: int, theta: float, dtype=jnp.float32):
    return 1.0 / (theta ** (jnp.arange(d_half, dtype=dtype) / d_half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """Rotate-half RoPE. x: (B, S, H, D), positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = _rope_freqs(half, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray,
                sections: Tuple[int, int, int],
                theta: float = 1e6) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) for (t, h, w);
    ``sections`` split the half-dim (sum == d_head // 2)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(half, theta)  # (half,)
    # choose the position stream per frequency slot
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)  # (half,)
    pos = positions.astype(jnp.float32)             # (3, B, S)
    ang = jnp.take(pos, sec_id, axis=0)             # (half, B, S) -> gather
    ang = jnp.moveaxis(ang, 0, -1) * freqs          # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE; logits (B,S,V) any float dtype, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def checkpoint_policy(cfg: Config):
    """Resolve cfg.remat_policy to a jax.checkpoint policy."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable
