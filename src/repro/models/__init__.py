"""LM substrate: model definitions for the assigned architectures."""
