"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

``input_specs`` supplies precomputed log-mel *frame embeddings* (B, T, D);
the conv frontend is out of scope per the assignment.  The encoder is a
non-causal transformer; the decoder adds cross-attention against the
encoder output with per-layer precomputed cross K/V.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import Config, P_, constrain, cross_entropy, rms_norm, swiglu
from repro.models import attention as att
from repro.models.transformer import mlp_specs


def encdec_specs(cfg: Config) -> Dict[str, object]:
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    return {
        "embed": P_((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        # learned decoder positions; sized for the largest assigned decode shape
        "pos_dec": P_((32768, cfg.d_model), (None, "embed"), init="small"),
        "enc": {
            "ln1": P_((Le, cfg.d_model), ("layers", "embed"), init="ones"),
            "ln2": P_((Le, cfg.d_model), ("layers", "embed"), init="ones"),
            "attn": att.attn_specs(cfg, Le),
            "mlp": mlp_specs(cfg, Le),
        },
        "enc_norm": P_((cfg.d_model,), ("embed",), init="ones"),
        "dec": {
            "ln1": P_((Ld, cfg.d_model), ("layers", "embed"), init="ones"),
            "ln_x": P_((Ld, cfg.d_model), ("layers", "embed"), init="ones"),
            "ln2": P_((Ld, cfg.d_model), ("layers", "embed"), init="ones"),
            "attn": att.attn_specs(cfg, Ld),
            "xattn": att.attn_specs(cfg, Ld),
            "mlp": mlp_specs(cfg, Ld),
        },
        "final_norm": P_((cfg.d_model,), ("embed",), init="ones"),
        "head": P_((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def encode(params, cfg: Config, mesh, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T, D) stub embeddings -> encoder output (B, T, D)."""
    x = frames.astype(cfg.act_dtype)
    x = constrain(x, mesh, ("batch", None, "act_embed"))

    def body(carry, lp):
        h = carry + att.attn_apply(rms_norm(carry, lp["ln1"]), lp["attn"],
                                   cfg, mesh, positions=None, causal=False,
                                   rope=False)
        z = rms_norm(h, lp["ln2"])
        out = h + swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
        return constrain(out, mesh, ("batch", None, "act_embed")), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=_ckpt_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=cfg.layer_unroll)
    return rms_norm(x, params["enc_norm"])


def decode_train(params, cfg: Config, mesh, tokens, enc_out) -> jnp.ndarray:
    """Teacher-forced decoder -> logits (B, S, V)."""
    b, s = tokens.shape
    x = params["embed"].astype(cfg.act_dtype)[tokens]
    x = x + params["pos_dec"].astype(x.dtype)[:s][None]
    x = constrain(x, mesh, ("batch", None, "act_embed"))

    def body(carry, lp):
        h = carry + att.attn_apply(rms_norm(carry, lp["ln1"]), lp["attn"],
                                   cfg, mesh, positions=None, causal=True,
                                   rope=False)
        mk, mv = att.cross_kv(enc_out, lp["xattn"], cfg)
        h = h + att.cross_attn_apply(rms_norm(h, lp["ln_x"]), lp["xattn"],
                                     cfg, mesh, mk, mv)
        z = rms_norm(h, lp["ln2"])
        out = h + swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
        return constrain(out, mesh, ("batch", None, "act_embed")), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=_ckpt_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["dec"],
                        unroll=cfg.layer_unroll)
    x = rms_norm(x, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))


def loss_fn(params, cfg: Config, mesh, batch) -> jnp.ndarray:
    enc_out = encode(params, cfg, mesh, batch["frames"])
    logits = decode_train(params, cfg, mesh, batch["tokens"], enc_out)
    return cross_entropy(logits, batch["labels"])


def init_cache_specs(cfg: Config, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kv, dh = cfg.n_kv_heads, cfg.d_head
    Ld = cfg.n_layers
    return {
        "k": jax.ShapeDtypeStruct((Ld, batch, max_seq, kv, dh), dtype),
        "v": jax.ShapeDtypeStruct((Ld, batch, max_seq, kv, dh), dtype),
        "xk": jax.ShapeDtypeStruct((Ld, batch, cfg.enc_frames, kv, dh), dtype),
        "xv": jax.ShapeDtypeStruct((Ld, batch, cfg.enc_frames, kv, dh), dtype),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical_axes(cfg: Config):
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax, "xk": ax, "xv": ax, "index": ()}


def decode_step(params, cfg: Config, mesh, cache, token, positions=None):
    """One decoder token with self-KV cache + precomputed cross-KV."""
    index = cache["index"]
    x = params["embed"].astype(cfg.act_dtype)[token]
    zero = jnp.zeros((), index.dtype) if hasattr(index, "dtype") else 0
    pos_emb = jax.lax.dynamic_slice(params["pos_dec"].astype(x.dtype),
                                    (index, zero), (1, cfg.d_model))
    x = x + pos_emb[None]

    def body(carry, lp_kv):
        lp, ck, cv, xk, xv = lp_kv
        h_in = rms_norm(carry, lp["ln1"])
        a_out, nk, nv = att.attn_decode(h_in, lp["attn"], cfg, mesh, ck, cv,
                                        index, positions=None, rope=False)
        h = carry + a_out
        h = h + att.cross_attn_apply(rms_norm(h, lp["ln_x"]), lp["xattn"],
                                     cfg, mesh, xk.astype(carry.dtype),
                                     xv.astype(carry.dtype))
        z = rms_norm(h, lp["ln2"])
        out = h + swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
        return out, (nk, nv)

    x, (k_all, v_all) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]), unroll=cfg.layer_unroll)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))[:, 0]
    new_cache = dict(cache)
    new_cache.update({"k": k_all, "v": v_all, "index": index + 1})
    return logits, new_cache
