"""Decoder stacks: dense / MoE / SSM / hybrid, with scan-over-layers + remat.

Layer parameters are stacked on a leading ``layers`` axis and consumed by
``lax.scan``; blocks are wrapped in ``jax.checkpoint`` when cfg.remat.  The
hybrid (zamba2) stack interleaves a *shared-weight* attention block between
groups of Mamba2 blocks with a python-level group loop (9 groups), keeping
the compiled program small while preserving the shared-parameter structure.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (Config, checkpoint_policy as _ckpt_policy, P_, batch_axes, constrain,
                                 cross_entropy, rms_norm, swiglu)
from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def mlp_specs(cfg: Config, n_layers: int) -> Dict[str, P_]:
    d, f = cfg.d_model, cfg.d_ff
    L = (n_layers,)
    return {
        "wg": P_(L + (d, f), ("layers", "embed", "mlp")),
        "wu": P_(L + (d, f), ("layers", "embed", "mlp")),
        "wd": P_(L + (f, d), ("layers", "mlp", "embed")),
    }


def block_specs(cfg: Config, n_layers: int) -> Dict[str, object]:
    L = (n_layers,)
    specs: Dict[str, object] = {
        "ln1": P_(L + (cfg.d_model,), ("layers", "embed"), init="ones"),
        "ln2": P_(L + (cfg.d_model,), ("layers", "embed"), init="ones"),
        "attn": att.attn_specs(cfg, n_layers),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_mod.moe_specs(cfg, n_layers)
        if cfg.moe_dense_residual:
            specs["mlp"] = mlp_specs(cfg, n_layers)
    else:
        specs["mlp"] = mlp_specs(cfg, n_layers)
    return specs


def lm_specs(cfg: Config) -> Dict[str, object]:
    specs: Dict[str, object] = {
        "embed": P_((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "final_norm": P_((cfg.d_model,), ("embed",), init="ones"),
        "head": P_((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }
    if cfg.family == "ssm":
        specs["ssm_ln"] = P_((cfg.n_layers, cfg.d_model), ("layers", "embed"),
                             init="ones")
        specs["ssm"] = ssm_mod.ssm_specs(cfg, cfg.n_layers)
    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_group
        per = cfg.hybrid_group - 1           # mamba blocks per group
        specs["ssm_ln"] = P_((n_groups, per, cfg.d_model),
                             ("layers", None, "embed"), init="ones")
        specs["ssm"] = jax.tree_util.tree_map(
            lambda s: P_((n_groups,) + s.shape, ("layers",) + s.logical,
                         init=s.init),
            ssm_mod.ssm_specs(cfg, per),
            is_leaf=lambda x: isinstance(x, P_))
        specs["shared"] = block_specs(
            dataclassesreplace_dense(cfg), 1)   # one shared attn+mlp block
    else:
        specs["layers"] = block_specs(cfg, cfg.n_layers)
    return specs


def dataclassesreplace_dense(cfg: Config) -> Config:
    import dataclasses
    return dataclasses.replace(cfg, family="dense")


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _dense_block(x, lp, cfg: Config, mesh, positions):
    h = x + att.attn_apply(rms_norm(x, lp["ln1"]), lp["attn"], cfg, mesh,
                           positions)
    z = rms_norm(h, lp["ln2"])
    if cfg.family == "moe":
        m = moe_mod.moe_apply(z, lp["moe"], cfg, mesh)
        if cfg.moe_dense_residual:
            m = m + swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    else:
        m = swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    out = h + m
    return constrain(out, mesh, ("batch", None, "act_embed"))


def _stack_forward(x, params, cfg: Config, mesh, positions):
    """scan the dense/moe decoder blocks over the stacked layer params."""
    def body(carry, lp):
        return _dense_block(carry, lp, cfg, mesh, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=_ckpt_policy(cfg))
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=cfg.layer_unroll)
    return x


def _ssm_stack_forward(x, params, cfg: Config, mesh):
    def body(carry, lp):
        ln, sp = lp
        out = carry + ssm_mod.ssm_apply(rms_norm(carry, ln), sp, cfg, mesh)
        return constrain(out, mesh, ("batch", None, "act_embed")), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=_ckpt_policy(cfg))
    x, _ = jax.lax.scan(body, x, (params["ssm_ln"], params["ssm"]),
                        unroll=cfg.layer_unroll)
    return x


def _hybrid_forward(x, params, cfg: Config, mesh, positions):
    """Nested scans: outer over groups, inner over the group's mamba blocks;
    the shared attention block (same weights every group) closes each group.
    ``cfg.layer_unroll`` unrolls the inner scan, ``cfg.group_unroll`` the
    outer one (dry-run accounting knobs)."""
    shared = jax.tree_util.tree_map(lambda a: a[0], params["shared"])
    dense_cfg = dataclassesreplace_dense(cfg)

    def mamba_body(carry, lp):
        ln, sp = lp
        out = carry + ssm_mod.ssm_apply(rms_norm(carry, ln), sp, cfg, mesh)
        return constrain(out, mesh, ("batch", None, "act_embed")), None

    if cfg.remat:
        mamba_body = jax.checkpoint(mamba_body,
                                    policy=_ckpt_policy(cfg))

    def group_body(carry, grp):
        h, _ = jax.lax.scan(mamba_body, carry, (grp["ssm_ln"], grp["ssm"]),
                            unroll=cfg.layer_unroll)
        h = _dense_block(h, shared, dense_cfg, mesh, positions)
        return h, None

    x, _ = jax.lax.scan(group_body, x,
                        {"ssm_ln": params["ssm_ln"], "ssm": params["ssm"]},
                        unroll=cfg.group_unroll)
    return x


def default_positions(cfg: Config, b: int, s: int):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos, (3, b, s))     # text-only M-RoPE default
    return pos


def forward(params, cfg: Config, mesh, tokens, positions=None,
            embeddings: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence forward -> logits (B, S, V)."""
    if embeddings is None:
        x = params["embed"].astype(cfg.act_dtype)[tokens]
    else:
        x = embeddings.astype(cfg.act_dtype)
    x = constrain(x, mesh, ("batch", None, "act_embed"))
    b, s = x.shape[:2]
    if positions is None:
        positions = default_positions(cfg, b, s)
    if cfg.family == "ssm":
        x = _ssm_stack_forward(x, params, cfg, mesh)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(x, params, cfg, mesh, positions)
    else:
        x = _stack_forward(x, params, cfg, mesh, positions)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
    return constrain(logits, mesh, ("batch", None, "vocab"))


def loss_fn(params, cfg: Config, mesh, batch) -> jnp.ndarray:
    logits = forward(params, cfg, mesh, batch["tokens"],
                     positions=batch.get("positions"),
                     embeddings=batch.get("embeddings"))
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Decode (serve_step) and prefill
# ---------------------------------------------------------------------------

def init_cache_specs(cfg: Config, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the decode cache (also used to allocate)."""
    kv, dh = cfg.n_kv_heads, cfg.d_head
    if cfg.family == "ssm":
        return {
            "ssm_h": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
                 cfg.ssm_head_dim), jnp.float32),
            "ssm_conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.conv_width - 1,
                 cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state), dtype),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_group
        per = cfg.hybrid_group - 1
        return {
            "ssm_h": jax.ShapeDtypeStruct(
                (n_groups, per, batch, cfg.ssm_heads, cfg.ssm_state,
                 cfg.ssm_head_dim), jnp.float32),
            "ssm_conv": jax.ShapeDtypeStruct(
                (n_groups, per, batch, cfg.conv_width - 1,
                 cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state), dtype),
            "k": jax.ShapeDtypeStruct((n_groups, batch, max_seq, kv, dh), dtype),
            "v": jax.ShapeDtypeStruct((n_groups, batch, max_seq, kv, dh), dtype),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((cfg.n_layers, batch, max_seq, kv, dh), dtype),
        "v": jax.ShapeDtypeStruct((cfg.n_layers, batch, max_seq, kv, dh), dtype),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_logical_axes(cfg: Config):
    kv_axis = "kv_heads"
    base = {
        "k": ("layers", "batch", "kv_seq", kv_axis, "head_dim"),
        "v": ("layers", "batch", "kv_seq", kv_axis, "head_dim"),
        "index": (),
    }
    if cfg.family == "ssm":
        return {
            "ssm_h": ("layers", "batch", "ssm_heads", "ssm_state", None),
            "ssm_conv": ("layers", "batch", None, "ssm_inner"),
            "index": (),
        }
    if cfg.family == "hybrid":
        return {
            "ssm_h": ("layers", None, "batch", "ssm_heads", "ssm_state", None),
            "ssm_conv": ("layers", None, "batch", None, "ssm_inner"),
            "k": ("layers", "batch", "kv_seq", kv_axis, "head_dim"),
            "v": ("layers", "batch", "kv_seq", kv_axis, "head_dim"),
            "index": (),
        }
    return base


def decode_step(params, cfg: Config, mesh, cache, token, positions=None):
    """One decode step: token (B, 1) -> (logits (B, V), new cache)."""
    x = params["embed"].astype(cfg.act_dtype)[token]     # (B, 1, D)
    index = cache["index"]
    b = token.shape[0]
    if positions is None:
        positions = jnp.broadcast_to(index, (b, 1)).astype(jnp.int32)
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions, (3, b, 1))

    if cfg.family == "ssm":
        def body(carry, lp):
            x_c = carry
            ln, sp, h_st, conv_st = lp
            out, (h_new, conv_new) = ssm_mod.ssm_decode(
                rms_norm(x_c, ln), sp, cfg, mesh, (h_st, conv_st))
            return x_c + out, (h_new, conv_new)

        x, (h_all, conv_all) = jax.lax.scan(
            body, x, (params["ssm_ln"], params["ssm"],
                      cache["ssm_h"], cache["ssm_conv"]),
            unroll=cfg.layer_unroll)
        new_cache = {"ssm_h": h_all, "ssm_conv": conv_all, "index": index + 1}
    elif cfg.family == "hybrid":
        shared = jax.tree_util.tree_map(lambda a: a[0], params["shared"])
        dense_cfg = dataclassesreplace_dense(cfg)

        def mamba_body(carry, lp):
            ln, sp, h_st, conv_st = lp
            out, (h_new, conv_new) = ssm_mod.ssm_decode(
                rms_norm(carry, ln), sp, cfg, mesh, (h_st, conv_st))
            return carry + out, (h_new, conv_new)

        def group_body(carry, grp):
            h, (h_g, c_g) = jax.lax.scan(
                mamba_body, carry,
                (grp["ln"], grp["ssm"], grp["h"], grp["conv"]),
                unroll=cfg.layer_unroll)
            h, nk, nv = _attn_block_decode(h, shared, dense_cfg, mesh,
                                           grp["k"], grp["v"], index,
                                           positions)
            return h, (h_g, c_g, nk, nv)

        x, (h_all, conv_all, k_all, v_all) = jax.lax.scan(
            group_body, x,
            {"ln": params["ssm_ln"], "ssm": params["ssm"],
             "h": cache["ssm_h"], "conv": cache["ssm_conv"],
             "k": cache["k"], "v": cache["v"]},
            unroll=cfg.group_unroll)
        new_cache = {
            "ssm_h": h_all, "ssm_conv": conv_all,
            "k": k_all, "v": v_all, "index": index + 1,
        }
    else:
        def body(carry, lp_kv):
            lp, ck, cv = lp_kv
            out, nk, nv = _attn_block_decode(carry, lp, cfg, mesh, ck, cv,
                                             index, positions)
            return out, (nk, nv)

        x, (k_all, v_all) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]),
            unroll=cfg.layer_unroll)
        new_cache = {"k": k_all, "v": v_all, "index": index + 1}

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))[:, 0]
    return logits, new_cache


def _attn_block_decode(x, lp, cfg: Config, mesh, ck, cv, index, positions):
    h_in = rms_norm(x, lp["ln1"])
    a_out, nk, nv = att.attn_decode(h_in, lp["attn"], cfg, mesh, ck, cv, index,
                                    positions)
    h = x + a_out
    z = rms_norm(h, lp["ln2"])
    if cfg.family == "moe":
        m = moe_mod.moe_apply(z, lp["moe"], cfg, mesh)
        if cfg.moe_dense_residual:
            m = m + swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    else:
        m = swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
    return h + m, nk, nv


def prefill(params, cfg: Config, mesh, tokens, max_seq: int,
            positions=None, cache_dtype=jnp.bfloat16):
    """Prefill the decode cache from a full prompt (all LM families)."""
    if cfg.family in ("ssm", "hybrid"):
        return _prefill_recurrent(params, cfg, mesh, tokens, max_seq,
                                  positions, cache_dtype)
    x = params["embed"].astype(cfg.act_dtype)[tokens]
    x = constrain(x, mesh, ("batch", None, "act_embed"))
    b, s = tokens.shape
    if positions is None:
        positions = default_positions(cfg, b, s)

    def body(carry, lp):
        h_in = rms_norm(carry, lp["ln1"])
        a_out, (k, v) = att.attn_prefill(h_in, lp["attn"], cfg, mesh, positions)
        h = carry + a_out
        z = rms_norm(h, lp["ln2"])
        if cfg.family == "moe":
            m = moe_mod.moe_apply(z, lp["moe"], cfg, mesh)
            if cfg.moe_dense_residual:
                m = m + swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
        else:
            m = swiglu(z, lp["mlp"]["wg"], lp["mlp"]["wu"], lp["mlp"]["wd"])
        out = constrain(h + m, mesh, ("batch", None, "act_embed"))
        pad = max_seq - s
        k = jnp.pad(k.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        return out, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, policy=_ckpt_policy(cfg))
    x, (k_all, v_all) = jax.lax.scan(body, x, params["layers"],
                                     unroll=cfg.layer_unroll)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"].astype(x.dtype))
    cache = {"k": k_all, "v": v_all, "index": jnp.asarray(s, jnp.int32)}
    return logits, cache


def _prefill_recurrent(params, cfg: Config, mesh, tokens, max_seq: int,
                       positions=None, cache_dtype=jnp.bfloat16):
    """SSM/hybrid prefill: full-sequence forward that also emits the decode
    states (final SSD state + conv tail per layer; KV for shared attn)."""
    b, s = tokens.shape
    x = params["embed"].astype(cfg.act_dtype)[tokens]
    x = constrain(x, mesh, ("batch", None, "act_embed"))
    if positions is None:
        positions = default_positions(cfg, b, s)
    index = jnp.asarray(s, jnp.int32)

    if cfg.family == "ssm":
        def body(carry, lp):
            ln, sp = lp
            out, st = ssm_mod.ssm_apply(rms_norm(carry, ln), sp, cfg, mesh,
                                        return_state=True)
            new = constrain(carry + out, mesh, ("batch", None, "act_embed"))
            return new, st

        x, (h_all, conv_all) = jax.lax.scan(
            body, x, (params["ssm_ln"], params["ssm"]),
            unroll=cfg.layer_unroll)
        cache = {"ssm_h": h_all, "ssm_conv": conv_all.astype(cache_dtype),
                 "index": index}
    else:
        shared = jax.tree_util.tree_map(lambda a: a[0], params["shared"])
        dense_cfg = dataclassesreplace_dense(cfg)

        def mamba_body(carry, lp):
            ln, sp = lp
            out, st = ssm_mod.ssm_apply(rms_norm(carry, ln), sp, cfg, mesh,
                                        return_state=True)
            new = constrain(carry + out, mesh, ("batch", None, "act_embed"))
            return new, st

        def group_body(carry, grp):
            h, (h_g, c_g) = jax.lax.scan(
                mamba_body, carry, (grp["ssm_ln"], grp["ssm"]),
                unroll=cfg.layer_unroll)
            a_out, (k, v) = att.attn_prefill(rms_norm(h, shared["ln1"]),
                                             shared["attn"], dense_cfg, mesh,
                                             positions)
            h2 = h + a_out
            z = rms_norm(h2, shared["ln2"])
            h2 = h2 + swiglu(z, shared["mlp"]["wg"], shared["mlp"]["wu"],
                             shared["mlp"]["wd"])
            h2 = constrain(h2, mesh, ("batch", None, "act_embed"))
            pad = max_seq - s
            k = jnp.pad(k.astype(cache_dtype),
                        ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v.astype(cache_dtype),
                        ((0, 0), (0, pad), (0, 0), (0, 0)))
            return h2, (h_g, c_g, k, v)

        x, (h_all, conv_all, k_all, v_all) = jax.lax.scan(
            group_body, x,
            {"ssm_ln": params["ssm_ln"], "ssm": params["ssm"]},
            unroll=cfg.group_unroll)
        cache = {"ssm_h": h_all, "ssm_conv": conv_all.astype(cache_dtype),
                 "k": k_all, "v": v_all, "index": index}

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"].astype(x.dtype))
    return logits, cache
