"""Mamba2 (SSD) block: chunked scan for train/prefill, O(1)-state decode.

The chunked SSD formulation (intra-chunk masked GEMM + inter-chunk state
carry) is implemented both as the Pallas kernel (repro.kernels.ssd_scan) and
as the pure-jnp path here used for lowering; they share the recurrence
h_t = exp(a_t) h_{t-1} + B_t (x) x_t,  y_t = C_t . h_t.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Config, P_, constrain, rms_norm


def ssm_specs(cfg: Config, n_layers: int) -> Dict[str, P_]:
    d, din = cfg.d_model, cfg.d_inner
    h, n, g = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    conv_dim = din + 2 * g * n
    L = (n_layers,)
    return {
        "wz": P_(L + (d, din), ("layers", "embed", "ssm_inner")),
        "wx": P_(L + (d, din), ("layers", "embed", "ssm_inner")),
        "wb": P_(L + (d, g * n), ("layers", "embed", "ssm_bc")),
        "wc": P_(L + (d, g * n), ("layers", "embed", "ssm_bc")),
        "wdt": P_(L + (d, h), ("layers", "embed", "ssm_heads")),
        "dt_bias": P_(L + (h,), ("layers", "ssm_heads"), init="zeros"),
        "a_log": P_(L + (h,), ("layers", "ssm_heads"), init="zeros"),
        "d_skip": P_(L + (h,), ("layers", "ssm_heads"), init="ones"),
        "conv_w": P_(L + (cfg.conv_width, conv_dim), ("layers", "conv", "ssm_inner")),
        "norm": P_(L + (din,), ("layers", "ssm_inner"), init="ones"),
        "wo": P_(L + (din, d), ("layers", "ssm_inner", "embed")),
    }


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, L, C), w: (W, C) -> causal depthwise conv via shifted adds."""
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i if i else None]
        out = out + shifted * w[width - 1 - i]
    return out


def ssd_chunked(x, b, c, a, chunk: int = 128, return_state: bool = False):
    """Pure-jnp chunked SSD (matches kernels/ssd_scan semantics).

    x: (B, H, L, P), b/c: (B, H, L, N), a: (B, H, L) log-decay.  Batch and
    head axes stay UNMERGED so GSPMD keeps batch on 'data' and heads on
    'model' (merging them forces replication).  Vectorized over chunks with
    a lax.scan carrying the (N, P) state per series."""
    bsz, h, l, p = x.shape
    n = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
    lp = x.shape[2]
    nc = lp // chunk
    xc = x.reshape(bsz, h, nc, chunk, p).astype(jnp.float32)
    bc = b.reshape(bsz, h, nc, chunk, n).astype(jnp.float32)
    cc = c.reshape(bsz, h, nc, chunk, n).astype(jnp.float32)
    ac = a.reshape(bsz, h, nc, chunk).astype(jnp.float32)
    cum = jnp.cumsum(ac, axis=-1)                       # (B, H, NC, C)
    total = cum[..., -1]                                # (B, H, NC)
    ii = jnp.arange(chunk)
    mask = ii[:, None] >= ii[None, :]
    decay = jnp.exp(cum[..., :, None] - cum[..., None, :])
    lmat = jnp.where(mask, decay, 0.0)                  # (B, H, NC, C, C)
    smat = jnp.einsum("zhcin,zhcjn->zhcij", cc, bc) * lmat
    y_intra = jnp.einsum("zhcij,zhcjp->zhcip", smat, xc)
    # chunk -> chunk state recurrence (the only sequential part; tiny body)
    w_in = jnp.exp(total[..., None] - cum)[..., None] * bc
    h_chunk = jnp.einsum("zhcjn,zhcjp->zhcnp", w_in, xc)

    def step(hs, inp):
        h_c, tot = inp                                  # (B,H,N,P), (B,H)
        h_new = jnp.exp(tot)[..., None, None] * hs + h_c
        return h_new, hs                                # emit INCOMING state

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    h_last, h_in = jax.lax.scan(step, h0,
                                (jnp.moveaxis(h_chunk, 2, 0),
                                 jnp.moveaxis(total, 2, 0)))
    h_in = jnp.moveaxis(h_in, 0, 2)                     # (B, H, NC, N, P)
    y_inter = jnp.einsum("zhcin,zhcnp->zhcip", cc * jnp.exp(cum)[..., None], h_in)
    y = (y_intra + y_inter).reshape(bsz, h, lp, p)[:, :, :l]
    if return_state:
        return y.astype(x.dtype), h_last                # (B, H, N, P)
    return y.astype(x.dtype)


def _split_proj(x, p, cfg: Config):
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    xin = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))
    bproj = jnp.einsum("bsd,de->bse", x, p["wb"].astype(x.dtype))
    cproj = jnp.einsum("bsd,de->bse", x, p["wc"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))
    return z, xin, bproj, cproj, dt


def ssm_apply(x, p, cfg: Config, mesh, chunk: int = None,
              return_state: bool = False):
    """Full-sequence Mamba2 block. x: (B, S, D).

    With ``return_state``, also returns the decode cache for this layer:
    (h_final (B,H,N,P), conv_state (B,W-1,conv_dim)) — the prefill path."""
    bsz, s, d = x.shape
    chunk = chunk or cfg.ssm_chunk
    h, n, g, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_head_dim
    z, xin, bp, cp, dt = _split_proj(x, p, cfg)
    xbc_raw = jnp.concatenate([xin, bp, cp], axis=-1)
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc_raw,
                                             p["conv_w"].astype(x.dtype)))
    xin, bp, cp = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt    # (B, S, H) log-decay
    xh = xin.reshape(bsz, s, h, pdim)
    xs = xh * dt[..., None].astype(xh.dtype)
    per_group = h // g
    bp = bp.reshape(bsz, s, g, n)
    cp = cp.reshape(bsz, s, g, n)
    bg = jnp.repeat(bp, per_group, axis=2)
    cg = jnp.repeat(cp, per_group, axis=2)
    # Explicitly head-shard the SSD inputs: the group->head jnp.repeat of
    # B/C severs GSPMD's sharding propagation and silently replicates every
    # (B,H,L,*) SSD intermediate over 'model' (measured 10x memory-term
    # inflation on zamba2/mamba2 — see EXPERIMENTS.md SSPerf).
    hx = constrain(jnp.moveaxis(xs, 2, 1), mesh,
                   ("batch", "act_heads", None, None))
    hb = constrain(jnp.moveaxis(bg, 2, 1), mesh,
                   ("batch", "act_heads", None, None))
    hc = constrain(jnp.moveaxis(cg, 2, 1), mesh,
                   ("batch", "act_heads", None, None))
    ha = constrain(jnp.moveaxis(a, 2, 1), mesh, ("batch", "act_heads", None))
    ssd_out = ssd_chunked(hx, hb, hc, ha,
                          chunk=chunk, return_state=return_state)
    y, h_final = ssd_out if return_state else (ssd_out, None)
    y = jnp.moveaxis(y, 1, 2)                             # (B, S, H, P)
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    y = constrain(y, mesh, ("batch", None, "act_mlp"))
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype))
    if return_state:
        w = cfg.conv_width
        conv_state = xbc_raw[:, -(w - 1):]                # (B, W-1, conv_dim)
        return out, (h_final, conv_state)
    return out


def ssm_decode(x, p, cfg: Config, mesh, state: Tuple[jnp.ndarray, jnp.ndarray]
               ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token decode. x: (B, 1, D); state = (h (B,H,N,P), conv (B,W-1,C))."""
    bsz = x.shape[0]
    h_state, conv_state = state
    hh, n, g, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_head_dim
    z, xin, bp, cp, dt = _split_proj(x, p, cfg)
    xbc = jnp.concatenate([xin, bp, cp], axis=-1)[:, 0]   # (B, C)
    w = p["conv_w"].astype(x.dtype)
    width = w.shape[0]
    hist = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w)
    new_conv = hist[:, 1:]
    xbc = jax.nn.silu(conv_out)
    xin, bp, cp = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt
    xh = xin.reshape(bsz, hh, pdim)
    xs = (xh.astype(jnp.float32) * dt[..., None])
    per_group = hh // g
    bg = jnp.repeat(bp.reshape(bsz, g, n), per_group, axis=1)  # (B, H, N)
    cg = jnp.repeat(cp.reshape(bsz, g, n), per_group, axis=1)
    h_new = jnp.exp(a)[..., None, None] * h_state.astype(jnp.float32) + \
        jnp.einsum("bhn,bhp->bhnp", bg.astype(jnp.float32), xs)
    y = jnp.einsum("bhn,bhnp->bhp", cg.astype(jnp.float32), h_new)
    y = y.astype(x.dtype) + p["d_skip"].astype(x.dtype)[None, :, None] * xh
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype))
    return out, (h_new.astype(h_state.dtype), new_conv)
