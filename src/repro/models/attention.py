"""GQA attention with RoPE/M-RoPE/qk-norm, full-sequence and cached decode."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (Config, P_, apply_mrope, apply_rope, constrain,
                                 rms_norm)


def attn_specs(cfg: Config, n_layers: int, cross: bool = False) -> Dict[str, P_]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    L = (n_layers,)
    specs = {
        "wq": P_(L + (d, h, dh), ("layers", "embed", "heads", "head_dim")),
        "wk": P_(L + (d, kv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": P_(L + (d, kv, dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": P_(L + (h, dh, d), ("layers", "heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = P_(L + (dh,), ("layers", "head_dim"), init="ones")
        specs["k_norm"] = P_(L + (dh,), ("layers", "head_dim"), init="ones")
    return specs


def _qkv(x, p, cfg: Config, mesh, positions, rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope and positions is not None:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    m = _model_size(mesh)
    h, s = q.shape[2], q.shape[1]
    if h % max(m, 1) == 0:
        q = constrain(q, mesh, ("batch", None, "act_heads", None))
        k = constrain(k, mesh, ("batch", None, "act_heads", None))
    elif m > 1 and s % m == 0:
        # heads unshardable on this TP size: sequence-parallel queries
        from jax.sharding import PartitionSpec
        from repro.models.common import batch_axes, sharding_constraint
        b_ax = batch_axes(mesh)
        q = sharding_constraint(
            q, mesh, PartitionSpec(b_ax if b_ax else None,
                                   "model", None, None))
    return q, k, v


def _model_size(mesh) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def _constrain_scores(x, mesh):
    """Shard the score tensor (b, kv, group, s, t) over 'model'.

    When total heads divide the TP size, GSPMD already tiles (kv, group)
    2-D-wise from the head-sharded q — constraining would FIGHT that
    propagation (involuntary full remat).  Only when heads are unshardable
    do we fall back to query-sequence sharding (matching the seq-sharded q
    produced by _qkv)."""
    m = _model_size(mesh)
    if m <= 1:
        return x
    kv, group, s = x.shape[1], x.shape[2], x.shape[3]
    if (kv * group) % m == 0:
        return x                                  # GSPMD's 2-D head tiling
    if s % m == 0:
        from repro.models.common import batch_axes, sharding_constraint
        from jax.sharding import PartitionSpec
        b_ax = batch_axes(mesh)
        return sharding_constraint(
            x, mesh, PartitionSpec(b_ax if b_ax else None,
                                   None, None, "model", None))
    return x


def _sdpa(q, k, v, causal: bool, kv_len: Optional[jnp.ndarray] = None,
          mesh=None):
    """(B,S,H,dh) x (B,Sk,KV,dh) GQA attention; f32 softmax (naive path)."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, s, kvh, group, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (dh ** 0.5)
    sk = k.shape[1]
    if causal:
        qi = jnp.arange(s)[:, None]
        kj = jnp.arange(sk)[None, :]
        mask = qi >= kj
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_len[:, None]       # (B, Sk)
        logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    logits = _constrain_scores(logits, mesh)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, dh).astype(q.dtype)


def _sdpa_chunked(q, k, v, causal: bool, kv_len: Optional[jnp.ndarray] = None,
                  mesh=None, chunk: int = 2048, unroll: bool = False):
    """Online-softmax attention: lax.scan over KV chunks — the jnp analogue
    of the flash kernel.  Never materializes the (S, Sk) score matrix, which
    turns the train/prefill memory-roofline term from O(S^2) to O(S*chunk).
    """
    b, s, h, dh = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    group = h // kvh
    if sk <= chunk:
        return _sdpa(q, k, v, causal, kv_len, mesh)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    qg = (q.reshape(b, s, kvh, group, dh).astype(jnp.float32) / (dh ** 0.5))
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, kvh, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, kvh, dh), 1, 0)
    qi = jnp.arange(s)[:, None]

    def body(carry, inp):
        m_run, l_run, acc = carry
        kb, vb, idx = inp
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.float32))
        logits = _constrain_scores(logits, mesh)
        kj = idx * chunk + jnp.arange(chunk)[None, :]
        valid = jnp.ones((s, chunk), bool) if not causal else (qi >= kj)
        if kv_len is not None:
            vlen = kj[None, :, :] < kv_len[:, None, None]       # (B,1,chunk)
            logits = jnp.where(vlen[:, None, None], logits, -1e30)
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        scale = jnp.exp(m_run - m_new)
        l_new = scale * l_run + jnp.sum(p, axis=-1)
        acc = acc * scale[..., None] + \
            jnp.einsum("bkgst,btkd->bkgsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, group, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, group, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, group, s, dh), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)),
        unroll=True if unroll else 1)
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def _sdpa_dispatch(cfg: Config):
    import functools
    if cfg.attn_impl == "chunked":
        return functools.partial(_sdpa_chunked, chunk=cfg.attn_chunk,
                                 unroll=cfg.attn_unroll)
    return _sdpa


def attn_apply(x, p, cfg: Config, mesh, positions=None, causal: bool = True,
               rope: bool = True):
    """Full-sequence attention (train / prefill)."""
    q, k, v = _qkv(x, p, cfg, mesh, positions, rope)
    out = _sdpa_dispatch(cfg)(q, k, v, causal, mesh=mesh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attn_prefill(x, p, cfg: Config, mesh, positions=None, rope: bool = True):
    """Prefill: returns output and the (k, v) cache for this layer."""
    q, k, v = _qkv(x, p, cfg, mesh, positions, rope)
    out = _sdpa_dispatch(cfg)(q, k, v, causal=True, mesh=mesh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), (k, v)


def attn_decode(x, p, cfg: Config, mesh, cache_k, cache_v, index,
                positions=None, rope: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (B,1,D); cache_{k,v}: (B,S,KV,dh); index: scalar.

    Returns (out, new_cache_k, new_cache_v)."""
    q, k, v = _qkv(x, p, cfg, mesh, positions, rope)
    zero = jnp.zeros((), index.dtype) if hasattr(index, "dtype") else 0
    idx4 = (zero, index, zero, zero)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           idx4)
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           idx4)
    b = x.shape[0]
    kv_len = jnp.full((b,), index + 1, jnp.int32)
    # q-len is 1: the naive matvec path is already memory-optimal for decode
    out = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                causal=False, kv_len=kv_len, mesh=mesh)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)),
            cache_k, cache_v)


def cross_attn_apply(x, p, cfg: Config, mesh, mem_k, mem_v):
    """Cross-attention against precomputed encoder K/V (B, T, KV, dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    q = constrain(q, mesh, ("batch", None, "act_heads", None))
    out = _sdpa_dispatch(cfg)(q, mem_k.astype(q.dtype), mem_v.astype(q.dtype),
                              causal=False, mesh=mesh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def cross_kv(mem, p, cfg: Config):
    """Encoder memory -> cross K/V using this layer's wk/wv."""
    k = jnp.einsum("btd,dhk->bthk", mem, p["wk"].astype(mem.dtype))
    v = jnp.einsum("btd,dhk->bthk", mem, p["wv"].astype(mem.dtype))
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v
