"""Top-k MoE with expert parallelism via shard_map.

Design (see DESIGN.md §5): tokens are batch-sharded over (pod, data); along
the ``model`` axis activations are replicated, so routing needs NO token
all-to-all — each model-rank selects the tokens routed to its local experts
into a fixed-capacity buffer, runs its experts as batched GEMMs, scatters
the results back, and a single psum over ``model`` combines contributions
(the same collective shape as a TP MLP).  Expert weights are additionally
FSDP-sharded over ``data`` and all-gathered on entry (ZeRO-3).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import Config, P_, batch_axes, shard_map


def moe_specs(cfg: Config, n_layers: int) -> Dict[str, P_]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert_ff
    L = (n_layers,)
    return {
        "router": P_(L + (d, e), ("layers", "embed", "expert")),
        "wg": P_(L + (e, d, f), ("layers", "expert", "embed", "expert_mlp")),
        "wu": P_(L + (e, d, f), ("layers", "expert", "embed", "expert_mlp")),
        "wd": P_(L + (e, f, d), ("layers", "expert", "expert_mlp", "embed")),
    }


def _capacity(n_tokens: int, cfg: Config, n_local: int) -> int:
    per_expert = (n_tokens * cfg.top_k * cfg.capacity_factor) / cfg.n_experts
    return max(cfg.top_k, int(-(-per_expert // 1)))  # ceil, floor k


def _moe_local(x, router, wg, wu, wd, *, cfg: Config, e_loc: int,
               capacity: int, has_model_axis: bool, fsdp_axes):
    """Per-shard MoE. x: (B_loc, S, D); expert weights hold e_loc experts.

    Two weight-layout strategies (cfg.moe_impl):
    * ``fsdp_gather`` — experts FSDP-sharded over 'data' on the embed axis;
      all-gathered per layer (ZeRO-3; right for training where T is large).
    * ``expert_tp``  — expert ffn axis sharded over 'data' and kept
      STATIONARY; the (small) token set is all-gathered over 'data' and the
      partial outputs psum'd back — removes the per-layer weight gathers
      (right for decode where T << weight size).
    """
    bdim, s, d = x.shape
    t = bdim * s
    k = cfg.top_k
    expert_tp = cfg.moe_impl == "expert_tp" and bool(fsdp_axes)
    if expert_tp:
        for ax in fsdp_axes:
            router = jax.lax.all_gather(router, ax, axis=0, tiled=True)
            x = jax.lax.all_gather(x, ax, axis=0, tiled=True)
        bdim = x.shape[0]
        t = bdim * s
    else:
        # ZeRO-3: gather the FSDP-sharded embed axis of the weights
        for ax in fsdp_axes:
            router = jax.lax.all_gather(router, ax, axis=0, tiled=True)
            wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)            # (T, k)
    if cfg.norm_topk:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    e0 = (jax.lax.axis_index("model") * e_loc) if has_model_axis else 0
    lidx = idx - e0
    local = (lidx >= 0) & (lidx < e_loc)              # (T, k)
    flat = jnp.where(local, lidx, e_loc).reshape(-1)  # (T*k,), e_loc = dump
    onehot = jax.nn.one_hot(flat, e_loc + 1, dtype=jnp.int32)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)      # exclusive prefix count
    myrank = jnp.take_along_axis(rank, flat[:, None], axis=1)[:, 0]
    keep = (flat < e_loc) & (myrank < capacity)
    slot_e = jnp.where(keep, flat, e_loc)
    slot_c = jnp.where(keep, myrank, 0)
    tok = jnp.arange(t * k) // k

    buf = jnp.zeros((e_loc + 1, capacity, d), x.dtype)
    buf = buf.at[slot_e, slot_c].set(xf[tok])
    act = buf[:e_loc]
    g = jnp.einsum("ecd,edf->ecf", act, wg.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", act, wu.astype(x.dtype))
    h = jax.nn.silu(g) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, capacity, d), y_buf.dtype)], 0)

    vals = y_buf[slot_e, slot_c]
    vals = jnp.where(keep[:, None], vals, 0.0)
    vals = vals * weights.reshape(-1)[:, None].astype(vals.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok].add(vals)
    if cfg.moe_psum_dtype == "bf16":
        y = y.astype(jnp.bfloat16)
    if expert_tp:
        # partial over the ffn ('data'-sharded) axis + expert ('model') axis
        axes = ("model",) if has_model_axis else ()
        y = jax.lax.psum(y, axes + tuple(fsdp_axes))
        n_data = 1
        for ax in fsdp_axes:
            n_data *= jax.lax.axis_size(ax)
        my = jax.lax.axis_index(fsdp_axes[0])
        y = jax.lax.dynamic_slice_in_dim(y.reshape(bdim, s, d),
                                         my * (bdim // n_data),
                                         bdim // n_data, axis=0)
        return y.astype(x.dtype)
    if has_model_axis:
        y = jax.lax.psum(y, "model")
    return y.reshape(bdim, s, d).astype(x.dtype)


def moe_apply(x, p, cfg: Config, mesh) -> jnp.ndarray:
    """x: (B, S, D) batch-sharded; p holds this layer's MoE params."""
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    model = sizes.get("model", 1)
    has_model = "model" in names and cfg.n_experts % max(model, 1) == 0 and model > 1
    e_loc = cfg.n_experts // model if has_model else cfg.n_experts
    b_axes = batch_axes(mesh)
    n_b = 1
    for a in b_axes:
        n_b *= sizes[a]
    t_loc = (x.shape[0] // max(n_b, 1)) * x.shape[1]

    fsdp_axes = tuple(a for a in ("data",) if a in names and
                      cfg.d_model % sizes[a] == 0 and sizes[a] > 1)
    if cfg.moe_impl == "expert_tp":
        fsdp_axes = tuple(a for a in fsdp_axes
                          if cfg.d_expert_ff % sizes[a] == 0)
        # tokens are all-gathered over 'data' inside the shard
        for a in fsdp_axes:
            t_loc *= sizes[a]
    capacity = _capacity(t_loc, cfg, e_loc)
    espec_embed = "data" if fsdp_axes else None
    x_spec = P(b_axes if b_axes else None, None, None)
    e_ax = None if not has_model else "model"
    if cfg.moe_impl == "expert_tp" and fsdp_axes:
        in_specs = (
            x_spec,
            P(espec_embed, None),                     # router (d, e)
            P(e_ax, None, "data"),                    # wg: ffn axis stationary
            P(e_ax, None, "data"),                    # wu
            P(e_ax, "data", None),                    # wd
        )
    else:
        in_specs = (
            x_spec,
            P(espec_embed, None),                     # router (d, e)
            P(e_ax, espec_embed, None),               # wg
            P(e_ax, espec_embed, None),               # wu
            P(e_ax, None, espec_embed),               # wd
        )
    fn = functools.partial(_moe_local, cfg=cfg, e_loc=e_loc, capacity=capacity,
                           has_model_axis=has_model, fsdp_axes=fsdp_axes)
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=x_spec,
                         check_vma=False)(x, p["router"], p["wg"], p["wu"],
                                          p["wd"])
