"""build(cfg, mesh) -> ModelBundle: specs, init, train/prefill/serve steps,
and per-shape input_specs (ShapeDtypeStruct stand-ins for the dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import (Config, DEFAULT_RULES, abstract_params,
                                 shard_map,
                                 batch_axes, init_params, param_shardings,
                                 resolve_spec)
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.optim.adamw import OptConfig, adamw_init, adamw_update


# The four assigned input shapes: (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
    # reduced variants for smoke tests
    "smoke_train": (64, 2, "train"),
    "smoke_prefill": (64, 2, "prefill"),
    "smoke_decode": (64, 2, "decode"),
}


@dataclasses.dataclass
class ModelBundle:
    cfg: Config
    mesh: Mesh
    rules: Dict[str, Any]
    specs: Any
    opt_cfg: OptConfig

    # ---------------------------------------------------------------- params
    def init(self, key) -> Any:
        return init_params(self.specs, key, self.cfg.param_dtype)

    def abstract_params(self) -> Any:
        return abstract_params(self.specs, self.cfg.param_dtype)

    def param_shardings(self) -> Any:
        return param_shardings(self.specs, self.mesh, self.rules)

    def opt_shardings(self) -> Any:
        ps = self.param_shardings()
        return {"mu": ps, "nu": ps,
                "count": NamedSharding(self.mesh, P())}

    def abstract_opt_state(self) -> Any:
        z = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
            self.abstract_params())
        return {"mu": z, "nu": z, "count": jax.ShapeDtypeStruct((), jnp.int32)}

    # ----------------------------------------------------------------- steps
    def loss_fn(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec_mod.loss_fn(params, self.cfg, self.mesh, batch)
        return tf_mod.loss_fn(params, self.cfg, self.mesh, batch)

    def train_step(self, params, opt_state, batch, microbatches: int = 1):
        """One optimizer step; with microbatches > 1, gradients are
        accumulated in f32 over a lax.scan (live activations /m)."""
        if microbatches == 1:
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = {k: split(v) for k, v in batch.items() if k != "positions"}
            if "positions" in batch:  # (3, B, S): the batch axis is axis 1
                p = batch["positions"]
                mb["positions"] = p.reshape(
                    (p.shape[0], microbatches, p.shape[1] // microbatches)
                    + p.shape[2:]).swapaxes(0, 1)

            def body(carry, micro):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(self.loss_fn)(params, micro)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (0.0, zeros), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params,
                                                    self.opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    def train_step_compressed(self, params, opt_state, err_state, batch):
        """Train step with int8 error-feedback compression of the CROSS-POD
        gradient all-reduce (distributed-optimization trick; multi-pod mesh).

        shard_map is manual over the 'pod' axis only — data/model stay under
        GSPMD — so each pod computes gradients on its own batch shard and
        the pods exchange int8 payloads (1 byte/grad over the slow links).
        """
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import psum_compressed
        assert "pod" in self.mesh.axis_names, "needs a multi-pod mesh"

        def per_pod(params, opt_state, err_state, batch):
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            flat_g, treedef = jax.tree_util.tree_flatten(grads)
            flat_e = treedef.flatten_up_to(err_state)
            new_g, new_e = [], []
            for g, e in zip(flat_g, flat_e):
                gm, em = psum_compressed(g, e, "pod")
                new_g.append(gm)
                new_e.append(em)
            grads = jax.tree_util.tree_unflatten(treedef, new_g)
            err = jax.tree_util.tree_unflatten(treedef, new_e)
            loss = jax.lax.pmean(loss, "pod")
            new_params, new_opt, metrics = adamw_update(
                grads, opt_state, params, self.opt_cfg)
            metrics["loss"] = loss
            return new_params, new_opt, err, metrics

        rep = jax.tree_util.tree_map(lambda _: P(), params)
        rep_opt = jax.tree_util.tree_map(lambda _: P(), opt_state)
        rep_err = jax.tree_util.tree_map(lambda _: P(), err_state)
        bspec = jax.tree_util.tree_map(lambda _: P("pod"), batch)
        out_specs = (rep, rep_opt, rep_err,
                     {"loss": P(), "grad_norm": P()})
        return shard_map(per_pod, mesh=self.mesh,
                             in_specs=(rep, rep_opt, rep_err, bspec),
                             out_specs=out_specs, axis_names={"pod"},
                             check_vma=False)(params, opt_state, err_state,
                                              batch)

    def prefill_step(self, params, tokens):
        assert self.cfg.family not in ("encdec",), "use encode for encdec"
        max_seq = tokens.shape[1]
        return tf_mod.prefill(params, self.cfg, self.mesh, tokens, max_seq)

    def encode_step(self, params, frames):
        return encdec_mod.encode(params, self.cfg, self.mesh, frames)

    def serve_step(self, params, cache, token, positions=None):
        if self.cfg.family == "encdec":
            return encdec_mod.decode_step(params, self.cfg, self.mesh, cache,
                                          token, positions)
        return tf_mod.decode_step(params, self.cfg, self.mesh, cache, token,
                                  positions)

    # ------------------------------------------------------------- dry-run IO
    def cache_specs(self, batch: int, max_seq: int):
        if self.cfg.family == "encdec":
            return encdec_mod.init_cache_specs(self.cfg, batch, max_seq)
        return tf_mod.init_cache_specs(self.cfg, batch, max_seq)

    def cache_shardings(self, batch: int, max_seq: int):
        if self.cfg.family == "encdec":
            axes = encdec_mod.cache_logical_axes(self.cfg)
        else:
            axes = tf_mod.cache_logical_axes(self.cfg)
        specs = self.cache_specs(batch, max_seq)
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        m = sizes.get("model", 1)
        out = {}
        for name, sds in specs.items():
            logical = list(axes[name])
            # KV caches: when kv_heads don't divide TP, shard the KV sequence
            # instead (flash-decode style: softmax stats psums are tiny)
            if "kv_heads" in logical and m > 1:
                kv_i = logical.index("kv_heads")
                seq_i = logical.index("kv_seq")
                if sds.shape[kv_i] % m != 0 and sds.shape[seq_i] % m == 0:
                    logical[kv_i] = None
                    logical[seq_i] = "act_heads"  # -> 'model'
            # batch divisibility fallback
            if "batch" in logical:
                b_i = logical.index("batch")
                b_ax = batch_axes(self.mesh)
                n = 1
                for a in b_ax:
                    n *= sizes[a]
                if n and sds.shape[b_i] % max(n, 1) != 0:
                    logical[b_i] = None
            out[name] = NamedSharding(
                self.mesh, resolve_spec(sds.shape, tuple(logical), self.mesh,
                                        self.rules))
        return out

    def init_cache(self, batch: int, max_seq: int):
        shardings = self.cache_shardings(batch, max_seq)
        return {
            name: jax.device_put(jnp.zeros(s.shape, s.dtype), shardings[name])
            for name, s in self.cache_specs(batch, max_seq).items()
        }

    def batch_sharding(self, batch_size: Optional[int] = None):
        b = batch_axes(self.mesh)
        if b and batch_size is not None:
            sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            n = 1
            for a in b:
                n *= sizes[a]
            if batch_size % n != 0:
                # try pods-only, then replicate (e.g. long_500k batch=1)
                b = tuple(a for a in b if a == "pod" and
                          batch_size % sizes[a] == 0)
        return NamedSharding(self.mesh, P(b if b else None, None))

    def input_specs(self, shape_name: str) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins + shardings for one assigned shape."""
        seq, gbatch, kind = SHAPES[shape_name]
        cfg = self.cfg
        tok = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        bspec = self.batch_sharding(gbatch)
        out: Dict[str, Any] = {"kind": kind}
        if kind == "train":
            if cfg.family == "encdec":
                frames = jax.ShapeDtypeStruct((gbatch, cfg.enc_frames,
                                               cfg.d_model), jnp.float32)
                out["batch"] = {"frames": frames,
                                "tokens": tok((gbatch, seq)),
                                "labels": tok((gbatch, seq))}
                out["batch_shardings"] = {
                    "frames": NamedSharding(self.mesh, P(bspec.spec[0], None, None)),
                    "tokens": bspec, "labels": bspec}
            elif cfg.family == "vlm":
                out["batch"] = {"tokens": tok((gbatch, seq)),
                                "labels": tok((gbatch, seq)),
                                "positions": tok((3, gbatch, seq))}
                out["batch_shardings"] = {
                    "tokens": bspec, "labels": bspec,
                    "positions": NamedSharding(self.mesh,
                                               P(None, bspec.spec[0], None))}
            else:
                out["batch"] = {"tokens": tok((gbatch, seq)),
                                "labels": tok((gbatch, seq))}
                out["batch_shardings"] = {"tokens": bspec, "labels": bspec}
        elif kind == "prefill":
            if cfg.family == "encdec":
                out["frames"] = jax.ShapeDtypeStruct(
                    (gbatch, seq, cfg.d_model), jnp.float32)
                out["frames_sharding"] = NamedSharding(
                    self.mesh, P(bspec.spec[0], None, None))
            else:
                out["tokens"] = tok((gbatch, seq))
                out["tokens_sharding"] = bspec
        else:  # decode
            out["cache"] = self.cache_specs(gbatch, seq)
            out["cache_shardings"] = self.cache_shardings(gbatch, seq)
            out["token"] = tok((gbatch, 1))
            out["token_sharding"] = bspec
            if cfg.family == "vlm":
                out["positions"] = tok((3, gbatch, 1))
        return out


# VLM forward needs positions threaded through loss; patch via batch dict
# (transformer.loss_fn already reads batch["positions"]).


def build(cfg: Config, mesh: Mesh, rules: Optional[Dict[str, Any]] = None,
          opt_cfg: Optional[OptConfig] = None) -> ModelBundle:
    rules = dict(rules or DEFAULT_RULES)
    if cfg.family == "encdec":
        specs = encdec_mod.encdec_specs(cfg)
    else:
        specs = tf_mod.lm_specs(cfg)
    return ModelBundle(cfg=cfg, mesh=mesh, rules=rules, specs=specs,
                       opt_cfg=opt_cfg or OptConfig())
