"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer moments are stored in float32 by default and sharded exactly like
their parameters (ZeRO-style: the FSDP rules in models/common.py shard the
embed axis over ``data``, so moments are fully distributed too).  The update
computes in the *moment* dtype — pass ``moment_dtype=jnp.float64`` to
:func:`adamw_init` (as the gradient-based VQE driver in
:mod:`repro.core.vqe` does) for full-precision f64 optimization; the f32
default is bit-identical to the original behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params, moment_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, moment_dtype), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    # promote (never truncate): f32/bf16 grads accumulate in f32 as before,
    # f64 grads keep f64 norms
    sq = [jnp.sum(jnp.square(x.astype(jnp.promote_types(x.dtype,
                                                        jnp.float32))))
          for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))


def adamw_update(grads, state, params, cfg: OptConfig, lr=None):
    """Returns (new_params, new_state, metrics).

    The update computes in each moment leaf's dtype (f32 with the default
    :func:`adamw_init`, bit-identical to the historical hard-f32 path)."""
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    count = state["count"] + 1

    def upd(g, m, v, p):
        dt = m.dtype
        c1 = 1.0 - cfg.b1 ** count.astype(dt)
        c2 = 1.0 - cfg.b2 ** count.astype(dt)
        gd = g.astype(dt) * scale.astype(dt)
        m_new = cfg.b1 * m + (1 - cfg.b1) * gd
        v_new = cfg.b2 * v + (1 - cfg.b2) * gd * gd
        step = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        pd = p.astype(dt)
        p_new = pd - lr * (step + cfg.weight_decay * pd)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        a, b, c = upd(g, m, v, p)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"mu": jax.tree_util.tree_unflatten(treedef, new_m),
             "nu": jax.tree_util.tree_unflatten(treedef, new_v),
             "count": count},
            {"grad_norm": gnorm})
