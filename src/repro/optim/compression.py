"""int8 error-feedback gradient compression for the cross-pod all-reduce.

At 2 pods the cross-pod gradient all-reduce is the only traffic on the
(slow, inter-pod) links; compressing it 2-4x directly shrinks the
collective roofline term.  Scheme: per-tensor scale = max|g|/127, quantize
to int8, all-reduce (psum) the int8 *as int32 accumulate*, dequantize, and
feed the quantization residual back into the next step (error feedback, so
the compression bias vanishes over time).

Used inside shard_map over the 'pod' axis (see launch/train.py); a pure
local (quantize->dequantize + residual) path is provided for tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_residual(g: jnp.ndarray, err: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression step: returns (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize(corrected)
    new_err = corrected - dequantize(q, scale)
    return q, scale, new_err


def psum_compressed(g: jnp.ndarray, err: jnp.ndarray, axis_name: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce mean of g over ``axis_name`` with int8 payload + error
    feedback.  Must run inside shard_map with ``axis_name`` in scope.

    A scalar max-|g| all-reduce first agrees on a SHARED scale, so the int8
    psum dequantizes exactly (up to rounding, which error feedback absorbs).
    Payload over the slow inter-pod link: 1 byte/grad instead of 2-4."""
    # jax.lax.axis_size is recent; psum(1) is the portable spelling.
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis_name)
    else:
        n = jax.lax.psum(1, axis_name)
    corrected = g.astype(jnp.float32) + err
    local_max = jnp.max(jnp.abs(corrected))
    scale = jax.lax.pmax(local_max, axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = q_sum.astype(jnp.float32) * scale / n
    return mean.astype(g.dtype), new_err


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
