"""Qwen3-30B-A3B — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B; hf]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=0, vocab=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, d_expert_ff=768, norm_topk=True,
    param_dtype=jnp.bfloat16,
)
