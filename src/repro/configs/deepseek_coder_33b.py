"""DeepSeek-Coder-33B — deep llama-arch dense [arXiv:2401.14196; hf]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=19200, vocab=32256,
    param_dtype=jnp.bfloat16,
)
