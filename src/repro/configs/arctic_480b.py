"""Snowflake Arctic-480B — 128e top-2 MoE + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, d_expert_ff=4864, moe_dense_residual=True,
    param_dtype=jnp.bfloat16,
)
