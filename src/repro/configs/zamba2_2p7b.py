"""Zamba2-2.7B — Mamba2 blocks + shared attention [arXiv:2411.15242; hf]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    hybrid_group=6, sub_quadratic=True,
    param_dtype=jnp.bfloat16,
)
