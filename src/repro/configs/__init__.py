"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full published Config; ``get_smoke(name)`` a
reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.common import Config

ARCHS: List[str] = [
    "granite-8b",
    "qwen3-4b",
    "smollm-360m",
    "deepseek-coder-33b",
    "qwen3-moe-30b-a3b",
    "arctic-480b",
    "zamba2-2.7b",
    "qwen2-vl-72b",
    "mamba2-2.7b",
    "whisper-large-v3",
]

_MODULES = {
    "granite-8b": "granite_8b",
    "qwen3-4b": "qwen3_4b",
    "smollm-360m": "smollm_360m",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "arctic-480b": "arctic_480b",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-2.7b": "mamba2_2p7b",
    "whisper-large-v3": "whisper_large_v3",
}


def get(name: str) -> Config:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke(name: str) -> Config:
    """Reduced same-family config: small widths, few layers/experts."""
    cfg = get(name)
    n_layers = 2
    overrides = dict(
        n_layers=n_layers, d_model=64, d_ff=128, vocab=256,
        n_heads=4, n_kv_heads=2, d_head=16,
        param_dtype=cfg.param_dtype, act_dtype=cfg.act_dtype,
        remat=False,
    )
    if cfg.family == "moe":
        overrides.update(n_experts=8, top_k=2, d_expert_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        overrides.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        overrides.update(n_layers=6, hybrid_group=3)
    if cfg.family == "encdec":
        overrides.update(n_enc_layers=2, enc_frames=16)
    if cfg.mrope_sections is not None:
        overrides.update(mrope_sections=(2, 3, 3))  # half-dim 8
    return dataclasses.replace(cfg, **overrides)
