"""Mamba2-2.7B — attention-free SSD [arXiv:2405.21060]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    sub_quadratic=True,
    param_dtype=jnp.bfloat16,
)
