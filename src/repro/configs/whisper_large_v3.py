"""Whisper-large-v3 — enc-dec backbone, conv frontend stubbed
[arXiv:2212.04356]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_head=64, d_ff=5120, vocab=51866, enc_frames=1500,
    param_dtype=jnp.bfloat16,
)
