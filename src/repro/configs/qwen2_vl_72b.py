"""Qwen2-VL-72B — M-RoPE decoder backbone; vision frontend stubbed
[arXiv:2409.12191; hf]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=29568, vocab=152064, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    param_dtype=jnp.bfloat16,
)
