"""SmolLM-360M — small llama-arch dense [hf:HuggingFaceTB/SmolLM; hf]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_head=64,
    d_ff=2560, vocab=49152,
    param_dtype=jnp.bfloat16,
)
