"""Qwen3-4B — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family; hf]."""
import jax.numpy as jnp
from repro.models.common import Config

CONFIG = Config(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=9728, vocab=151936, qk_norm=True, rope_theta=1e6,
    param_dtype=jnp.bfloat16,
)
