"""Gram-matrix kernel: G = A^T A for a tall operand (paper Alg. 5 hot-spot).

The reshape-avoiding orthogonalization reduces distributed QR to (i) one big
Gram contraction over the tall modes and (ii) a small local eigh.  Step (i)
is this kernel: the small G stays resident in VMEM while A streams through
in (bm x n) tiles — a reduction over the grid's sequential dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _gram_kernel(a_ref, g_ref, acc_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = a_ref[...]
    acc_ref[...] += jnp.dot(blk.T, blk, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(0) - 1)
    def _flush():
        g_ref[...] = acc_ref[...].astype(g_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def gram(a: jnp.ndarray, *, bm: int = 256, interpret: bool = True) -> jnp.ndarray:
    """G = A^T A for real A of shape (M, N) with M >> N (N <= ~512)."""
    m, n = a.shape
    pad_m = (-m) % bm
    if pad_m:
        a = jnp.pad(a, ((0, pad_m), (0, 0)))
    mp = a.shape[0]
    # lane-align the small dimension
    pad_n = (-n) % 128
    if pad_n:
        a = jnp.pad(a, ((0, 0), (0, pad_n)))
    np_ = a.shape[1]
    out = pl.pallas_call(
        _gram_kernel,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, np_), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((np_, np_), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((np_, np_), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(a)
    return out[:n, :n]


def gram_complex(a: jnp.ndarray, *, bm: int = 256,
                 interpret: bool = True) -> jnp.ndarray:
    """G = A^H A for complex A via planar decomposition (4 real Grams/GEMMs).

    Pallas-TPU has no complex dtype; the PEPS library calls this wrapper.
    """
    from repro.kernels.tiled_matmul import tiled_matmul
    ar, ai = jnp.real(a), jnp.imag(a)
    g_rr = gram(ar, bm=bm, interpret=interpret)
    g_ii = gram(ai, bm=bm, interpret=interpret)
    g_ri = tiled_matmul(ar.T, ai, interpret=interpret)
    real = g_rr + g_ii
    imag = g_ri - g_ri.T
    return real + 1j * imag
