"""Gram-matrix kernel: G = A^T A for a tall operand (paper Alg. 5 hot-spot).

The reshape-avoiding orthogonalization reduces distributed QR to (i) one big
Gram contraction over the tall modes and (ii) a small local eigh.  Step (i)
is this kernel: the small G stays resident in VMEM while A streams through
in (bm x n) tiles — a reduction over the grid's sequential dimension.

``interpret=None`` (default) autodetects: compiled on TPU, interpret mode
elsewhere (see ``repro.kernels.dispatch.interpret_default`` for the
env/flag overrides).  ``compute`` optionally demotes the streamed tiles to
a narrower multiplicand dtype (``"bfloat16"`` under the mixed precision
policy) — accumulation stays f32 either way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _gram_kernel(a_ref, g_ref, acc_ref, *, compute):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = a_ref[...]
    if compute is not None:
        blk = blk.astype(compute)
    acc_ref[...] += jnp.dot(blk.T, blk, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(0) - 1)
    def _flush():
        g_ref[...] = acc_ref[...].astype(g_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "compute"))
def _gram(a: jnp.ndarray, bm: int, interpret: bool, compute) -> jnp.ndarray:
    m, n = a.shape
    pad_m = (-m) % bm
    if pad_m:
        a = jnp.pad(a, ((0, pad_m), (0, 0)))
    mp = a.shape[0]
    # lane-align the small dimension
    pad_n = (-n) % 128
    if pad_n:
        a = jnp.pad(a, ((0, 0), (0, pad_n)))
    np_ = a.shape[1]
    kernel = functools.partial(
        _gram_kernel, compute=None if compute is None else jnp.dtype(compute))
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, np_), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((np_, np_), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((np_, np_), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(a)
    return out[:n, :n]


def gram(a: jnp.ndarray, *, bm: int = 256, interpret: Optional[bool] = None,
         compute=None) -> jnp.ndarray:
    """G = A^T A for real A of shape (M, N) with M >> N (N <= ~512)."""
    if interpret is None:
        from repro.kernels.dispatch import interpret_default
        interpret = interpret_default()
    return _gram(a, bm, bool(interpret),
                 None if compute is None else jnp.dtype(compute).name)


def gram_complex(a: jnp.ndarray, *, bm: int = 256,
                 interpret: Optional[bool] = None,
                 compute=None) -> jnp.ndarray:
    """G = A^H A for complex A via planar decomposition (4 real Grams/GEMMs).

    Pallas-TPU has no complex dtype; the PEPS library calls this wrapper.
    The imaginary part is ``g_ri - g_ri.T`` — exactly antisymmetric by
    construction, matching the Hermiticity of the exact G.
    """
    from repro.kernels.tiled_matmul import tiled_matmul
    ar, ai = jnp.real(a), jnp.imag(a)
    g_rr = gram(ar, bm=bm, interpret=interpret, compute=compute)
    g_ii = gram(ai, bm=bm, interpret=interpret, compute=compute)
    g_ri = tiled_matmul(ar.T, ai, interpret=interpret, compute=compute)
    real = g_rr + g_ii
    imag = g_ri - g_ri.T
    return real + 1j * imag
