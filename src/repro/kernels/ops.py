"""jit'd dispatch wrappers for the Pallas kernels.

``use_pallas`` picks the kernel path; the default follows the backend
(Pallas on TPU, interpret-mode only under explicit request on CPU so model
code never pays interpret overhead silently).  The pure-jnp fallbacks are
the same code XLA fuses well on its own — they are also the oracles.
"""
from __future__ import annotations

import jax

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gram import gram as _gram, gram_complex as _gram_complex
from repro.kernels.ssd_scan import ssd_scan as _ssd
from repro.kernels.tiled_matmul import tiled_matmul as _matmul


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(a, b, use_pallas: bool = None, **kw):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _matmul(a, b, interpret=not _on_tpu(), **kw)
    return _ref.matmul(a, b)


def gram(a, use_pallas: bool = None, **kw):
    if use_pallas is None:
        use_pallas = _on_tpu()
    import jax.numpy as jnp
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        if use_pallas:
            return _gram_complex(a, interpret=not _on_tpu())
        return _ref.gram_complex(a)
    if use_pallas:
        return _gram(a, interpret=not _on_tpu(), **kw)
    return _ref.gram(a)


def attention(q, k, v, causal: bool = True, use_pallas: bool = None, **kw):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _flash(q, k, v, causal=causal, interpret=not _on_tpu(), **kw)
    return _ref.attention(q, k, v, causal=causal)


def ssd(x, b, c, a, use_pallas: bool = None, **kw):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _ssd(x, b, c, a, interpret=not _on_tpu(), **kw)
    return _ref.ssd(x, b, c, a)
