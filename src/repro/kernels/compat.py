"""Version-compat shims for Pallas-TPU APIs.

``pltpu.CompilerParams`` was called ``TPUCompilerParams`` in older JAX
releases; kernels import the alias from here so they run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:
    CompilerParams = pltpu.TPUCompilerParams
