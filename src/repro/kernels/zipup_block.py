"""Fused zip-up inner-einsum kernels (paper Alg. 3 first-column hot spots).

The zip-up block kernels of ``core/engines/zipup.py`` own three direct
einsums that do NOT go through einsumsvd (they build/close the carry, no
truncation): the one-layer first-column carry init, its two-layer sibling,
and the first-row pair merge.  Each is a (chain of) matricized GEMM(s), so
each gets a Pallas implementation built on the streaming tall-apply kernel
(:mod:`repro.kernels.matvec`; complex operands via the planar single-GEMM
trick) next to a dense implementation that is *verbatim* the pre-kernel
``jnp.einsum`` — the pinned goldens of ``tests/test_engines.py`` are
bit-identical on the dense path.

Dispatch goes through :mod:`repro.kernels.dispatch` (sites
``zipup_first_onelayer`` / ``zipup_first_twolayer`` / ``pair_merge``):
f64/c128 operands stay dense unconditionally; in auto mode the kernels
engage only for large operands on a TPU backend, so CPU CI runs the exact
dense path by default.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.matvec import planar_matmul


def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


# ---------------------------------------------------------------------------
# One-layer first column: S_0 (b,f,g) x O_0 (f,c,h,k) -> (b,c,h,g,k)
# ---------------------------------------------------------------------------

def _first_onelayer_dense(s0, o0):
    return jnp.einsum("bfg,fchk->bchgk", s0, o0)


def _first_onelayer_pallas(s0, o0):
    b, f, g = s0.shape
    _, c, h, k = o0.shape
    a_mat = jnp.transpose(s0, (0, 2, 1)).reshape(b * g, f)
    b_mat = o0.reshape(f, c * h * k)
    out = planar_matmul(a_mat, b_mat, compute=dispatch.kernel_compute())
    out = out.reshape(b, g, c, h, k)
    return jnp.transpose(out, (0, 2, 3, 1, 4))


def first_column_onelayer(s0: jnp.ndarray, o0: jnp.ndarray) -> jnp.ndarray:
    """Carry init of the one-layer zip-up (``zipup_block`` first column)."""
    return dispatch.dispatch("zipup_first_onelayer", s0, o0)


# ---------------------------------------------------------------------------
# Two-layer first column:
#   S_0 (b,f,F,g) x bra* (p,f,c,h,k) x ket (p,F,C,H,K) -> (b,c,C,h,H,g,k,K)
# ---------------------------------------------------------------------------

def _first_twolayer_dense(s0, tb0, tk0):
    return jnp.einsum("bfFg,pfchk,pFCHK->bcChHgkK", s0, tb0, tk0,
                      optimize="optimal")


def _first_twolayer_pallas(s0, tb0, tk0):
    b, f, F, g = s0.shape
    p, _, c, h, k = tb0.shape
    _, _, C, H, K = tk0.shape
    compute = dispatch.kernel_compute()
    # stage 1 — contract f:  (b F g, f) @ (f, p c h k)
    a1 = jnp.transpose(s0, (0, 2, 3, 1)).reshape(b * F * g, f)
    b1 = jnp.transpose(tb0, (1, 0, 2, 3, 4)).reshape(f, p * c * h * k)
    t1 = planar_matmul(a1, b1, compute=compute)
    t1 = t1.reshape(b, F, g, p, c, h, k)
    # stage 2 — contract (p, F):  (b g c h k, p F) @ (p F, C H K)
    a2 = jnp.transpose(t1, (0, 2, 4, 5, 6, 3, 1)).reshape(
        b * g * c * h * k, p * F)
    b2 = tk0.reshape(p * F, C * H * K)
    t2 = planar_matmul(a2, b2, compute=compute)
    t2 = t2.reshape(b, g, c, h, k, C, H, K)
    return jnp.transpose(t2, (0, 2, 5, 3, 6, 1, 4, 7))


def first_column_twolayer(s0: jnp.ndarray, tb0: jnp.ndarray,
                          tk0: jnp.ndarray) -> jnp.ndarray:
    """Carry init of the two-layer zip-up (``tb0`` pre-conjugated)."""
    return dispatch.dispatch("zipup_first_twolayer", s0, tb0, tk0)


# ---------------------------------------------------------------------------
# First-row pair merge: bra* (p,u,l,d,r) x ket (p,U,L,D,R) -> (l,L,d,D,r,R)
# (u/U are dim 1 on the first row and are summed out)
# ---------------------------------------------------------------------------

def _pair_merge_dense(tb, tk):
    return jnp.einsum("puldr,pULDR->lLdDrR", tb, tk)


def _pair_merge_pallas(tb, tk):
    p, u, l, d, r = tb.shape
    _, U, L, D, R = tk.shape
    a_mat = jnp.moveaxis(tb, 0, -1).reshape(u * l * d * r, p)
    b_mat = tk.reshape(p, U * L * D * R)
    out = planar_matmul(a_mat, b_mat, compute=dispatch.kernel_compute())
    out = out.reshape(u, l, d, r, U, L, D, R)
    # sum out the (dim-1 on row 0, but kept general) u/U axes, then interleave
    out = out.sum(axis=(0, 4))                       # (l, d, r, L, D, R)
    return jnp.transpose(out, (0, 3, 1, 4, 2, 5))    # (l, L, d, D, r, R)


def pair_merge(tb: jnp.ndarray, tk: jnp.ndarray) -> jnp.ndarray:
    """First-row boundary pair merge (``tb`` pre-conjugated)."""
    return dispatch.dispatch("pair_merge", tb, tk)


# ---------------------------------------------------------------------------
# Site registration
# ---------------------------------------------------------------------------

def _supported(*tensors) -> bool:
    return dispatch.dtype_supported(*(t.dtype for t in tensors))


def _auto_onelayer(s0, o0) -> bool:
    b, f, g = s0.shape
    _, c, h, k = o0.shape
    return dispatch.tall_skinny_auto(b * g, max(f, c * h * k))


def _auto_twolayer(s0, tb0, tk0) -> bool:
    b, f, F, g = s0.shape
    p, _, c, h, k = tb0.shape
    _, _, C, H, K = tk0.shape
    return dispatch.tall_skinny_auto(b * g * c * h * k,
                                     max(f, p * F, C * H * K))


def _auto_pair(tb, tk) -> bool:
    return dispatch.tall_skinny_auto(_numel(tb.shape[1:]), _numel(tk.shape[1:]))


dispatch.register_kernel("zipup_first_onelayer",
                         pallas=_first_onelayer_pallas,
                         dense=_first_onelayer_dense,
                         supported=_supported, auto=_auto_onelayer)
dispatch.register_kernel("zipup_first_twolayer",
                         pallas=_first_twolayer_pallas,
                         dense=_first_twolayer_dense,
                         supported=_supported, auto=_auto_twolayer)
dispatch.register_kernel("pair_merge",
                         pallas=_pair_merge_pallas,
                         dense=_pair_merge_dense,
                         supported=_supported, auto=_auto_pair)
