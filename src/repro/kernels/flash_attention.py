"""Causal flash attention (online softmax) for the LM substrate.

Grid: (batch*q_heads, S/bq, S/bk) with the key dimension sequential.  Query
tile, running max/denominator and the output accumulator live in VMEM; the
KV index_map folds GQA head-grouping so grouped KV heads are streamed
without materializing the head-repeat.  Causal key blocks strictly in the
future are skipped via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip key blocks strictly in the future of every query in this block
    guard = (iq * bq + bq - 1) >= (ik * bk) if causal else True

    @pl.when(guard)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kj < kv_len                        # mask padded keys
        if causal:
            qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid = valid & (qi >= kj)
        s = jnp.where(valid, s, _NEG_INF)
        m_prev = m_ref[:, 0]                       # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])            # (bq, bk)
        scale = jnp.exp(m_prev - m_new)            # (bq,)
        l_new = scale * l_ref[:, 0] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * scale[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Attention over (B, Hq, S, D) queries and (B, Hkv, S, D) keys/values.

    ``Hq`` must be a multiple of ``Hkv`` (GQA); softmax scale 1/sqrt(D)."""
    b, hq, s, d = q.shape
    _, hkv, sk, dk = k.shape
    assert d == dk and hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    q = (q * scale).reshape(b * hq, s, d)
    k = k.reshape(b * hkv, sk, d)
    v = v.reshape(b * hkv, sk, d)

    pad_q = (-s) % bq
    pad_k = (-sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    sq, skk = qp.shape[1], kp.shape[1]

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               kv_len=sk)
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sq // bq, skk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            # GQA: fold the head-group mapping into the index map
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s].reshape(b, hq, s, d)
