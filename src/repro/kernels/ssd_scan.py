"""Mamba2 SSD chunked scan kernel (state-space duality, arXiv:2405.21060).

Recurrence per (batch, head):  h_t = exp(a_t) h_{t-1} + B_t (x) x_t,
y_t = C_t . h_t  with h in R^{N x P}.  The chunked (SSD) form computes the
intra-chunk part as an attention-like masked GEMM and carries the chunk
state sequentially — mapping both halves onto the MXU.

Grid: (BH, L/C) with the chunk dimension sequential; the (N, P) state lives
in VMEM scratch across chunk steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, b_ref, c_ref, a_ref, y_ref, h_ref, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)               # (C, P)
    bmat = b_ref[0].astype(jnp.float32)            # (C, N)
    cmat = c_ref[0].astype(jnp.float32)            # (C, N)
    a = a_ref[0, :, 0].astype(jnp.float32)         # (C,) log-decay (<= 0)

    cum = jnp.cumsum(a)                            # inclusive prefix sums
    total = cum[-1]
    # intra-chunk: scores[i,j] = exp(cum_i - cum_j) for i >= j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    l_mat = jnp.where(ii >= jj, decay, 0.0)
    s_mat = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32) * l_mat
    y_intra = jnp.dot(s_mat, x, preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the incoming state
    h = h_ref[...]
    y_inter = jnp.dot(cmat * jnp.exp(cum)[:, None], h,
                      preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update for the next chunk
    w = jnp.exp(total - cum)[:, None] * bmat       # (C, N)
    h_ref[...] = jnp.exp(total) * h + jnp.dot(w.T, x,
                                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, a: jnp.ndarray,
             *, chunk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """SSD scan over (BH, L, P) inputs with (BH, L, N) B/C and (BH, L) log-decay.

    ``a`` must already be the per-step log decay (dt * A_head, <= 0); ``x``
    the dt-scaled inputs.  L is padded to a chunk multiple internally."""
    bh, l, p = x.shape
    n = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad)))
    lp = x.shape[1]
    a3 = a[..., None]                               # (BH, L, 1) for blocking
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, lp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lp, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, b, c, a3)
    return out[:, :l]
