"""Streaming tall-apply kernel: C = A @ B with tall A and a small resident B.

This is the implicit-matvec shape of the rSVD power-iteration chain (paper
Alg. 4/5): every reconstitution ``Q = A P`` in the Gram-QR orthogonalization
and the final projections ``P u_small`` / ``q_t^* vh^T`` of
``core/rsvd.randomized_svd`` multiply a *tall* matricized operand
``(nbig, nsmall)`` by a small ``(nsmall, q)`` matrix.  Unlike the general
``tiled_matmul`` (M/N/K grid), B here fits VMEM whole: the grid runs over
row tiles of A only, B stays resident, and each tile emits its output slab
in one MXU pass with f32 accumulation — the same streaming structure as the
``gram`` kernel, which handles the other half of the chain (G = A^H A).

Complex operands use the planar trick in ONE real GEMM instead of four:

    [Re C | Im C] = [Re A | Im A] @ [[Re B, Im B], [-Im B, Re B]]

(Pallas-TPU has no complex dtype.)  ``compute`` optionally demotes the
multiplicands (``"bfloat16"`` under the mixed precision policy);
accumulation stays f32.  ``interpret=None`` autodetects (compiled on TPU,
interpret elsewhere).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _tall_apply_kernel(a_ref, b_ref, o_ref, *, compute):
    a_blk, b_blk = a_ref[...], b_ref[...]
    if compute is not None:
        a_blk, b_blk = a_blk.astype(compute), b_blk.astype(compute)
    o_ref[...] = jnp.dot(a_blk, b_blk,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "interpret", "compute"))
def _tall_apply(a: jnp.ndarray, b: jnp.ndarray, bm: int, interpret: bool,
                compute) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    a_p = _pad_axis(_pad_axis(a, bm, 0), 128, 1)
    b_p = _pad_axis(_pad_axis(b, 128, 0), 128, 1)
    mp, kp = a_p.shape
    _, np_ = b_p.shape
    kernel = functools.partial(
        _tall_apply_kernel,
        compute=None if compute is None else jnp.dtype(compute))
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i: (i, 0)),
            pl.BlockSpec((kp, np_), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, np_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def tall_apply(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256,
               interpret: Optional[bool] = None,
               compute=None) -> jnp.ndarray:
    """C = A @ B for real tall A (M, K) and small resident B (K, N)."""
    if interpret is None:
        from repro.kernels.dispatch import interpret_default
        interpret = interpret_default()
    return _tall_apply(a, b, bm, bool(interpret),
                       None if compute is None else jnp.dtype(compute).name)


def planar_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256,
                  interpret: Optional[bool] = None,
                  compute=None) -> jnp.ndarray:
    """C = A @ B through the tall-apply kernel, complex via one planar GEMM.

    Real operands go straight to :func:`tall_apply`.  Complex operands are
    planar-decomposed into a single doubled real GEMM (module docstring) —
    the kernel entry point for every complex matricized contraction of the
    zip-up / rSVD sites.
    """
    if not (jnp.issubdtype(a.dtype, jnp.complexfloating)
            or jnp.issubdtype(b.dtype, jnp.complexfloating)):
        return tall_apply(a, b, bm=bm, interpret=interpret, compute=compute)
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    n = b.shape[1]
    a2 = jnp.concatenate([ar, ai], axis=1)                       # (M, 2K)
    b2 = jnp.concatenate(
        [jnp.concatenate([br, bi], axis=1),
         jnp.concatenate([-bi, br], axis=1)], axis=0)            # (2K, 2N)
    c2 = tall_apply(a2, b2, bm=bm, interpret=interpret, compute=compute)
    return (c2[:, :n] + 1j * c2[:, n:]).astype(out_dtype)
