"""Kernel-dispatch registry: one gate for every Pallas micro-kernel site.

PR 1 introduced a single hard-wired gate for the Gram kernel inside
``core/orthogonalize.py``; this module generalizes it so every fused
micro-kernel — the streaming Gram, the tall-apply projections of the rSVD
chain, the zip-up first-column/pair-merge einsums — shares one decision
procedure, one set of hit/miss counters, and one trace-time signature that
the planner folds into its fused-cache keys.

Model
-----
A **site** is a named operation with two interchangeable implementations:

* ``dense``  — the reference ``jnp`` contraction (bit-identical to the
  pre-kernel code paths; the goldens are pinned against it);
* ``pallas`` — the tiled kernel (f32 accumulation, optional bf16
  multiplicands; interpret mode off-TPU).

Per call, :func:`dispatch` picks an implementation:

1. the site's **supported** predicate is a *hard* gate — dtypes the
   f32-accumulating kernels cannot serve at full precision (f64/c128)
   never route to Pallas, even when forced;
2. the mode — per-site override, else the global mode — decides the rest:
   ``"dense"`` forces dense, ``"pallas"`` forces the kernel, ``"auto"``
   additionally consults the site's **auto** shape/backend predicate
   (typically: tall-skinny operand AND a real TPU backend, so CPU CI
   stays on the exact dense path).

Every decision ticks ``pallas_<site>_calls`` / ``dense_<site>_calls``
(surfaced through ``planner.stats()``).  Counters tick at Python dispatch
time: inside a jit-fused solver they tick once per trace, not per replay —
the same contract as the planner counters.

Trace-time state
----------------
:func:`backend_signature` captures everything here that changes a traced
computation — global + per-site modes, the interpret override, and the
kernel compute dtype (:func:`set_kernel_compute`, set by the mixed
:class:`~repro.core.precision.PrecisionPolicy` around each solve).  The
planner appends it to every fused-cache key; forgetting it would silently
replay stale executables after a ``set_kernel_backend`` flip.

Interpret mode
--------------
Pallas-TPU kernels compile only on TPU; elsewhere they run in interpret
mode (functionally exact, slow — for correctness testing).
:func:`interpret_default` autodetects (compiled on TPU, interpret
otherwise) with two overrides: :func:`set_interpret_mode` (a process flag,
highest precedence) and the ``REPRO_PALLAS_INTERPRET`` environment
variable (``1``/``interpret`` or ``0``/``compiled``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import faults

_MODES = ("auto", "pallas", "dense")

# dtypes the f32-accumulating kernels serve at full (or better) precision.
# f64/c128 are excluded unconditionally: routing them through an f32
# accumulator would silently halve precision (see tests/test_dispatch.py).
KERNEL_DTYPES = (jnp.float32.dtype, jnp.bfloat16.dtype, jnp.complex64.dtype)


@dataclasses.dataclass(frozen=True)
class KernelSite:
    """One dispatchable operation (see module docstring)."""
    name: str
    pallas_fn: Callable
    dense_fn: Callable
    supported: Callable[..., bool]   # hard gate (dtype) — applies always
    auto: Callable[..., bool]        # soft gate (shape/backend) — auto mode


_SITES: Dict[str, KernelSite] = {}
_COUNTERS: Dict[str, int] = {}
_STATE = {
    "mode": "auto",                # global mode
    "interpret": "autodetect",     # "autodetect" | "interpret" | "compiled"
    "compute": None,               # kernel multiplicand dtype name (e.g.
}                                  # "bfloat16") or None for operand dtype
_SITE_MODES: Dict[str, str] = {}   # per-site overrides


def register_kernel(name: str, *, pallas: Callable, dense: Callable,
                    supported: Callable[..., bool] = None,
                    auto: Callable[..., bool] = None) -> KernelSite:
    """Register (or replace) a dispatch site.  Idempotent per name."""
    site = KernelSite(name, pallas, dense,
                      supported if supported is not None else lambda *a, **k: True,
                      auto if auto is not None else lambda *a, **k: False)
    _SITES[name] = site
    _COUNTERS.setdefault(f"pallas_{name}_calls", 0)
    _COUNTERS.setdefault(f"dense_{name}_calls", 0)
    return site


def registered_sites() -> tuple:
    return tuple(sorted(_SITES))


def dispatch(name: str, *args, **kwargs):
    """Run site ``name`` on ``args``, Pallas- or dense-routed (see module
    docstring for the decision procedure).  Unknown sites raise KeyError."""
    site = _SITES[name]
    mode = _SITE_MODES.get(name, _STATE["mode"])
    use_pallas = False
    if mode != "dense" and site.supported(*args, **kwargs):
        use_pallas = mode == "pallas" or site.auto(*args, **kwargs)
    if use_pallas:
        # Deterministic fault injection (tests of the runtime-guard dense
        # fallback).  Fires at Python dispatch time — i.e. while *tracing*
        # a fused solver, the same tick semantics as the counters below —
        # which models a kernel that fails to lower/compile on a device.
        if faults.should_fire(f"kernel.{name}") is not None:
            raise faults.InjectedFault(f"kernel.{name}")
        _COUNTERS[f"pallas_{name}_calls"] += 1
        return site.pallas_fn(*args, **kwargs)
    _COUNTERS[f"dense_{name}_calls"] += 1
    return site.dense_fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Mode / compute / interpret state
# ---------------------------------------------------------------------------

def set_kernel_backend(mode: str, site: Optional[str] = None) -> str:
    """Select ``'auto'`` | ``'pallas'`` | ``'dense'``, globally or for one
    ``site``.  Returns the previous value (for restore-in-finally)."""
    if mode not in _MODES:
        raise ValueError(f"bad kernel backend {mode!r}: expected one of {_MODES}")
    if site is not None:
        if site not in _SITES:
            raise KeyError(f"unknown kernel site {site!r}: "
                           f"registered: {registered_sites()}")
        prev = _SITE_MODES.get(site, _STATE["mode"])
        _SITE_MODES[site] = mode
        return prev
    prev = _STATE["mode"]
    _STATE["mode"] = mode
    _SITE_MODES.clear()    # a global set supersedes per-site overrides
    return prev


def kernel_backend(site: Optional[str] = None) -> str:
    """Effective mode, global or for one site."""
    if site is not None:
        return _SITE_MODES.get(site, _STATE["mode"])
    return _STATE["mode"]


@contextlib.contextmanager
def forced_dense():
    """Force every site dense for the duration (the runtime guard's
    ``dense_kernel`` escalation rung).  Saves and restores both the global
    mode and the per-site overrides, so a per-site ``'pallas'`` pin set by
    a test or a tuning run survives the guarded retry."""
    prev_mode = _STATE["mode"]
    prev_sites = dict(_SITE_MODES)
    _STATE["mode"] = "dense"
    _SITE_MODES.clear()
    try:
        yield
    finally:
        _STATE["mode"] = prev_mode
        _SITE_MODES.clear()
        _SITE_MODES.update(prev_sites)


def set_kernel_compute(dtype) -> Optional[str]:
    """Set the kernel multiplicand dtype (``'bfloat16'`` for the mixed
    precision policy, ``None`` for operand dtype).  Accumulation is always
    f32.  Returns the previous value."""
    prev = _STATE["compute"]
    _STATE["compute"] = None if dtype is None else jnp.dtype(dtype).name
    return prev


def kernel_compute() -> Optional[str]:
    return _STATE["compute"]


def set_interpret_mode(mode: str) -> str:
    """Force Pallas interpret mode: ``'interpret'``, ``'compiled'``, or
    ``'autodetect'`` (compiled on TPU, interpret elsewhere).  Highest
    precedence; overrides ``REPRO_PALLAS_INTERPRET``.  Returns previous."""
    if mode not in ("autodetect", "interpret", "compiled"):
        raise ValueError(f"bad interpret mode {mode!r}")
    prev = _STATE["interpret"]
    _STATE["interpret"] = mode
    return prev


def interpret_default() -> bool:
    """Whether Pallas kernels should run in interpret mode right now.

    Precedence: :func:`set_interpret_mode` flag > ``REPRO_PALLAS_INTERPRET``
    env var (``1``/``true``/``interpret`` vs ``0``/``false``/``compiled``) >
    backend autodetect (compiled iff ``jax.default_backend() == "tpu"``)."""
    mode = _STATE["interpret"]
    if mode == "interpret":
        return True
    if mode == "compiled":
        return False
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
    if env in ("1", "true", "interpret"):
        return True
    if env in ("0", "false", "compiled"):
        return False
    return jax.default_backend() != "tpu"


def backend_signature() -> tuple:
    """Every piece of dispatch state that changes a *traced* computation.

    Appended by the planner to fused-cache keys so flipping any of it
    (mode, per-site overrides, compute dtype, interpret mode) never
    silently replays a stale executable."""
    return (_STATE["mode"],
            tuple(sorted(_SITE_MODES.items())),
            _STATE["compute"],
            interpret_default())


# ---------------------------------------------------------------------------
# Counters
# ---------------------------------------------------------------------------

def dispatch_stats() -> Dict[str, int]:
    return dict(_COUNTERS)


def reset_dispatch_stats() -> None:
    for k in _COUNTERS:
        _COUNTERS[k] = 0


# ---------------------------------------------------------------------------
# Shared auto-gate helpers (the tall-skinny criterion of PR 1's gram gate)
# ---------------------------------------------------------------------------

PALLAS_MIN_BIG = 4096
PALLAS_MAX_SMALL = 512


def dtype_supported(*dtypes) -> bool:
    """True iff every dtype is one the f32-accumulating kernels serve."""
    return all(jnp.dtype(d) in KERNEL_DTYPES for d in dtypes)


def tall_skinny_auto(nbig: int, nsmall: int) -> bool:
    """The auto-mode shape/backend gate shared by the GEMM-shaped sites."""
    return (nbig >= PALLAS_MIN_BIG and nsmall <= PALLAS_MAX_SMALL
            and nbig >= 8 * nsmall and jax.default_backend() == "tpu")
