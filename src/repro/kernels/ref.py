"""Pure-jnp oracles for every Pallas kernel (used by the allclose sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(
        jnp.result_type(a.dtype, b.dtype))


def gram(a: jnp.ndarray) -> jnp.ndarray:
    a32 = a.astype(jnp.float32)
    return jnp.dot(a32.T, a32).astype(a.dtype)


def gram_complex(a: jnp.ndarray) -> jnp.ndarray:
    return a.conj().T @ a


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True) -> jnp.ndarray:
    """Reference attention over (B, Hq, S, D) with GQA (B, Hkv, Sk, D) kv."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        qi = jnp.arange(s)[:, None]
        kj = jnp.arange(sk)[None, :]
        logits = jnp.where(qi >= kj, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd(x: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
        a: jnp.ndarray) -> jnp.ndarray:
    """Naive SSD recurrence: h_t = exp(a_t) h_{t-1} + B_t (x) x_t; y = C.h."""
    bh, l, p = x.shape
    n = b.shape[-1]

    def step(h, inp):
        xt, bt, ct, at = inp
        h = jnp.exp(at) * h + jnp.outer(bt, xt)      # (N, P)
        return h, ct @ h

    def per_bh(xb, bb, cb, ab):
        h0 = jnp.zeros((n, p), jnp.float32)
        _, y = jax.lax.scan(step, h0, (xb.astype(jnp.float32),
                                       bb.astype(jnp.float32),
                                       cb.astype(jnp.float32),
                                       ab.astype(jnp.float32)))
        return y

    y = jax.vmap(per_bh)(x, b, c, a)
    return y.astype(x.dtype)
