"""MXU-aligned blocked matmul kernel (einsumsvd / IBMPS GEMM hot-spot).

The paper reports 60-70% of PEPS contraction time in GEMM; on TPU the same
GEMMs must be fed through the MXU with VMEM-resident tiles.  Grid is
(M/bm, N/bn, K/bk) with the K dimension sequential ("arbitrary") and a
float32 VMEM accumulator carried across K steps.

``interpret=None`` autodetects (compiled on TPU, interpret elsewhere; see
``repro.kernels.dispatch.interpret_default``); ``compute`` optionally
demotes the tile multiplicands (e.g. ``"bfloat16"``) while the accumulator
stays f32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, compute):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_blk, b_blk = a_ref[...], b_ref[...]
    if compute is not None:
        a_blk, b_blk = a_blk.astype(compute), b_blk.astype(compute)
    acc_ref[...] += jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret", "compute"))
def _tiled_matmul(a: jnp.ndarray, b: jnp.ndarray, bm: int, bn: int, bk: int,
                  interpret: bool, compute) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    a_p = _pad_to(_pad_to(a, bm, 0), bk, 1)
    b_p = _pad_to(_pad_to(b, bk, 0), bn, 1)
    mp, kp = a_p.shape
    _, np_ = b_p.shape
    kernel = functools.partial(
        _matmul_kernel, compute=None if compute is None else jnp.dtype(compute))
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def tiled_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                 bn: int = 128, bk: int = 128,
                 interpret: Optional[bool] = None,
                 compute=None) -> jnp.ndarray:
    """C = A @ B with explicit BlockSpec tiling; pads to block multiples."""
    if interpret is None:
        from repro.kernels.dispatch import interpret_default
        interpret = interpret_default()
    return _tiled_matmul(a, b, bm, bn, bk, bool(interpret),
                         None if compute is None else jnp.dtype(compute).name)
